//! Static dataflow: replay the copy/reduce contribution algebra of
//! [`crate::collectives::validate`] purely over *dependency order* — no
//! `ExecResult`, no simulated clock. Each op gets a completion depth
//! (`structure::done_depths`); a flow edge captures its source cell at
//! the op's start depth and applies it to the destination cell at the
//! op's completion depth, with applies ordered before captures at equal
//! depth (an arrival may feed a forward that starts the same instant —
//! the engine's dependency semantics). For dep-wired plans this replays
//! exactly the linearization the engine would produce, so the final
//! contracts — all n contributions exactly once — are provable before
//! anything executes.

use crate::collectives::{CollectiveKind, CollectivePlan, EdgeSem};

use super::diag::{Code, Diag};
use super::structure;

pub(super) fn check(cp: &CollectivePlan, diags: &mut Vec<Diag>) {
    let spec = &cp.spec;
    let n = spec.n_ranks;
    let k = cp.n_chunks;
    let plan = &cp.plan;
    let n_ops = plan.len();

    if n == 0 || k == 0 {
        diags.push(Diag::new(
            Code::ChunkCount,
            format!("degenerate collective shape: {n} ranks x {k} chunks"),
        ));
        return;
    }
    if matches!(
        spec.kind,
        CollectiveKind::ReduceScatter | CollectiveKind::Allgather
    ) && k != n
    {
        diags.push(Diag::new(
            Code::ChunkCount,
            format!(
                "{} plan must carry one chunk per rank (got {k} chunks for {n} ranks)",
                spec.kind.name()
            ),
        ));
        return;
    }

    // delivery labels: range + uniqueness, via a dense (rank, chunk) map
    // scanned in op order — first writer wins, the duplicate is reported
    // at the second op (deterministic, no hashing)
    let mut delivered = vec![usize::MAX; n * k];
    for (id, label) in plan.labels.iter().enumerate() {
        if let Some((r, c)) = *label {
            if r >= n || c >= k {
                diags.push(Diag::at(
                    Code::LabelRange,
                    id,
                    format!("delivery label ({r}, {c}) outside {n} ranks x {k} chunks"),
                ));
                continue;
            }
            let cell = r * k + c;
            if delivered[cell] != usize::MAX {
                diags.push(Diag::at(
                    Code::DuplicateLabel,
                    id,
                    format!(
                        "duplicate delivery of chunk {c} to rank {r} \
                         (ops {} and {id})",
                        delivered[cell]
                    ),
                ));
            } else {
                delivered[cell] = id;
            }
        }
    }

    // broadcast owes every (non-root rank, chunk) a labelled delivery
    if spec.kind == CollectiveKind::Broadcast {
        for r in 0..n {
            if r == spec.root {
                continue;
            }
            for c in 0..k {
                if delivered[r * k + c] == usize::MAX {
                    diags.push(Diag::new(
                        Code::MissingDelivery,
                        format!("rank {r} never receives chunk {c}"),
                    ));
                }
            }
        }
    }

    // flow edges: range checks gate the replay (bad indices cannot be
    // replayed), duplicates are structural waste/double-application
    let mut edges_ok = true;
    for (i, e) in cp.edges.iter().enumerate() {
        let problem = if e.src >= n || e.dst >= n {
            Some(format!("edge {i}: ranks {} -> {} outside 0..{n}", e.src, e.dst))
        } else if e.chunk >= k {
            Some(format!("edge {i}: chunk {} outside 0..{k}", e.chunk))
        } else if e.op >= n_ops {
            Some(format!("edge {i}: references nonexistent op {}", e.op))
        } else {
            None
        };
        if let Some(message) = problem {
            diags.push(Diag::new(Code::EdgeRange, message));
            edges_ok = false;
        }
    }
    if edges_ok && spec.kind != CollectiveKind::Broadcast {
        // broadcast legitimately records several custody edges per
        // (dst, chunk); reductions must ship each contribution once
        let mut keys: Vec<(usize, usize, usize, u8, usize)> = cp
            .edges
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let sem = match e.sem {
                    EdgeSem::Copy => 0u8,
                    EdgeSem::Reduce => 1u8,
                };
                (e.src, e.dst, e.chunk, sem, i)
            })
            .collect();
        keys.sort_unstable();
        for pair in keys.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            if (a.0, a.1, a.2, a.3) == (b.0, b.1, b.2, b.3) {
                diags.push(Diag::at(
                    Code::DuplicateEdge,
                    cp.edges[b.4].op,
                    format!(
                        "duplicate flow edge {} -> {} for chunk {} (edges {} and {})",
                        a.0, a.1, a.2, a.4, b.4
                    ),
                ));
                edges_ok = false;
            }
        }
    }
    if !edges_ok {
        return;
    }

    let Some(depths) = structure::done_depths(plan) else {
        // cyclic or dangling — already diagnosed by the structure pass
        return;
    };

    // initial contributions, one dense cell per (rank, chunk)
    let mut state: Vec<Vec<u32>> = vec![vec![0u32; n]; n * k];
    match spec.kind {
        CollectiveKind::Broadcast => {
            for c in 0..k {
                state[spec.root * k + c][spec.root] = 1;
            }
        }
        CollectiveKind::ReduceScatter | CollectiveKind::Allreduce => {
            for r in 0..n {
                for c in 0..k {
                    state[r * k + c][r] = 1;
                }
            }
        }
        CollectiveKind::Allgather => {
            for r in 0..n {
                state[r * k + r][r] = 1;
            }
        }
    }

    // replay edge events in depth order; applies before captures at the
    // same depth
    const APPLY: u8 = 0;
    const CAPTURE: u8 = 1;
    let mut events: Vec<(u32, u8, usize)> = Vec::with_capacity(2 * cp.edges.len());
    for (i, e) in cp.edges.iter().enumerate() {
        events.push((depths[e.op] - 1, CAPTURE, i));
        events.push((depths[e.op], APPLY, i));
    }
    events.sort_unstable();

    let mut payloads: Vec<Option<Vec<u32>>> = vec![None; cp.edges.len()];
    let mut causal = true;
    for (_depth, phase, i) in events {
        let e = &cp.edges[i];
        if phase == CAPTURE {
            let snap = state[e.src * k + e.chunk].clone();
            if snap.iter().all(|&x| x == 0) {
                diags.push(Diag::at(
                    Code::Causality,
                    e.op,
                    format!(
                        "rank {} forwards chunk {} before any dependency \
                         chain could deliver it",
                        e.src, e.chunk
                    ),
                ));
                causal = false;
            }
            payloads[i] = Some(snap);
        } else {
            let payload = payloads[i].take().unwrap_or_else(|| vec![0u32; n]);
            match e.sem {
                EdgeSem::Reduce => {
                    for (acc, add) in state[e.dst * k + e.chunk].iter_mut().zip(&payload) {
                        *acc = acc.saturating_add(*add);
                    }
                }
                EdgeSem::Copy => state[e.dst * k + e.chunk] = payload,
            }
        }
    }
    if !causal {
        // the final state is garbage downstream of a causality break;
        // reporting contract mismatches on top would only add noise
        return;
    }

    // final contracts
    let mut contract = |rank: usize, chunk: usize, want: &dyn Fn(usize) -> u32| {
        for (i, &got) in state[rank * k + chunk].iter().enumerate() {
            let want = want(i);
            if got != want {
                diags.push(Diag::new(
                    Code::Contribution,
                    format!(
                        "rank {rank} chunk {chunk}: contribution from rank {i} \
                         appears {got} times (want {want})"
                    ),
                ));
            }
        }
    };
    match spec.kind {
        CollectiveKind::Broadcast => {
            let root = spec.root;
            for r in 0..n {
                for c in 0..k {
                    contract(r, c, &|i| u32::from(i == root));
                }
            }
        }
        CollectiveKind::Allreduce => {
            for r in 0..n {
                for c in 0..k {
                    contract(r, c, &|_| 1);
                }
            }
        }
        CollectiveKind::ReduceScatter => {
            for s in 0..n {
                contract(s, s, &|_| 1);
            }
        }
        CollectiveKind::Allgather => {
            for r in 0..n {
                for c in 0..k {
                    contract(r, c, &|i| u32::from(i == c));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{self, Algorithm, BcastSpec, CollectiveSpec};
    use crate::comm::Comm;
    use crate::netsim::Deps;
    use crate::topology::presets::{flat, kesch};

    fn diags_for(cp: &CollectivePlan) -> Vec<Diag> {
        let mut diags = Vec::new();
        check(cp, &mut diags);
        diags
    }

    #[test]
    fn every_algorithm_replays_clean() {
        let c = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&c);
        for (algo, spec) in [
            (Algorithm::Direct, BcastSpec::new(0, 8, 1 << 20)),
            (Algorithm::Chain, BcastSpec::new(3, 8, 1 << 20)),
            (
                Algorithm::PipelinedChain { chunk: 64 << 10 },
                BcastSpec::new(0, 8, 1 << 20),
            ),
            (Algorithm::Knomial { k: 2 }, BcastSpec::new(0, 8, 1 << 20)),
            (
                Algorithm::ScatterRingAllgather,
                BcastSpec::new(0, 8, 1 << 20),
            ),
            (
                Algorithm::HostStagedKnomial { k: 2 },
                BcastSpec::new(0, 8, 64 << 10),
            ),
            (
                Algorithm::RingReduceScatter,
                CollectiveSpec::reduce_scatter(8, 1 << 20),
            ),
            (
                Algorithm::RingAllgather,
                CollectiveSpec::allgather(8, 1 << 20),
            ),
            (
                Algorithm::RingAllreduce,
                CollectiveSpec::allreduce(8, 1 << 20),
            ),
            (
                Algorithm::TreeAllreduce { k: 2 },
                CollectiveSpec::allreduce(8, 8 << 10),
            ),
        ] {
            let cp = collectives::plan(&algo, &mut comm, &spec);
            let diags = diags_for(&cp);
            assert!(diags.is_empty(), "{}: {diags:?}", algo.name());
        }
    }

    #[test]
    fn dropped_dep_breaks_static_causality() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = collectives::chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        cp.plan.deps[1] = Deps::none();
        let diags = diags_for(&cp);
        assert!(
            diags.iter().any(|d| d.code == Code::Causality),
            "{diags:?}"
        );
    }

    #[test]
    fn dropped_reduce_edge_breaks_contract() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = collectives::allreduce::ring(&mut comm, &CollectiveSpec::allreduce(4, 4096));
        cp.edges.remove(0);
        let diags = diags_for(&cp);
        assert!(
            diags.iter().any(|d| d.code == Code::Contribution),
            "{diags:?}"
        );
    }

    #[test]
    fn duplicated_reduce_edge_flagged() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = collectives::allreduce::ring(&mut comm, &CollectiveSpec::allreduce(4, 4096));
        let dup = cp.edges[0];
        cp.edges.push(dup);
        let diags = diags_for(&cp);
        assert!(
            diags.iter().any(|d| d.code == Code::DuplicateEdge),
            "{diags:?}"
        );
    }

    #[test]
    fn missing_and_duplicate_labels_flagged() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = collectives::chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        let last = cp.plan.len() - 1;
        let first_labeled = (0..last)
            .find(|&i| cp.plan.label_of(i).is_some())
            .expect("chain has labelled deliveries before the tail");
        let hijack = cp.plan.label_of(first_labeled);
        cp.plan.set_label(last, hijack);
        let diags = diags_for(&cp);
        assert!(
            diags.iter().any(|d| d.code == Code::DuplicateLabel),
            "{diags:?}"
        );
        assert!(
            diags.iter().any(|d| d.code == Code::MissingDelivery),
            "{diags:?}"
        );
    }

    #[test]
    fn wrong_chunk_count_flagged() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = collectives::reduce_scatter::plan(
            &mut comm,
            &CollectiveSpec::reduce_scatter(4, 4096),
        );
        cp.n_chunks = 2;
        let diags = diags_for(&cp);
        assert!(
            diags.iter().any(|d| d.code == Code::ChunkCount),
            "{diags:?}"
        );
    }
}
