//! Typed verifier diagnostics: stable codes, deterministic ordering.
//!
//! Every invariant the static verifier (and the post-execution validator
//! in [`crate::collectives::validate`]) can reject has a stable `PL*`
//! code — `PL0xx` are errors (the plan is wrong), `PL1xx` are warnings
//! (the plan is suspicious but executable). Diagnostics are plain data:
//! a code, an optional anchoring op id and a rendered message. Reports
//! are sorted by `(op id, code, message)` — never by hash-map iteration
//! order — so the same plan yields byte-identical output run to run
//! (DESIGN.md §Static plan verification).

use crate::netsim::OpId;
use std::fmt;

/// How bad a diagnostic is: errors fail verification (and panic the
/// debug-build hooks), warnings are reported but do not.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Error,
    Warning,
}

/// Stable diagnostic codes. The numeric part never changes meaning; new
/// checks append new codes. Declaration order matches numeric order so
/// the derived `Ord` sorts reports by code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Code {
    /// PL001: the dependency graph has a cycle — the plan can never run
    /// to completion (deadlock).
    Cycle,
    /// PL002: an op depends on an op id past the end of the plan.
    DanglingDep,
    /// PL003: an op depends on itself.
    SelfDep,
    /// PL004: the SoA columns disagree on length — the plan was mutated
    /// behind the builders' back.
    ColumnMismatch,
    /// PL005: a transfer's `RouteId` was interned under an older topology
    /// generation (stale template after `kill_link`/`retain_ranks`).
    StaleRoute,
    /// PL006: a transfer's route traverses a link marked dead.
    DeadLink,
    /// PL007: a transfer endpoint is a GPU that is no longer a rank
    /// (removed by `retain_ranks`).
    DeadEndpoint,
    /// PL008: a delivery label's (rank, chunk) is outside the
    /// collective's declared shape.
    LabelRange,
    /// PL009: two ops deliver the same (rank, chunk).
    DuplicateLabel,
    /// PL010: a (rank, chunk) the collective owes a delivery to is never
    /// delivered.
    MissingDelivery,
    /// PL011: static causality violation — a flow edge captures its
    /// source's buffer before any dependency chain could have filled it.
    Causality,
    /// PL012: a flow edge references an out-of-range rank, chunk or op.
    EdgeRange,
    /// PL013: duplicate flow edge (same src, dst, chunk, semantics) —
    /// wasted traffic or double-applied reduction.
    DuplicateEdge,
    /// PL014: the replayed final state violates the collective's
    /// contract (a contribution appears the wrong number of times).
    Contribution,
    /// PL015: the chunk count is inconsistent with the collective kind
    /// (reduce-scatter/allgather carry one chunk per rank).
    ChunkCount,
    /// PL016: a delay row carries transfer-only parameters (nonzero
    /// bytes/issue cost or a finite bandwidth cap).
    MalformedDelay,
    /// PL017: a transfer's route hop chain is not a contiguous path from
    /// its source to its destination (an algebraic resolver emitted a
    /// broken hop sequence, or the route was assembled by hand).
    BrokenPath,
    /// PL100 (warning): a zero-byte transfer still pays a nonzero
    /// protocol overhead.
    ZeroByteOverhead,
    /// PL101 (warning): a terminal transfer into a rank GPU carries no
    /// delivery label — completions there are invisible to
    /// delivery-tracking consumers.
    UnlabeledTerminal,
    /// PL102 (warning): a byte or duration column entry sits in the
    /// `UNREACHABLE_NS` saturation band — likely leaked sentinel
    /// arithmetic.
    UnreachableValue,
}

impl Code {
    /// Every code, in numeric order (docs and coverage tests iterate
    /// this).
    pub const ALL: [Code; 20] = [
        Code::Cycle,
        Code::DanglingDep,
        Code::SelfDep,
        Code::ColumnMismatch,
        Code::StaleRoute,
        Code::DeadLink,
        Code::DeadEndpoint,
        Code::LabelRange,
        Code::DuplicateLabel,
        Code::MissingDelivery,
        Code::Causality,
        Code::EdgeRange,
        Code::DuplicateEdge,
        Code::Contribution,
        Code::ChunkCount,
        Code::MalformedDelay,
        Code::BrokenPath,
        Code::ZeroByteOverhead,
        Code::UnlabeledTerminal,
        Code::UnreachableValue,
    ];

    /// The stable wire/display name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Code::Cycle => "PL001",
            Code::DanglingDep => "PL002",
            Code::SelfDep => "PL003",
            Code::ColumnMismatch => "PL004",
            Code::StaleRoute => "PL005",
            Code::DeadLink => "PL006",
            Code::DeadEndpoint => "PL007",
            Code::LabelRange => "PL008",
            Code::DuplicateLabel => "PL009",
            Code::MissingDelivery => "PL010",
            Code::Causality => "PL011",
            Code::EdgeRange => "PL012",
            Code::DuplicateEdge => "PL013",
            Code::Contribution => "PL014",
            Code::ChunkCount => "PL015",
            Code::MalformedDelay => "PL016",
            Code::BrokenPath => "PL017",
            Code::ZeroByteOverhead => "PL100",
            Code::UnlabeledTerminal => "PL101",
            Code::UnreachableValue => "PL102",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            Code::ZeroByteOverhead | Code::UnlabeledTerminal | Code::UnreachableValue => {
                Severity::Warning
            }
            _ => Severity::Error,
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One verifier finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diag {
    pub code: Code,
    /// The op the finding anchors to, when there is a single one.
    pub op: Option<OpId>,
    pub message: String,
}

impl Diag {
    /// A plan-level finding (no single anchoring op).
    pub fn new(code: Code, message: impl Into<String>) -> Diag {
        Diag {
            code,
            op: None,
            message: message.into(),
        }
    }

    /// A finding anchored to op `op`.
    pub fn at(code: Code, op: OpId, message: impl Into<String>) -> Diag {
        Diag {
            code,
            op: Some(op),
            message: message.into(),
        }
    }

    pub fn severity(&self) -> Severity {
        self.code.severity()
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.op {
            Some(op) => write!(f, "{} [op {}]: {}", self.code, op, self.message),
            None => write!(f, "{}: {}", self.code, self.message),
        }
    }
}

/// Canonical report order: by anchoring op (plan-level findings last),
/// then code, then message — fully deterministic, independent of
/// discovery order.
pub fn sort(diags: &mut [Diag]) {
    diags.sort_by(|a, b| {
        let ka = (a.op.unwrap_or(usize::MAX), a.code, &a.message);
        let kb = (b.op.unwrap_or(usize::MAX), b.code, &b.message);
        ka.cmp(&kb)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_and_ordered() {
        for pair in Code::ALL.windows(2) {
            assert!(pair[0] < pair[1], "{} !< {}", pair[0], pair[1]);
            assert!(pair[0].as_str() < pair[1].as_str());
        }
    }

    #[test]
    fn severity_split_matches_numbering() {
        for code in Code::ALL {
            let is_warning = code.as_str().starts_with("PL1");
            assert_eq!(code.severity() == Severity::Warning, is_warning, "{code}");
        }
    }

    #[test]
    fn report_order_is_op_then_code() {
        let mut diags = vec![
            Diag::new(Code::MissingDelivery, "plan-level"),
            Diag::at(Code::Causality, 7, "late"),
            Diag::at(Code::Cycle, 2, "loop"),
            Diag::at(Code::SelfDep, 2, "self"),
        ];
        sort(&mut diags);
        let codes: Vec<&str> = diags.iter().map(|d| d.code.as_str()).collect();
        assert_eq!(codes, ["PL001", "PL003", "PL011", "PL010"]);
        assert_eq!(
            diags[0].to_string(),
            "PL001 [op 2]: loop",
            "display format is part of the stable surface"
        );
    }
}
