//! Mutation-kill tests: seed one defect into a known-good plan and
//! assert the verifier reports the expected `PL*` code — across every
//! topology family. If a check ever regresses into a no-op, one of
//! these fails.

use crate::collectives::{self, Algorithm, CollectivePlan, CollectiveSpec};
use crate::comm::Comm;
use crate::netsim::{Deps, SimOp};
use crate::topology::presets::{flat, kesch};
use crate::topology::Cluster;

use super::{has_errors, render, verify_collective, Code};

fn topologies() -> Vec<(&'static str, Cluster)> {
    vec![
        ("flat(8)", flat(8).unwrap()),
        ("kesch(1,16)", kesch(1, 16).unwrap()),
        ("kesch(2,8)", kesch(2, 8).unwrap()),
    ]
}

fn chain_plan(c: &Cluster) -> CollectivePlan {
    let mut comm = Comm::new(c);
    let spec = CollectiveSpec::new(0, c.n_gpus(), 1 << 20);
    collectives::plan(&Algorithm::Chain, &mut comm, &spec)
}

/// Apply `mutate`, verify, and assert `code` is reported (as an error).
fn assert_killed(
    name: &str,
    c: &Cluster,
    mut cp: CollectivePlan,
    code: Code,
    mutate: impl FnOnce(&mut Cluster, &mut CollectivePlan),
) {
    let mut cluster = c.clone();
    mutate(&mut cluster, &mut cp);
    let diags = verify_collective(&cluster, &cp);
    assert!(
        diags.iter().any(|d| d.code == code),
        "{name}: mutation not flagged {code}; got:\n{}",
        render(&diags)
    );
    assert!(has_errors(&diags), "{name}: {code} must be error severity");
}

#[test]
fn baseline_plans_are_clean_everywhere() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        let diags = verify_collective(&c, &cp);
        assert!(!has_errors(&diags), "{name}:\n{}", render(&diags));
    }
}

#[test]
fn dropped_dep_is_flagged_pl011() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        assert_killed(name, &c, cp, Code::Causality, |_, cp| {
            // the final delivery op captures its source's buffer before
            // any dependency chain could have filled it
            let last = cp.plan.len() - 1;
            cp.plan.deps[last] = Deps::none();
        });
    }
}

#[test]
fn introduced_cycle_is_flagged_pl001() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        assert_killed(name, &c, cp, Code::Cycle, |_, cp| {
            // the chain's head already (transitively) feeds the tail;
            // closing the loop deadlocks the whole plan
            let last = cp.plan.len() - 1;
            cp.plan.deps[0] = Deps::one(last);
        });
    }
}

#[test]
fn byte_swapped_into_delay_row_is_flagged_pl016() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        let dev = c.rank_device(0);
        assert_killed(name, &c, cp, Code::MalformedDelay, move |_, cp| {
            let id = cp.plan.push(SimOp::Delay { dev, dur_ns: 5 }, Deps::none(), None);
            // direct column surgery behind `push`'s back
            cp.plan.bytes[id] = 42;
        });
    }
}

#[test]
fn stale_route_after_kill_link_is_flagged_pl005() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        assert_killed(name, &c, cp, Code::StaleRoute, |cluster, _| {
            // any kill bumps the topology generation; the un-rebuilt
            // plan's interned routes all go stale
            let victim = cluster.links()[0].id;
            cluster.kill_link(victim).unwrap();
        });
    }
}

#[test]
fn duplicated_label_is_flagged_pl009() {
    for (name, c) in topologies() {
        let cp = chain_plan(&c);
        assert_killed(name, &c, cp, Code::DuplicateLabel, |_, cp| {
            let labeled: Vec<usize> = (0..cp.plan.len())
                .filter(|&i| cp.plan.label_of(i).is_some())
                .collect();
            assert!(labeled.len() >= 2, "chain delivers to at least 2 ranks");
            let hijack = cp.plan.label_of(labeled[0]);
            cp.plan.set_label(labeled[1], hijack);
        });
    }
}
