//! Static plan verification: prove DAG, route and dataflow invariants
//! *before* execution.
//!
//! The netsim engine and the post-execution validator catch broken plans
//! late — after simulated time was spent, or (for `debug_assert`s) only
//! on the exact schedule that ran. This module proves the same
//! invariants statically, over any [`Plan`]/[`CollectivePlan`] — freshly
//! built, merged into an overlap timeline, or replanned after a fault —
//! without executing anything:
//!
//! - **structure** — SoA column consistency, dependency sanity
//!   (in-range, non-self), acyclicity ([`structure`]);
//! - **routes** — every `RouteId` current under the cluster's topology
//!   generation, no dead-link traversal, endpoints still ranks
//!   ([`routes`]);
//! - **dataflow** — replay of the copy/reduce contribution-set algebra
//!   over dependency order, proving every rank ends with exactly the
//!   contributions its collective contract owes it ([`dataflow`]);
//! - **lints** — suspicious-but-executable shapes: zero-byte transfers
//!   paying overhead, unlabeled terminal deliveries, values in the
//!   `UNREACHABLE_NS` saturation band ([`lints`]).
//!
//! Findings are typed [`Diag`]s with stable `PL*` codes, reported in a
//! deterministic order (never hash-map iteration order). Debug builds
//! run the verifier on every plan entering [`Engine::run`] and every
//! collective plan built by `collectives::plan`, so the whole test suite
//! doubles as a verifier test; release builds compile the hooks to
//! nothing (`verify_time_ns` proves it from the bench path). Opt out
//! with `GDRBCAST_VERIFY=0`.
//!
//! [`Engine::run`]: crate::netsim::Engine::run

mod dataflow;
mod diag;
mod lints;
#[cfg(test)]
mod mutation;
mod routes;
mod structure;

pub use diag::{sort, Code, Diag, Severity};

use crate::collectives::CollectivePlan;
use crate::netsim::Plan;
use crate::topology::Cluster;
use std::sync::atomic::{AtomicU64, Ordering};

/// Statically verify a raw transfer plan against `cluster`: structure,
/// route liveness and sanity lints. Returns all findings, sorted into
/// the canonical deterministic order (errors and warnings mixed; filter
/// with [`has_errors`] / [`Diag::severity`]).
pub fn verify_plan(cluster: &Cluster, plan: &Plan) -> Vec<Diag> {
    let mut diags = Vec::new();
    if structure::check(plan, &mut diags) {
        routes::check(cluster, plan, &mut diags);
        lints::check(cluster, plan, &mut diags);
    }
    sort(&mut diags);
    diags
}

/// Statically verify a collective plan: everything [`verify_plan`]
/// proves, plus the label/edge shape and the contribution-set dataflow
/// contract of the collective kind.
pub fn verify_collective(cluster: &Cluster, cp: &CollectivePlan) -> Vec<Diag> {
    let mut diags = Vec::new();
    if structure::check(&cp.plan, &mut diags) {
        routes::check(cluster, &cp.plan, &mut diags);
        lints::check(cluster, &cp.plan, &mut diags);
        dataflow::check(cp, &mut diags);
    }
    sort(&mut diags);
    diags
}

/// Whether any finding is an error (warnings alone verify clean).
pub fn has_errors(diags: &[Diag]) -> bool {
    diags.iter().any(|d| d.severity() == Severity::Error)
}

/// Render findings one per line for terminal/panic output.
pub fn render(diags: &[Diag]) -> String {
    let mut out = String::new();
    for d in diags {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

/// Nanoseconds spent inside the debug verification hooks since process
/// start. Always 0 in release builds — the bench harness records this to
/// prove the verifier costs nothing on the measured path.
pub fn verify_time_ns() -> u64 {
    VERIFY_NS.load(Ordering::Relaxed)
}

static VERIFY_NS: AtomicU64 = AtomicU64::new(0);

#[cfg(debug_assertions)]
fn hooks_enabled() -> bool {
    use std::sync::OnceLock;
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| std::env::var("GDRBCAST_VERIFY").as_deref() != Ok("0"))
}

#[cfg(debug_assertions)]
fn finish_hook(context: &str, diags: Vec<Diag>, started: std::time::Instant) {
    VERIFY_NS.fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if has_errors(&diags) {
        panic!("static plan verification failed at {context}:\n{}", render(&diags));
    }
}

/// Debug-build hook: verify `plan` and panic (with the rendered report)
/// on any error-severity finding. Compiled to nothing in release builds;
/// disable in debug builds with `GDRBCAST_VERIFY=0`.
#[cfg(debug_assertions)]
pub fn debug_verify_plan(cluster: &Cluster, plan: &Plan, context: &str) {
    if !hooks_enabled() {
        return;
    }
    let started = std::time::Instant::now();
    let diags = verify_plan(cluster, plan);
    finish_hook(context, diags, started);
}

/// Release-build no-op twin of the debug verification hook.
#[cfg(not(debug_assertions))]
#[inline(always)]
pub fn debug_verify_plan(_cluster: &Cluster, _plan: &Plan, _context: &str) {}

/// Debug-build hook for collective plans (adds the dataflow contract to
/// [`debug_verify_plan`]'s checks). No-op in release builds.
#[cfg(debug_assertions)]
pub fn debug_verify_collective(cluster: &Cluster, cp: &CollectivePlan, context: &str) {
    if !hooks_enabled() {
        return;
    }
    let started = std::time::Instant::now();
    let diags = verify_collective(cluster, cp);
    finish_hook(context, diags, started);
}

/// Release-build no-op twin of the collective verification hook.
#[cfg(not(debug_assertions))]
#[inline(always)]
pub fn debug_verify_collective(_cluster: &Cluster, _cp: &CollectivePlan, _context: &str) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{chain, plan, Algorithm, BcastSpec};
    use crate::comm::Comm;
    use crate::netsim::Deps;
    use crate::topology::presets::{flat, kesch};

    #[test]
    fn clean_collective_plan_verifies() {
        let c = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&c);
        let cp = plan(
            &Algorithm::Knomial { k: 2 },
            &mut comm,
            &BcastSpec::new(0, 8, 1 << 20),
        );
        let diags = verify_collective(&c, &cp);
        assert!(!has_errors(&diags), "{}", render(&diags));
    }

    #[test]
    fn report_is_deterministic_and_sorted() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut cp = chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        cp.plan.deps[1] = Deps::none(); // break causality
        cp.plan.set_label(cp.plan.len() - 1, None); // drop a delivery
        let a = verify_collective(&c, &cp);
        let b = verify_collective(&c, &cp);
        assert_eq!(a, b);
        assert!(has_errors(&a), "{}", render(&a));
        for pair in a.windows(2) {
            let key = |d: &Diag| (d.op.unwrap_or(usize::MAX), d.code);
            assert!(key(&pair[0]) <= key(&pair[1]), "{}", render(&a));
        }
    }

    #[test]
    fn warnings_alone_do_not_fail_verification() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let cp = chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        let mut plan = cp.plan.clone();
        plan.bytes[0] = 0; // zero-byte transfer paying overhead: PL100
        let diags = verify_plan(&c, &plan);
        assert!(!diags.is_empty(), "expected a PL100 warning");
        assert!(!has_errors(&diags), "{}", render(&diags));
    }
}
