//! Sanity lints: findings that don't make a plan wrong, but almost
//! always mean a builder (or a rescale) did something unintended. All
//! warnings (`PL1xx`) — the debug-build hooks ignore them; the `verify`
//! CLI reports them.

use crate::netsim::{OpEnd, Plan, UNREACHABLE_NS};
use crate::topology::{Cluster, DeviceKind};

use super::diag::{Code, Diag};

pub(super) fn check(cluster: &Cluster, plan: &Plan, diags: &mut Vec<Diag>) {
    let dependents = plan.dependent_flags();
    for id in 0..plan.len() {
        // values in the saturation band mean sentinel arithmetic leaked
        // into a parameter column (tx_ns saturates *to* UNREACHABLE_NS;
        // anything at or above it in bytes/durations is nonsense)
        if plan.bytes[id] >= UNREACHABLE_NS
            || plan.overheads[id] >= UNREACHABLE_NS
            || plan.issues[id] >= UNREACHABLE_NS
        {
            diags.push(Diag::at(
                Code::UnreachableValue,
                id,
                format!(
                    "parameter column in the UNREACHABLE_NS saturation band \
                     (bytes {}, overhead {} ns, issue {} ns)",
                    plan.bytes[id], plan.overheads[id], plan.issues[id]
                ),
            ));
        }
        let OpEnd::Route(route) = plan.ends[id] else {
            continue;
        };
        if plan.bytes[id] == 0 && plan.overheads[id] > 0 {
            diags.push(Diag::at(
                Code::ZeroByteOverhead,
                id,
                format!(
                    "zero-byte transfer still pays {} ns of overhead",
                    plan.overheads[id]
                ),
            ));
        }
        // a terminal transfer into a rank GPU with no delivery label is
        // invisible to delivery tracking — usually a forgotten label
        if !dependents[id] && plan.labels[id].is_none() && cluster.route_current(route) {
            let dst = cluster.route_meta(route).dst;
            if cluster.device(dst).kind == DeviceKind::Gpu
                && cluster.gpu_ranks().contains(&dst)
            {
                diags.push(Diag::at(
                    Code::UnlabeledTerminal,
                    id,
                    format!("terminal transfer into rank GPU {} has no label", dst.0),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{chain, BcastSpec};
    use crate::comm::Comm;
    use crate::topology::presets::flat;

    #[test]
    fn clean_plan_has_no_lint_findings() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        let mut diags = Vec::new();
        check(&c, &bp.plan, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn zero_byte_overhead_and_unlabeled_terminal_flagged() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        let mut plan = bp.plan.clone();
        let last = plan.len() - 1;
        plan.set_label(last, None); // terminal delivery, label dropped
        plan.bytes[last] = 0; // and starved of payload
        let mut diags = Vec::new();
        check(&c, &plan, &mut diags);
        let codes: Vec<Code> = diags.iter().map(|d| d.code).collect();
        assert!(codes.contains(&Code::ZeroByteOverhead), "{diags:?}");
        assert!(codes.contains(&Code::UnlabeledTerminal), "{diags:?}");
    }

    #[test]
    fn saturation_band_values_flagged() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20));
        let mut plan = bp.plan.clone();
        plan.overheads[0] = UNREACHABLE_NS;
        let mut diags = Vec::new();
        check(&c, &plan, &mut diags);
        assert!(
            diags.iter().any(|d| d.code == Code::UnreachableValue),
            "{diags:?}"
        );
    }
}
