//! DAG well-formedness: column consistency, dependency sanity, acyclicity.

use crate::netsim::{OpEnd, Plan};

use super::diag::{Code, Diag};

/// Check structural invariants, appending findings to `diags`. Returns
/// `true` when the plan is structurally sound — columns consistent,
/// every dep in range and non-self, no cycles — i.e. when the deeper
/// route/dataflow passes may safely index and topologically order it.
pub(super) fn check(plan: &Plan, diags: &mut Vec<Diag>) -> bool {
    let lens = plan.column_lens();
    let n = lens[0];
    if lens.iter().any(|&l| l != n) {
        diags.push(Diag::new(
            Code::ColumnMismatch,
            format!(
                "SoA columns disagree on length \
                 (ends/bytes/overheads/issues/bw_caps/deps/labels = {lens:?})"
            ),
        ));
        // nothing below can index safely
        return false;
    }

    let mut sound = true;
    for (id, deps) in plan.deps.iter().enumerate() {
        for &d in deps.as_slice() {
            if d >= n {
                diags.push(Diag::at(
                    Code::DanglingDep,
                    id,
                    format!("depends on nonexistent op {d} (plan has {n} ops)"),
                ));
                sound = false;
            } else if d == id {
                diags.push(Diag::at(Code::SelfDep, id, "depends on itself".to_string()));
                sound = false;
            }
        }
    }

    // delay rows must carry neutral transfer parameters: `Plan::push`
    // guarantees it, so a violation means the columns were mutated
    // directly (or a future append path went wrong)
    for id in 0..n {
        if let OpEnd::Dev(_) = plan.ends[id] {
            if plan.bytes[id] != 0 || plan.issues[id] != 0 || plan.bw_caps[id].is_finite() {
                diags.push(Diag::at(
                    Code::MalformedDelay,
                    id,
                    format!(
                        "delay row carries transfer parameters \
                         (bytes {}, issue {} ns, bw cap {})",
                        plan.bytes[id], plan.issues[id], plan.bw_caps[id]
                    ),
                ));
                sound = false;
            }
        }
    }

    let unprocessed = kahn_unprocessed(plan, n);
    if unprocessed > 0 {
        let stuck = first_stuck_op(plan, n);
        diags.push(Diag::at(
            Code::Cycle,
            stuck,
            format!("dependency cycle: {unprocessed} op(s) can never become ready"),
        ));
        sound = false;
    }
    sound
}

/// Number of ops Kahn's algorithm cannot schedule (0 ⇔ acyclic).
/// Out-of-range deps are ignored here — they are diagnosed separately.
fn kahn_unprocessed(plan: &Plan, n: usize) -> usize {
    let (indeg, start, adj) = adjacency(plan, n);
    let mut indeg = indeg;
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut processed = 0usize;
    while let Some(i) = ready.pop() {
        processed += 1;
        for &j in &adj[start[i]..start[i + 1]] {
            indeg[j] -= 1;
            if indeg[j] == 0 {
                ready.push(j);
            }
        }
    }
    n - processed
}

/// The smallest op id left unscheduled by Kahn's algorithm — the
/// deterministic anchor for the cycle diagnostic.
fn first_stuck_op(plan: &Plan, n: usize) -> usize {
    let (indeg, start, adj) = adjacency(plan, n);
    let mut indeg = indeg;
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    while let Some(i) = ready.pop() {
        for &j in &adj[start[i]..start[i + 1]] {
            indeg[j] -= 1;
            if indeg[j] == 0 {
                ready.push(j);
            }
        }
    }
    indeg.iter().position(|&d| d > 0).unwrap_or(0)
}

/// CSR adjacency (dep -> dependents) plus per-op in-degrees, counting
/// only in-range deps.
fn adjacency(plan: &Plan, n: usize) -> (Vec<u32>, Vec<usize>, Vec<usize>) {
    let mut indeg = vec![0u32; n];
    let mut out_count = vec![0usize; n];
    for (id, deps) in plan.deps.iter().enumerate() {
        for &d in deps.as_slice() {
            if d < n {
                indeg[id] += 1;
                out_count[d] += 1;
            }
        }
    }
    let mut start = vec![0usize; n + 1];
    for i in 0..n {
        start[i + 1] = start[i] + out_count[i];
    }
    let mut adj = vec![0usize; start[n]];
    let mut cursor = start.clone();
    for (id, deps) in plan.deps.iter().enumerate() {
        for &d in deps.as_slice() {
            if d < n {
                adj[cursor[d]] = id;
                cursor[d] += 1;
            }
        }
    }
    (indeg, start, adj)
}

/// Completion depth of every op under the dependency partial order:
/// `done_depth(i) = 1 + max(done_depth(d) for d in deps(i))`, 1 for
/// dep-free ops. `None` if the plan is cyclic or has out-of-range deps
/// (callers diagnose those via [`check`] first). The dataflow replay
/// linearizes edge events on these depths.
pub(super) fn done_depths(plan: &Plan) -> Option<Vec<u32>> {
    let n = plan.len();
    for deps in plan.deps.iter() {
        if deps.as_slice().iter().any(|&d| d >= n) {
            return None;
        }
    }
    let (indeg, start, adj) = adjacency(plan, n);
    let mut indeg = indeg;
    let mut depth = vec![1u32; n];
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut processed = 0usize;
    while let Some(i) = ready.pop() {
        processed += 1;
        for &j in &adj[start[i]..start[i + 1]] {
            depth[j] = depth[j].max(depth[i] + 1);
            indeg[j] -= 1;
            if indeg[j] == 0 {
                ready.push(j);
            }
        }
    }
    if processed < n {
        return None;
    }
    Some(depth)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Deps, SimOp};
    use crate::topology::DeviceId;

    fn delay_plan(n: usize) -> Plan {
        let mut p = Plan::new();
        for i in 0..n {
            let deps = if i == 0 { Deps::none() } else { Deps::one(i - 1) };
            p.push(
                SimOp::Delay {
                    dev: DeviceId(0),
                    dur_ns: 1,
                },
                deps,
                None,
            );
        }
        p
    }

    #[test]
    fn clean_chain_is_sound() {
        let p = delay_plan(4);
        let mut diags = Vec::new();
        assert!(check(&p, &mut diags));
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(done_depths(&p).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn cycle_found() {
        let mut p = delay_plan(3);
        p.deps[0] = Deps::one(2); // 0 -> 2 -> 1 -> 0
        let mut diags = Vec::new();
        assert!(!check(&p, &mut diags));
        assert!(diags.iter().any(|d| d.code == Code::Cycle), "{diags:?}");
        assert!(done_depths(&p).is_none());
    }

    #[test]
    fn dangling_and_self_deps_found() {
        let mut p = delay_plan(2);
        p.deps[1] = Deps::two(5, 1);
        let mut diags = Vec::new();
        assert!(!check(&p, &mut diags));
        let codes: Vec<Code> = diags.iter().map(|d| d.code).collect();
        assert!(codes.contains(&Code::DanglingDep), "{diags:?}");
        assert!(codes.contains(&Code::SelfDep), "{diags:?}");
    }

    #[test]
    fn malformed_delay_found() {
        let mut p = delay_plan(2);
        p.bytes[1] = 42;
        let mut diags = Vec::new();
        assert!(!check(&p, &mut diags));
        assert!(
            diags.iter().any(|d| d.code == Code::MalformedDelay && d.op == Some(1)),
            "{diags:?}"
        );
    }

    #[test]
    fn depths_join_at_the_widest_dep() {
        let mut p = delay_plan(3); // 0 -> 1 -> 2
        p.push(
            SimOp::Delay {
                dev: DeviceId(0),
                dur_ns: 1,
            },
            Deps::two(0, 2),
            None,
        );
        assert_eq!(done_depths(&p).unwrap(), vec![1, 2, 3, 4]);
    }
}
