//! Route liveness: every transfer's `RouteId` must resolve against the
//! *current* topology, traverse no dead links, and terminate on devices
//! that are still part of the job — the checks that catch a stale plan
//! template surviving a `kill_link`/`retain_ranks` mutation.

use crate::netsim::{OpEnd, Plan};
use crate::topology::{Cluster, DeviceKind};

use super::diag::{Code, Diag};

pub(super) fn check(cluster: &Cluster, plan: &Plan, diags: &mut Vec<Diag>) {
    let scan_dead_links = cluster.n_dead_links() > 0;
    // Hop-chain contiguity only needs proving when routes can come from
    // an algebraic resolver — BFS routes are contiguous by construction
    // (each hop extends the frontier), so the healthy BFS-only case
    // skips the walk entirely.
    let scan_contiguity = cluster.has_algebraic_resolver();
    // endpoint aliveness only matters once the rank set and the GPU set
    // can disagree (retain_ranks leaves dead GPUs in the device list) or
    // links have been killed; the common healthy case skips the scan
    let n_rank_gpus = cluster.gpu_ranks().len();
    let n_gpus = cluster
        .devices()
        .iter()
        .filter(|d| d.kind == DeviceKind::Gpu)
        .count();
    let scan_endpoints = scan_dead_links || n_rank_gpus != n_gpus;
    let mut is_rank = Vec::new();
    if scan_endpoints {
        is_rank = vec![false; cluster.devices().len()];
        for &d in cluster.gpu_ranks() {
            is_rank[d.0] = true;
        }
    }

    for (id, end) in plan.ends.iter().enumerate() {
        let OpEnd::Route(route) = *end else { continue };
        if !cluster.route_current(route) {
            diags.push(Diag::at(
                Code::StaleRoute,
                id,
                format!(
                    "RouteId interned under an older topology generation \
                     (cluster is now at generation {})",
                    cluster.generation()
                ),
            ));
            continue;
        }
        if scan_dead_links {
            let hops = cluster.route_hops(route);
            for &h in hops.iter() {
                if !cluster.link_alive(h) {
                    diags.push(Diag::at(
                        Code::DeadLink,
                        id,
                        format!("route traverses dead link {}", h.0),
                    ));
                }
            }
        }
        if scan_contiguity {
            let meta = cluster.route_meta(route);
            let mut at = meta.src;
            let mut broken = None;
            {
                let hops = cluster.route_hops(route);
                for (k, &h) in hops.iter().enumerate() {
                    let link = cluster.link(h);
                    if link.src != at {
                        broken = Some(format!(
                            "hop {k} (link {}) departs device {} but the \
                             path is at device {}",
                            h.0, link.src.0, at.0
                        ));
                        break;
                    }
                    at = link.dst;
                }
            }
            if broken.is_none() && at != meta.dst {
                broken = Some(format!(
                    "path ends at device {} instead of the declared \
                     destination {}",
                    at.0, meta.dst.0
                ));
            }
            if let Some(msg) = broken {
                diags.push(Diag::at(Code::BrokenPath, id, msg));
            }
        }
        if scan_endpoints {
            let meta = cluster.route_meta(route);
            for (which, dev) in [("source", meta.src), ("destination", meta.dst)] {
                if cluster.device(dev).kind == DeviceKind::Gpu && !is_rank[dev.0] {
                    diags.push(Diag::at(
                        Code::DeadEndpoint,
                        id,
                        format!(
                            "route {which} GPU {} is not a rank of the current job",
                            dev.0
                        ),
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{chain, BcastSpec};
    use crate::comm::Comm;
    use crate::topology::presets::{flat, kesch};

    #[test]
    fn fresh_plan_is_clean() {
        let c = kesch(2, 4).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, 8, 1 << 20));
        let mut diags = Vec::new();
        check(&c, &bp.plan, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn stale_route_flagged_after_kill_link() {
        let mut c = flat(4).unwrap();
        let bp = {
            let mut comm = Comm::new(&c);
            chain::plan(&mut comm, &BcastSpec::new(0, 4, 1 << 20))
        };
        let victim = c.links()[0].id;
        c.kill_link(victim).unwrap();
        let mut diags = Vec::new();
        check(&c, &bp.plan, &mut diags);
        assert!(
            diags.iter().any(|d| d.code == Code::StaleRoute),
            "{diags:?}"
        );
    }

    #[test]
    fn algebraic_routes_pass_the_contiguity_scan() {
        // fat-tree installs an algebraic resolver, so every route in the
        // plan goes through the PL017 hop-chain walk — and must be a
        // contiguous src→dst path
        let c = crate::topology::presets::fat_tree(2, 2, 2, 2, 2).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, c.n_gpus(), 1 << 20));
        let mut diags = Vec::new();
        check(&c, &bp.plan, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn broken_hop_chain_flagged_as_pl017() {
        use crate::netsim::{Deps, Plan, SimOp};
        let c = crate::topology::presets::fat_tree(2, 2, 2, 2, 2).unwrap();
        let (a, b) = (c.rank_device(0), c.rank_device(1));
        let good = c.route(a, b).unwrap();
        // drop the final hop: the chain now ends on the leaf switch
        // instead of the declared destination GPU
        let truncated: Vec<_> = {
            let hops = c.route_hops(good);
            hops[..hops.len() - 1].to_vec()
        };
        let broken = c.intern_raw_route_for_test(a, b, &truncated);
        let mut plan = Plan::new();
        plan.push(
            SimOp::Transfer {
                route: broken,
                bytes: 1 << 20,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::none(),
            None,
        );
        let mut diags = Vec::new();
        check(&c, &plan, &mut diags);
        assert!(
            diags.iter().any(|d| d.code == Code::BrokenPath),
            "{diags:?}"
        );
    }

    #[test]
    fn rebuilt_plan_after_kill_is_clean() {
        // kill one FDR rail of the dual-rail kesch node; the sibling
        // socket's rail keeps every rank reachable, so a plan rebuilt on
        // the mutated topology must verify clean
        let mut c = kesch(2, 8).unwrap();
        let cross = c.route(c.rank_device(7), c.rank_device(8)).unwrap();
        let rail = *c
            .route_view(cross)
            .hops
            .iter()
            .find(|&&h| c.link(h).kind == crate::topology::LinkKind::IbFdr)
            .expect("cross-node route crosses an FDR rail");
        c.kill_link(rail).unwrap();
        let mut comm = Comm::new(&c);
        let bp = chain::plan(&mut comm, &BcastSpec::new(0, 16, 1 << 20));
        let mut diags = Vec::new();
        check(&c, &bp.plan, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }
}
