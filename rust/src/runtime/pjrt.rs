//! PJRT client wrapper.
//!
//! The real client binds the `xla` crate's PJRT CPU runtime. That crate
//! is unavailable in the offline build, so it is gated behind the `pjrt`
//! cargo feature *and* the `pjrt_vendored` cfg that build.rs emits only
//! once `vendor/xla` exists — `--all-features` builds stay compilable
//! before the crate is vendored. Every other configuration ships a stub
//! with the same surface that returns a friendly error, keeping the
//! rest of the crate — and the tests that skip when artifacts are
//! missing — fully buildable.

#[cfg(all(feature = "pjrt", pjrt_vendored))]
mod real {
    use std::path::Path;

    use crate::error::{Error, Result};

    impl From<xla::Error> for Error {
        fn from(e: xla::Error) -> Self {
            Error::Xla(e.to_string())
        }
    }

    /// A PJRT CPU runtime bound to one process.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create the CPU client.
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime {
                client: xla::PjRtClient::cpu()?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "artifact {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Executable { exe })
        }
    }

    /// A compiled computation.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with f32 tensor inputs `(data, dims)`; expects the
        /// program to return a 1-tuple of a single f32 array (the aot.py
        /// convention) and returns it flattened.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = xla::Literal::vec1(data);
                    if dims.is_empty() {
                        // scalar
                        lit.reshape(&[]).map_err(Error::from)
                    } else {
                        lit.reshape(dims).map_err(Error::from)
                    }
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let literal = result[0][0].to_literal_sync()?;
            let out = literal.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }
}

#[cfg(not(all(feature = "pjrt", pjrt_vendored)))]
mod stub {
    use std::path::Path;

    use crate::error::{Error, Result};

    fn unavailable() -> Error {
        Error::Runtime(
            "built without the `pjrt` feature (the offline build has no `xla` \
             crate); vendor it and rebuild with `--features pjrt`"
                .into(),
        )
    }

    /// Stub runtime: mirrors the real client's API, constructor always
    /// errors.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".into()
        }

        pub fn device_count(&self) -> usize {
            0
        }

        pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
            Err(unavailable())
        }
    }

    /// Stub executable: never constructible via the stub runtime.
    pub struct Executable {
        _private: (),
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(unavailable())
        }
    }
}

#[cfg(all(feature = "pjrt", pjrt_vendored))]
pub use real::{Executable, Runtime};
#[cfg(not(all(feature = "pjrt", pjrt_vendored)))]
pub use stub::{Executable, Runtime};

#[cfg(all(test, feature = "pjrt", pjrt_vendored))]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_friendly_error() {
        let rt = Runtime::cpu().unwrap();
        let err = match rt.load_hlo_text(Path::new("/nonexistent/nope.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}

#[cfg(all(test, not(all(feature = "pjrt", pjrt_vendored))))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = match Runtime::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not construct"),
        };
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
