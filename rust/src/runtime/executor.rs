//! Typed training-step execution + the PJRT-backed compute worker.

use crate::coordinator::worker::ComputeBackend;
use crate::error::Result;
use crate::util::rng::Rng;

use super::artifacts::Artifacts;
use super::pjrt::{Executable, Runtime};

/// The AOT-compiled training step:
///
/// `train_step(flat_params[P], x[B,D], y[B,C], lr[]) ->
///      (concat(new_flat_params, [loss]),)`
///
/// (single flat f32 output so the rust side needs no pytree machinery —
/// and flat parameters are exactly what the CNTK-style broadcast
/// partitioning wants).
pub struct TrainStep {
    exe: Executable,
    pub n_params: usize,
    pub batch: usize,
    pub input_dim: usize,
    pub classes: usize,
}

impl TrainStep {
    /// Load from the artifact bundle.
    pub fn load(rt: &Runtime, artifacts: &Artifacts) -> Result<TrainStep> {
        let exe = rt.load_hlo_text(&artifacts.train_step_path())?;
        Ok(TrainStep {
            exe,
            n_params: artifacts.meta.n_params,
            batch: artifacts.meta.batch,
            input_dim: artifacts.meta.input_dim,
            classes: artifacts.meta.classes,
        })
    }

    /// Run one SGD step; returns (new_params, loss).
    pub fn step(
        &self,
        params: &[f32],
        x: &[f32],
        y_onehot: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        assert_eq!(params.len(), self.n_params, "param length mismatch");
        assert_eq!(x.len(), self.batch * self.input_dim, "x shape mismatch");
        assert_eq!(y_onehot.len(), self.batch * self.classes, "y shape mismatch");
        let lr_arr = [lr];
        let out = self.exe.run_f32(&[
            (params, &[self.n_params as i64]),
            (x, &[self.batch as i64, self.input_dim as i64]),
            (y_onehot, &[self.batch as i64, self.classes as i64]),
            (&lr_arr, &[1]),
        ])?;
        debug_assert_eq!(out.len(), self.n_params + 1);
        let loss = out[self.n_params];
        let mut new_params = out;
        new_params.truncate(self.n_params);
        Ok((new_params, loss))
    }
}

/// A data-parallel worker backed by the PJRT training step, holding a
/// fixed synthetic shard (random inputs labelled by a shared random
/// linear teacher — a learnable classification task). Each iteration is
/// one full pass over the worker's shard, i.e. classic epoch-style
/// data-parallel SGD.
pub struct PjrtWorker<'a> {
    step: &'a TrainStep,
    x: Vec<f32>,
    y: Vec<f32>,
}

impl<'a> PjrtWorker<'a> {
    pub fn new(step: &'a TrainStep, shard_seed: u64, teacher_seed: u64) -> PjrtWorker<'a> {
        let mut trng = Rng::new(teacher_seed);
        let teacher: Vec<f32> = (0..step.input_dim * step.classes)
            .map(|_| (trng.next_f64() as f32 - 0.5) * 2.0)
            .collect();
        let (b, d, c) = (step.batch, step.input_dim, step.classes);
        let mut rng = Rng::new(shard_seed);
        let mut x = Vec::with_capacity(b * d);
        for _ in 0..b * d {
            x.push((rng.next_f64() as f32 - 0.5) * 2.0);
        }
        let mut y = vec![0.0f32; b * c];
        for i in 0..b {
            // teacher logits -> argmax label
            let mut best = 0usize;
            let mut best_v = f32::MIN;
            for j in 0..c {
                let mut v = 0.0f32;
                for k in 0..d {
                    v += x[i * d + k] * teacher[k * c + j];
                }
                if v > best_v {
                    best_v = v;
                    best = j;
                }
            }
            y[i * c + best] = 1.0;
        }
        PjrtWorker { step, x, y }
    }

    /// This worker's shard.
    pub fn batch(&self) -> (&[f32], &[f32]) {
        (&self.x, &self.y)
    }
}

impl<'a> ComputeBackend for PjrtWorker<'a> {
    fn grad(&mut self, params: &[f32], _iter: u64) -> (Vec<f32>, f32) {
        // The AOT step applies the update itself (donated-style); recover
        // the gradient as (old - new)/lr so the leader can average shards.
        const LR: f32 = 0.05;
        let (new_params, loss) = self
            .step
            .step(params, &self.x, &self.y, LR)
            .expect("train step execution");
        let grad: Vec<f32> = params
            .iter()
            .zip(&new_params)
            .map(|(o, n)| (o - n) / LR)
            .collect();
        (grad, loss)
    }

    fn n_params(&self) -> usize {
        self.step.n_params
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent integration tests live in rust/tests/e2e_runtime.rs
    // (they need `make artifacts`); here we only test the synthetic batch
    // generator's label validity via a stub-shaped worker… which itself
    // needs a TrainStep. Covered end-to-end instead.
}
