//! The PJRT runtime: load and execute the AOT-compiled JAX/Pallas
//! training step from `artifacts/`.
//!
//! Python runs only at `make artifacts` time (`python/compile/aot.py`
//! lowers the L2 model — which calls the L1 Pallas kernels — to HLO
//! *text*; see /opt/xla-example's gotcha list for why text, not proto).
//! This module is the request-path side: a thin, typed wrapper over the
//! `xla` crate's PJRT CPU client.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Artifacts};
pub use executor::{PjrtWorker, TrainStep};
pub use pjrt::Runtime;
