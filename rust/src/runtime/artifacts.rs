//! Artifact discovery: `artifacts/` layout and `meta.json`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Metadata emitted by `python/compile/aot.py` alongside the HLO text.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Flattened parameter count P.
    pub n_params: usize,
    /// Batch size the step was lowered for.
    pub batch: usize,
    /// Input feature dimension.
    pub input_dim: usize,
    /// Output classes.
    pub classes: usize,
    /// Layer boundary offsets into the flat parameter vector
    /// (name, offset, len) — the CNTK-style partition points.
    pub layout: Vec<(String, usize, usize)>,
}

/// The artifact bundle.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: ArtifactMeta,
}

impl Artifacts {
    /// Locate artifacts: `$GDRBCAST_ARTIFACTS` or `./artifacts`.
    pub fn discover() -> Result<Artifacts> {
        let dir = std::env::var("GDRBCAST_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        Artifacts::open(&dir)
    }

    pub fn open(dir: &Path) -> Result<Artifacts> {
        let meta_path = dir.join("meta.json");
        if !meta_path.exists() {
            return Err(Error::Runtime(format!(
                "{} not found — run `make artifacts` first",
                meta_path.display()
            )));
        }
        let text = std::fs::read_to_string(&meta_path)?;
        let meta = parse_meta(&text)?;
        Ok(Artifacts {
            dir: dir.to_path_buf(),
            meta,
        })
    }

    /// Path of the training-step HLO text.
    pub fn train_step_path(&self) -> PathBuf {
        self.dir.join("train_step.hlo.txt")
    }

    /// Path of the forward-only (predict) HLO text.
    pub fn predict_path(&self) -> PathBuf {
        self.dir.join("predict.hlo.txt")
    }
}

fn parse_meta(text: &str) -> Result<ArtifactMeta> {
    let j = Json::parse(text)?;
    let get_usize = |key: &str| -> Result<usize> {
        j.get(key)
            .and_then(|v| v.as_u64())
            .map(|v| v as usize)
            .ok_or_else(|| Error::Runtime(format!("meta.json missing '{key}'")))
    };
    let mut layout = Vec::new();
    if let Some(arr) = j.get("layout").and_then(|v| v.as_arr()) {
        for item in arr {
            let name = item
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("param")
                .to_string();
            let offset = item.get("offset").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
            let len = item.get("len").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
            layout.push((name, offset, len));
        }
    }
    Ok(ArtifactMeta {
        n_params: get_usize("n_params")?,
        batch: get_usize("batch")?,
        input_dim: get_usize("input_dim")?,
        classes: get_usize("classes")?,
        layout,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta() {
        let text = r#"{
            "n_params": 1707274, "batch": 64, "input_dim": 3072,
            "classes": 10,
            "layout": [
                {"name": "fc1.w", "offset": 0, "len": 1572864},
                {"name": "fc1.b", "offset": 1572864, "len": 512}
            ]
        }"#;
        let meta = parse_meta(text).unwrap();
        assert_eq!(meta.n_params, 1_707_274);
        assert_eq!(meta.batch, 64);
        assert_eq!(meta.layout.len(), 2);
        assert_eq!(meta.layout[1].1, 1_572_864);
    }

    #[test]
    fn missing_field_errors() {
        assert!(parse_meta(r#"{"batch": 4}"#).is_err());
    }

    #[test]
    fn missing_dir_is_friendly() {
        let err = Artifacts::open(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
