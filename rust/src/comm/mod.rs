//! The CUDA-aware point-to-point engine.
//!
//! A CUDA-aware MPI runtime's collective performance is dominated by
//! *which mechanism* each point-to-point transfer uses (§II-C of the
//! paper): CUDA IPC under a PLX switch, GDR writes over IB, SGL-based
//! eager sends for small internode messages, host staging where direct
//! paths hit hardware bottlenecks (the GDR-read-across-QPI problem of
//! ref. [26]). This module reproduces that mechanism menu and the
//! selection logic, emitting [`crate::netsim`] ops.
//!
//! [`Comm::send`] is the rank-to-rank primitive used by every collective
//! algorithm in [`crate::collectives`].

pub mod chunk;
pub mod p2p;
pub mod protocol;

pub use chunk::chunk_sizes;
pub use p2p::Comm;
pub use protocol::{CommParams, Mechanism, PathPlan};
