//! Message chunking for pipelined transfers.
//!
//! Both splitters guard their degenerate inputs explicitly — `chunk == 0`,
//! `chunk > total`, `total == 0`, `parts == 0` — instead of panicking on
//! a division by zero or handing back surprise shapes: callers range over
//! tuning grids and CLI inputs where the degenerate corners are reachable.

/// Split `total` bytes into chunks of at most `chunk` bytes (last chunk
/// carries the remainder). Degenerate inputs collapse to a single slot:
/// `chunk == 0` or `chunk >= total` yields one chunk of `total`, and
/// `total == 0` one empty chunk (so a plan always has at least one slot
/// per message).
pub fn chunk_sizes(total: u64, chunk: u64) -> Vec<u64> {
    if total == 0 {
        return vec![0];
    }
    if chunk == 0 || chunk >= total {
        return vec![total];
    }
    let full = (total / chunk) as usize;
    let rem = total % chunk;
    let mut out = vec![chunk; full];
    if rem > 0 {
        out.push(rem);
    }
    out
}

/// Split `total` into exactly `parts` near-equal pieces (scatter-allgather
/// partitioning). Earlier parts get the extra bytes. `parts == 0` is a
/// zero-part split: no pieces at all (and therefore no bytes) — not a
/// panic. `netsim::ByteRole::Part` mirrors this (a part of a zero-part
/// split is 0 bytes).
pub fn equal_parts(total: u64, parts: usize) -> Vec<u64> {
    if parts == 0 {
        return Vec::new();
    }
    let base = total / parts as u64;
    let extra = (total % parts as u64) as usize;
    (0..parts)
        .map(|i| base + if i < extra { 1 } else { 0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, shrink_u64, Config};

    #[test]
    fn chunks_cover_total() {
        for (total, chunk) in [(100u64, 30u64), (1 << 20, 64 << 10), (7, 7), (7, 100), (5, 0)] {
            let cs = chunk_sizes(total, chunk);
            assert_eq!(cs.iter().sum::<u64>(), total);
            if chunk > 0 {
                assert!(cs.iter().all(|&c| c <= chunk.max(total)));
            }
        }
    }

    #[test]
    fn zero_total_one_empty_chunk() {
        assert_eq!(chunk_sizes(0, 64), vec![0]);
    }

    #[test]
    fn degenerate_inputs_are_guarded() {
        // chunk == 0 -> one whole-message chunk, no div-by-zero
        assert_eq!(chunk_sizes(5, 0), vec![5]);
        // chunk > total -> one chunk
        assert_eq!(chunk_sizes(7, 100), vec![7]);
        // parts == 0 -> a zero-part split has no pieces, no panic
        assert_eq!(equal_parts(10, 0), Vec::<u64>::new());
        assert_eq!(equal_parts(0, 0), Vec::<u64>::new());
        // total == 0 still yields the requested number of (empty) parts
        assert_eq!(equal_parts(0, 3), vec![0, 0, 0]);
    }

    #[test]
    fn equal_parts_cover_and_balance() {
        let ps = equal_parts(10, 3);
        assert_eq!(ps, vec![4, 3, 3]);
        assert_eq!(ps.iter().sum::<u64>(), 10);
        let ps = equal_parts(0, 4);
        assert_eq!(ps.iter().sum::<u64>(), 0);
    }

    #[test]
    fn exact_division() {
        assert_eq!(chunk_sizes(1 << 20, 256 << 10).len(), 4);
        assert_eq!(equal_parts(1 << 20, 4), vec![256 << 10; 4]);
    }

    #[test]
    fn prop_chunk_sizes_total_and_shape() {
        // randomized totals/chunks including the degenerate corners:
        // coverage, per-chunk bound, and only-the-last-chunk-short
        check(
            Config::default().cases(256),
            "chunk-sizes-invariants",
            |rng| (rng.range_u64(0, 1 << 24), rng.range_u64(0, 1 << 22)),
            |&(total, chunk)| {
                let cs = chunk_sizes(total, chunk);
                if cs.iter().sum::<u64>() != total {
                    return Err(format!("sum {} != total {total}", cs.iter().sum::<u64>()));
                }
                if cs.is_empty() {
                    return Err("no slots".into());
                }
                if chunk == 0 || chunk >= total {
                    // degenerate corner: exactly one whole-message slot
                    if cs != vec![total] {
                        return Err(format!("degenerate input not one slot: {cs:?}"));
                    }
                } else {
                    // all slots but the last are exactly C; the remainder
                    // slot is short but never empty
                    if cs[..cs.len() - 1].iter().any(|&c| c != chunk) {
                        return Err(format!("non-final slot differs from C in {cs:?}"));
                    }
                    let last = *cs.last().unwrap();
                    if last == 0 || last > chunk {
                        return Err(format!("bad remainder slot {last} in {cs:?}"));
                    }
                }
                Ok(())
            },
            |&(t, c)| {
                let mut out = Vec::new();
                for st in shrink_u64(t, 0) {
                    out.push((st, c));
                }
                for sc in shrink_u64(c, 0) {
                    out.push((t, sc));
                }
                out
            },
        );
    }

    #[test]
    fn prop_equal_parts_total_count_balance() {
        check(
            Config::default().cases(256),
            "equal-parts-invariants",
            |rng| (rng.range_u64(0, 1 << 24), rng.range_usize(0, 64)),
            |&(total, parts)| {
                let ps = equal_parts(total, parts);
                if ps.len() != parts {
                    return Err(format!("{} parts, wanted {parts}", ps.len()));
                }
                if parts == 0 {
                    return Ok(()); // zero-part split: nothing else to hold
                }
                if ps.iter().sum::<u64>() != total {
                    return Err(format!("sum {} != total {total}", ps.iter().sum::<u64>()));
                }
                let (max, min) = (ps.iter().max().unwrap(), ps.iter().min().unwrap());
                if max - min > 1 {
                    return Err(format!("imbalance {max}-{min} in {ps:?}"));
                }
                if !ps.windows(2).all(|w| w[0] >= w[1]) {
                    return Err(format!("extra bytes not front-loaded: {ps:?}"));
                }
                Ok(())
            },
            |&(t, p)| {
                let mut out = Vec::new();
                for st in shrink_u64(t, 0) {
                    out.push((st, p));
                }
                if p > 0 {
                    out.push((t, p - 1));
                }
                out
            },
        );
    }
}
