//! Message chunking for pipelined transfers.

/// Split `total` bytes into chunks of at most `chunk` bytes (last chunk
/// carries the remainder). `chunk == 0` or `chunk >= total` yields one
/// chunk.
pub fn chunk_sizes(total: u64, chunk: u64) -> Vec<u64> {
    if total == 0 {
        return vec![0];
    }
    if chunk == 0 || chunk >= total {
        return vec![total];
    }
    let full = (total / chunk) as usize;
    let rem = total % chunk;
    let mut out = vec![chunk; full];
    if rem > 0 {
        out.push(rem);
    }
    out
}

/// Split `total` into exactly `parts` near-equal pieces (scatter-allgather
/// partitioning). Earlier parts get the extra bytes.
pub fn equal_parts(total: u64, parts: usize) -> Vec<u64> {
    assert!(parts > 0);
    let base = total / parts as u64;
    let extra = (total % parts as u64) as usize;
    (0..parts)
        .map(|i| base + if i < extra { 1 } else { 0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_total() {
        for (total, chunk) in [(100u64, 30u64), (1 << 20, 64 << 10), (7, 7), (7, 100), (5, 0)] {
            let cs = chunk_sizes(total, chunk);
            assert_eq!(cs.iter().sum::<u64>(), total);
            if chunk > 0 {
                assert!(cs.iter().all(|&c| c <= chunk.max(total)));
            }
        }
    }

    #[test]
    fn zero_total_one_empty_chunk() {
        assert_eq!(chunk_sizes(0, 64), vec![0]);
    }

    #[test]
    fn equal_parts_cover_and_balance() {
        let ps = equal_parts(10, 3);
        assert_eq!(ps, vec![4, 3, 3]);
        assert_eq!(ps.iter().sum::<u64>(), 10);
        let ps = equal_parts(0, 4);
        assert_eq!(ps.iter().sum::<u64>(), 0);
    }

    #[test]
    fn exact_division() {
        assert_eq!(chunk_sizes(1 << 20, 256 << 10).len(), 4);
        assert_eq!(equal_parts(1 << 20, 4), vec![256 << 10; 4]);
    }
}
