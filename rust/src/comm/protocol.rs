//! Mechanism selection: which hardware path a transfer takes.

use crate::topology::{Cluster, DeviceId, RouteId};

/// The transfer mechanisms of a CUDA-aware MPI runtime (MVAPICH2-GDR's
/// menu, §II-C / §IV-C of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mechanism {
    /// Intranode GPU↔GPU DMA through the PCIe/NVLink fabric (requires
    /// peer access). Pipelined for large messages.
    CudaIpc,
    /// Direct GDR read across the socket boundary — available but slow
    /// (the [26] bottleneck); modelled with a hard bandwidth cap.
    GdrReadCrossSocket,
    /// Bounce through host memory (D2H, then H2D / host-side hop).
    HostStaged,
    /// Internode small-message eager path using IB Scatter-Gather lists +
    /// GDR write (ref. [29]) — excellent small-message latency.
    SglEagerGdr,
    /// Internode rendezvous with pipelined GDR — full IB bandwidth for
    /// large messages.
    RndvGdrPipelined,
}

impl Mechanism {
    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::CudaIpc => "cuda-ipc",
            Mechanism::GdrReadCrossSocket => "gdr-read",
            Mechanism::HostStaged => "host-staged",
            Mechanism::SglEagerGdr => "sgl-eager",
            Mechanism::RndvGdrPipelined => "rndv-gdr",
        }
    }
}

/// Calibrated protocol constants. See DESIGN.md §4 — these encode
/// published latency/bandwidth characteristics of the mechanisms, not
/// fitted curves.
#[derive(Debug, Clone)]
pub struct CommParams {
    /// CUDA IPC per-transfer startup (handle cache hit), ns.
    pub ipc_overhead_ns: u64,
    /// GDR-write / SGL-eager internode startup, ns.
    pub eager_overhead_ns: u64,
    /// Rendezvous (RTS/CTS + pipelining setup) startup, ns.
    pub rndv_overhead_ns: u64,
    /// Host-staging per-copy startup (cudaMemcpy D2H/H2D launch), ns.
    pub staging_copy_overhead_ns: u64,
    /// Eager/rendezvous switchover (MVAPICH2 default for GPU buffers).
    pub eager_threshold: u64,
    /// Effective ceiling for GDR reads crossing the socket boundary
    /// (bytes/s) — the ref. [26] bottleneck.
    pub gdr_read_cap: f64,
    /// Message sizes at or below this stage through the host intranode
    /// when peer access is unavailable (instead of capped GDR read).
    pub staging_preferred_below: u64,
}

impl Default for CommParams {
    fn default() -> Self {
        CommParams {
            ipc_overhead_ns: 1_900,
            eager_overhead_ns: 2_300,
            rndv_overhead_ns: 5_500,
            staging_copy_overhead_ns: 1_200,
            eager_threshold: 16 << 10,
            gdr_read_cap: 2.2e9,
            staging_preferred_below: 4 << 20,
        }
    }
}

/// Mechanism size class of a byte count: bit 0 set above the
/// eager/rendezvous switchover, bit 1 set above the intranode
/// staging-preference bound. [`select`]'s branching is a pure function
/// of the class (it is evaluated at [`class_representative`]), which is
/// what lets path-plan caches, plan templates and the parallel tuner
/// share state without becoming visit-order dependent.
pub fn size_class(params: &CommParams, bytes: u64) -> u8 {
    let mut class = 0u8;
    if bytes > params.eager_threshold {
        class |= 1;
    }
    if bytes > params.staging_preferred_below {
        class |= 2;
    }
    class
}

/// The canonical byte count [`select`] evaluates for a class — the
/// smallest size in it. Selection outcomes must not vary within a class
/// (the threshold branches cannot by construction; the cross-socket
/// staged-vs-GDR-read estimate comparison does not in practice because
/// staging both starts ahead at the class floor and scales with a
/// shallower slope — guarded by the template golden-parity suite).
pub fn class_representative(params: &CommParams, class: u8) -> u64 {
    let mut rep = 1u64;
    if class & 1 != 0 {
        rep = params.eager_threshold + 1;
    }
    if class & 2 != 0 {
        rep = rep.max(params.staging_preferred_below + 1);
    }
    rep
}

/// A resolved transfer recipe between two devices. Routes are interned
/// ids, so the whole recipe is `Copy` — the per-send cache hit on
/// [`super::p2p::Comm`] no longer clones hop vectors (DESIGN.md §Perf).
#[derive(Debug, Clone, Copy)]
pub enum PathPlan {
    /// One cut-through transfer.
    Direct {
        mechanism: Mechanism,
        route: RouteId,
        overhead_ns: u64,
        bw_cap: Option<f64>,
    },
    /// Two chained transfers through an intermediate (host staging).
    Staged {
        mechanism: Mechanism,
        first: RouteId,
        second: RouteId,
        overhead_each_ns: u64,
    },
}

impl PathPlan {
    /// Uncontended end-to-end estimate, ns — used by the tuning framework
    /// and by selection itself. Takes the cluster whose table interned the
    /// routes.
    pub fn estimate_ns(&self, cluster: &Cluster, bytes: u64) -> u64 {
        match self {
            PathPlan::Direct {
                route,
                overhead_ns,
                bw_cap,
                ..
            } => {
                let meta = cluster.route_meta(*route);
                let bw = bw_cap
                    .map(|c| meta.bottleneck_bw.min(c))
                    .unwrap_or(meta.bottleneck_bw);
                overhead_ns + meta.latency_ns + crate::netsim::time::tx_ns(bytes, bw)
            }
            PathPlan::Staged {
                first,
                second,
                overhead_each_ns,
                ..
            } => {
                cluster.route_uncontended_ns(*first, bytes)
                    + cluster.route_uncontended_ns(*second, bytes)
                    + 2 * overhead_each_ns
            }
        }
    }

    pub fn mechanism(&self) -> Mechanism {
        match self {
            PathPlan::Direct { mechanism, .. } => *mechanism,
            PathPlan::Staged { mechanism, .. } => *mechanism,
        }
    }
}

/// Decide the mechanism for a GPU→GPU transfer of `bytes`.
///
/// This is the selection logic that gives MVAPICH2-GDR its small/medium
/// message advantage: peer-access IPC when possible, host-staging as the
/// cross-socket workaround, SGL eager vs pipelined rendezvous internode.
pub fn select(
    cluster: &Cluster,
    params: &CommParams,
    src: DeviceId,
    dst: DeviceId,
    bytes: u64,
) -> PathPlan {
    assert_ne!(src, dst, "p2p transfer to self");
    if cluster.same_node(src, dst) {
        if cluster.peer_access(src, dst) {
            let route = cluster.route(src, dst).expect("intranode route");
            return PathPlan::Direct {
                mechanism: Mechanism::CudaIpc,
                route,
                overhead_ns: params.ipc_overhead_ns,
                bw_cap: None,
            };
        }
        // cross-socket: staged vs capped GDR read — pick the cheaper
        let src_host = cluster.staging_host(src).expect("src host");
        let first = cluster.route(src, src_host).expect("d2h route");
        let second = cluster.route(src_host, dst).expect("h2d route");
        let staged = PathPlan::Staged {
            mechanism: Mechanism::HostStaged,
            first,
            second,
            overhead_each_ns: params.staging_copy_overhead_ns,
        };
        let direct_route = cluster.route(src, dst).expect("intranode route");
        let direct = PathPlan::Direct {
            mechanism: Mechanism::GdrReadCrossSocket,
            route: direct_route,
            overhead_ns: params.ipc_overhead_ns,
            bw_cap: Some(params.gdr_read_cap),
        };
        return if bytes <= params.staging_preferred_below
            || staged.estimate_ns(cluster, bytes) <= direct.estimate_ns(cluster, bytes)
        {
            staged
        } else {
            direct
        };
    }
    // internode
    let route = cluster.route(src, dst).expect("internode route");
    if bytes <= params.eager_threshold {
        PathPlan::Direct {
            mechanism: Mechanism::SglEagerGdr,
            route,
            overhead_ns: params.eager_overhead_ns,
            bw_cap: None,
        }
    } else {
        PathPlan::Direct {
            mechanism: Mechanism::RndvGdrPipelined,
            route,
            overhead_ns: params.rndv_overhead_ns,
            bw_cap: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    #[test]
    fn class_representative_is_a_class_member() {
        let p = CommParams::default();
        for bytes in [
            1u64,
            4,
            16 << 10,
            (16 << 10) + 1,
            4 << 20,
            (4 << 20) + 1,
            256 << 20,
        ] {
            let class = size_class(&p, bytes);
            assert_eq!(
                size_class(&p, class_representative(&p, class)),
                class,
                "representative left its class at {bytes}B"
            );
        }
    }

    #[test]
    fn selection_is_constant_within_a_class() {
        // the assumption canonical path-plan resolution (and therefore
        // plan-template rescaling) rests on: any two byte values in one
        // size class resolve to the same mechanism for every pair
        let c = kesch(2, 16).unwrap();
        let p = CommParams::default();
        let pairs = [(0usize, 1usize), (0, 8), (0, 16)];
        let groups: [&[u64]; 3] = [
            &[1, 512, 16 << 10],                // class 0
            &[(16 << 10) + 1, 1 << 20, 4 << 20], // class 1
            &[(4 << 20) + 1, 64 << 20, 256 << 20], // class 3
        ];
        for (a, b) in pairs {
            for group in groups {
                let mechanisms: Vec<Mechanism> = group
                    .iter()
                    .map(|&bytes| {
                        select(&c, &p, c.rank_device(a), c.rank_device(b), bytes).mechanism()
                    })
                    .collect();
                assert!(
                    mechanisms.windows(2).all(|w| w[0] == w[1]),
                    "{a}->{b}: mechanism varied within a class: {mechanisms:?}"
                );
            }
        }
    }

    #[test]
    fn intranode_peer_uses_ipc() {
        let c = kesch(1, 4).unwrap();
        let p = CommParams::default();
        let plan = select(&c, &p, c.rank_device(0), c.rank_device(1), 1024);
        assert_eq!(plan.mechanism(), Mechanism::CudaIpc);
    }

    #[test]
    fn cross_socket_small_stages_through_host() {
        let c = kesch(1, 16).unwrap();
        let p = CommParams::default();
        let plan = select(&c, &p, c.rank_device(0), c.rank_device(8), 4096);
        assert_eq!(plan.mechanism(), Mechanism::HostStaged);
    }

    #[test]
    fn cross_socket_huge_may_use_gdr_read_if_cheaper() {
        let c = kesch(1, 16).unwrap();
        let p = CommParams::default();
        let plan = select(&c, &p, c.rank_device(0), c.rank_device(8), 256 << 20);
        // whichever it picks must be the cheaper of the two estimates
        let est = plan.estimate_ns(&c, 256 << 20);
        for m in [Mechanism::HostStaged, Mechanism::GdrReadCrossSocket] {
            if plan.mechanism() != m {
                // crude check: selected plan beats or equals the cap-based
                // lower bound of the alternative
                let _ = m;
            }
        }
        assert!(est > 0);
    }

    #[test]
    fn internode_eager_vs_rndv_threshold() {
        let c = kesch(2, 4).unwrap();
        let p = CommParams::default();
        let small = select(&c, &p, c.rank_device(0), c.rank_device(4), 8 << 10);
        assert_eq!(small.mechanism(), Mechanism::SglEagerGdr);
        let large = select(&c, &p, c.rank_device(0), c.rank_device(4), 1 << 20);
        assert_eq!(large.mechanism(), Mechanism::RndvGdrPipelined);
    }

    #[test]
    fn estimates_monotone_in_bytes() {
        let c = kesch(2, 8).unwrap();
        let p = CommParams::default();
        let pairs = [(0usize, 1usize), (0, 4), (0, 8)];
        for (a, b) in pairs {
            let mut prev = 0u64;
            for bytes in [64u64, 4 << 10, 1 << 20, 64 << 20] {
                let plan = select(&c, &p, c.rank_device(a), c.rank_device(b), bytes);
                let est = plan.estimate_ns(&c, bytes);
                assert!(est >= prev, "estimate must grow with size");
                prev = est;
            }
        }
    }

    #[test]
    fn small_eager_beats_rndv_latency() {
        let c = kesch(2, 4).unwrap();
        let p = CommParams::default();
        let eager = select(&c, &p, c.rank_device(0), c.rank_device(4), 4);
        assert!(eager.estimate_ns(&c, 4) < p.rndv_overhead_ns + 10_000);
    }
}
