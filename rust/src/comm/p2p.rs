//! Rank-to-rank sends: emit netsim ops for a selected mechanism.

use std::collections::HashMap;

use crate::netsim::{Deps, OpId, Plan, SimOp};
use crate::topology::{Cluster, DeviceId};

use super::protocol::{select, CommParams, PathPlan};

/// The point-to-point engine bound to one cluster. Caches path plans per
/// (src, dst, size-class) — mechanism choice depends only on the class.
pub struct Comm<'c> {
    cluster: &'c Cluster,
    params: CommParams,
    cache: HashMap<(DeviceId, DeviceId, u8), PathPlan>,
}

impl<'c> Comm<'c> {
    pub fn new(cluster: &'c Cluster) -> Comm<'c> {
        Comm::with_params(cluster, CommParams::default())
    }

    pub fn with_params(cluster: &'c Cluster, params: CommParams) -> Comm<'c> {
        Comm {
            cluster,
            params,
            cache: HashMap::new(),
        }
    }

    /// The bound cluster (returned with the cluster's own lifetime so
    /// callers can hold it across later `&mut self` calls).
    pub fn cluster(&self) -> &'c Cluster {
        self.cluster
    }

    pub fn params(&self) -> &CommParams {
        &self.params
    }

    /// Size class for plan caching: eager vs rendezvous vs staging
    /// decisions switch at parameter thresholds; within a class the plan
    /// is size-independent.
    fn size_class(&self, bytes: u64) -> u8 {
        let mut class = 0u8;
        if bytes > self.params.eager_threshold {
            class |= 1;
        }
        if bytes > self.params.staging_preferred_below {
            class |= 2;
        }
        class
    }

    /// Resolve (and cache) a path plan.
    pub fn path_plan(&mut self, src: DeviceId, dst: DeviceId, bytes: u64) -> &PathPlan {
        let key = (src, dst, self.size_class(bytes));
        let cluster = self.cluster;
        let params = &self.params;
        self.cache
            .entry(key)
            .or_insert_with(|| select(cluster, params, src, dst, bytes))
    }

    /// Uncontended estimate for one rank-to-rank transfer, ns.
    pub fn estimate_ns(&mut self, src_rank: usize, dst_rank: usize, bytes: u64) -> u64 {
        let (s, d) = (
            self.cluster.rank_device(src_rank),
            self.cluster.rank_device(dst_rank),
        );
        let cluster = self.cluster;
        self.path_plan(s, d, bytes).estimate_ns(cluster, bytes)
    }

    /// Emit the ops for one rank→rank send of `bytes` into `plan`,
    /// depending on `deps`; the final op carries `label`. Returns the op
    /// id whose completion means "dst received the data".
    pub fn send(
        &mut self,
        plan: &mut Plan,
        src_rank: usize,
        dst_rank: usize,
        bytes: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let src = self.cluster.rank_device(src_rank);
        let dst = self.cluster.rank_device(dst_rank);
        self.send_dev(plan, src, dst, bytes, deps, label)
    }

    /// Device-level send with mechanism selection (used by collectives
    /// that manipulate hosts/HCAs directly).
    pub fn send_dev(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        // PathPlan is Copy (interned routes): cache hits clone nothing
        let path = *self.path_plan(src, dst, bytes);
        match path {
            PathPlan::Direct {
                route,
                overhead_ns,
                bw_cap,
                ..
            } => plan.push(
                SimOp::Transfer {
                    route,
                    bytes,
                    overhead_ns,
                    // MPI send semantics: the whole t_s serialises the
                    // channel (Eq. 5)
                    issue_ns: overhead_ns,
                    bw_cap,
                },
                deps,
                label,
            ),
            PathPlan::Staged {
                first,
                second,
                overhead_each_ns,
                ..
            } => {
                let mid = plan.push(
                    SimOp::Transfer {
                        route: first,
                        bytes,
                        overhead_ns: overhead_each_ns,
                        issue_ns: overhead_each_ns,
                        bw_cap: None,
                    },
                    deps,
                    None,
                );
                plan.push(
                    SimOp::Transfer {
                        route: second,
                        bytes,
                        overhead_ns: overhead_each_ns,
                        issue_ns: overhead_each_ns,
                        bw_cap: None,
                    },
                    Deps::one(mid),
                    label,
                )
            }
        }
    }

    /// Raw transfer along the shortest route with explicit overhead — for
    /// algorithm-internal copies (e.g. host-staged collective D2H).
    pub fn raw_transfer(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        overhead_ns: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        self.raw_transfer_issue(plan, src, dst, bytes, overhead_ns, overhead_ns, deps, label)
    }

    /// Raw transfer with a distinct issue cost: posted writes (GDR H2D
    /// fan-out) are issued back-to-back (`issue_ns` apart) even though
    /// each completes only after the full `overhead_ns` latency.
    #[allow(clippy::too_many_arguments)]
    pub fn raw_transfer_issue(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        overhead_ns: u64,
        issue_ns: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let route = self
            .cluster
            .route(src, dst)
            .expect("raw_transfer: no route");
        plan.push(
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns,
                issue_ns,
                bw_cap: None,
            },
            deps,
            label,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::{flat, kesch};

    #[test]
    fn send_emits_single_op_for_ipc() {
        let c = kesch(1, 2);
        let mut comm = Comm::new(&c);
        let mut plan = Plan::new();
        let id = comm.send(&mut plan, 0, 1, 4096, vec![], Some((1, 0)));
        assert_eq!(plan.len(), 1);
        assert_eq!(id, 0);
    }

    #[test]
    fn send_emits_two_ops_for_staged() {
        let c = kesch(1, 16);
        let mut comm = Comm::new(&c);
        let mut plan = Plan::new();
        // rank 0 (socket 0) -> rank 8 (socket 1): staged
        let id = comm.send(&mut plan, 0, 8, 4096, vec![], Some((8, 0)));
        assert_eq!(plan.len(), 2);
        assert_eq!(id, 1);
        // delivery label on the second op only
        assert_eq!(plan.deliveries().get(&(8, 0)), Some(&1));
    }

    #[test]
    fn estimate_matches_execution_uncontended() {
        let c = flat(2);
        let mut comm = Comm::new(&c);
        let est = comm.estimate_ns(0, 1, 1 << 20);
        let mut plan = Plan::new();
        comm.send(&mut plan, 0, 1, 1 << 20, vec![], Some((1, 0)));
        let mut engine = Engine::new(&c);
        let r = engine.execute(&plan);
        assert_eq!(r.makespan, est);
    }

    #[test]
    fn cache_hits_are_consistent() {
        let c = kesch(2, 8);
        let mut comm = Comm::new(&c);
        let a = comm.estimate_ns(0, 9, 1024);
        let b = comm.estimate_ns(0, 9, 1024);
        assert_eq!(a, b);
    }

    #[test]
    fn small_intranode_faster_than_internode() {
        let c = kesch(2, 8);
        let mut comm = Comm::new(&c);
        let intra = comm.estimate_ns(0, 1, 4);
        let inter = comm.estimate_ns(0, 8, 4);
        assert!(intra < inter);
    }
}
