//! Rank-to-rank sends: emit netsim ops for a selected mechanism.

use std::collections::HashMap;

use crate::collectives::template::TemplateCache;
use crate::netsim::{Deps, OpId, Plan, SimOp};
use crate::topology::{Cluster, DeviceId};

use super::protocol::{class_representative, select, size_class, CommParams, PathPlan};

/// The point-to-point engine bound to one cluster. Caches path plans per
/// (src, dst, size-class) — mechanism choice depends only on the class —
/// and carries the collective-plan [`TemplateCache`] so plan structure
/// is built once per (algorithm, chunk shape, topology) and rescaled per
/// message size (DESIGN.md §Plan templates).
pub struct Comm<'c> {
    cluster: &'c Cluster,
    params: CommParams,
    cache: HashMap<(DeviceId, DeviceId, u8), PathPlan>,
    templates: TemplateCache,
}

impl<'c> Comm<'c> {
    pub fn new(cluster: &'c Cluster) -> Comm<'c> {
        Comm::with_params(cluster, CommParams::default())
    }

    pub fn with_params(cluster: &'c Cluster, params: CommParams) -> Comm<'c> {
        Comm {
            cluster,
            params,
            cache: HashMap::new(),
            templates: TemplateCache::new(),
        }
    }

    /// The bound cluster (returned with the cluster's own lifetime so
    /// callers can hold it across later `&mut self` calls).
    pub fn cluster(&self) -> &'c Cluster {
        self.cluster
    }

    pub fn params(&self) -> &CommParams {
        &self.params
    }

    /// The mechanism size class of a byte count (eager vs rendezvous vs
    /// staging switchovers) — recorded into plan templates so rescaling
    /// knows when a new size would have selected differently.
    pub fn size_class_of(&self, bytes: u64) -> u8 {
        size_class(&self.params, bytes)
    }

    /// The collective plan-template cache (hit-rate inspection).
    pub fn template_cache(&self) -> &TemplateCache {
        &self.templates
    }

    /// Mutable template cache — the `collectives::template::cached_plan`
    /// entry point drives it; most callers never touch this directly.
    pub fn template_cache_mut(&mut self) -> &mut TemplateCache {
        &mut self.templates
    }

    /// Detach the template cache to carry it across `Comm` instances
    /// (pair with [`Self::set_template_cache`]). Entries are keyed on the
    /// cluster's topology generation, so reuse after a mutation misses
    /// instead of serving stale structure.
    pub fn take_template_cache(&mut self) -> TemplateCache {
        std::mem::take(&mut self.templates)
    }

    pub fn set_template_cache(&mut self, templates: TemplateCache) {
        self.templates = templates;
    }

    /// Resolve (and cache) a path plan. Selection runs on the class's
    /// canonical representative size, making the resolved plan a pure
    /// function of (cluster, params, src, dst, class) — independent of
    /// the byte values or visit order that warmed the cache. Template
    /// rescaling and the parallel tuner's shared-comm workers rely on
    /// this purity.
    pub fn path_plan(&mut self, src: DeviceId, dst: DeviceId, bytes: u64) -> &PathPlan {
        let class = size_class(&self.params, bytes);
        let key = (src, dst, class);
        let cluster = self.cluster;
        let params = &self.params;
        self.cache
            .entry(key)
            .or_insert_with(|| {
                select(cluster, params, src, dst, class_representative(params, class))
            })
    }

    /// Uncontended estimate for one rank-to-rank transfer, ns.
    pub fn estimate_ns(&mut self, src_rank: usize, dst_rank: usize, bytes: u64) -> u64 {
        let (s, d) = (
            self.cluster.rank_device(src_rank),
            self.cluster.rank_device(dst_rank),
        );
        let cluster = self.cluster;
        self.path_plan(s, d, bytes).estimate_ns(cluster, bytes)
    }

    /// Emit the ops for one rank→rank send of `bytes` into `plan`,
    /// depending on `deps`; the final op carries `label`. Returns the op
    /// id whose completion means "dst received the data".
    pub fn send(
        &mut self,
        plan: &mut Plan,
        src_rank: usize,
        dst_rank: usize,
        bytes: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let src = self.cluster.rank_device(src_rank);
        let dst = self.cluster.rank_device(dst_rank);
        self.send_dev(plan, src, dst, bytes, deps, label)
    }

    /// Device-level send with mechanism selection (used by collectives
    /// that manipulate hosts/HCAs directly).
    pub fn send_dev(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        // PathPlan is Copy (interned routes): cache hits clone nothing
        let path = *self.path_plan(src, dst, bytes);
        match path {
            PathPlan::Direct {
                route,
                overhead_ns,
                bw_cap,
                ..
            } => plan.push(
                SimOp::Transfer {
                    route,
                    bytes,
                    overhead_ns,
                    // MPI send semantics: the whole t_s serialises the
                    // channel (Eq. 5)
                    issue_ns: overhead_ns,
                    bw_cap,
                },
                deps,
                label,
            ),
            PathPlan::Staged {
                first,
                second,
                overhead_each_ns,
                ..
            } => {
                let mid = plan.push(
                    SimOp::Transfer {
                        route: first,
                        bytes,
                        overhead_ns: overhead_each_ns,
                        issue_ns: overhead_each_ns,
                        bw_cap: None,
                    },
                    deps,
                    None,
                );
                plan.push(
                    SimOp::Transfer {
                        route: second,
                        bytes,
                        overhead_ns: overhead_each_ns,
                        issue_ns: overhead_each_ns,
                        bw_cap: None,
                    },
                    Deps::one(mid),
                    label,
                )
            }
        }
    }

    /// Raw transfer along the shortest route with explicit overhead — for
    /// algorithm-internal copies (e.g. host-staged collective D2H).
    pub fn raw_transfer(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        overhead_ns: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        self.raw_transfer_issue(plan, src, dst, bytes, overhead_ns, overhead_ns, deps, label)
    }

    /// Raw transfer with a distinct issue cost: posted writes (GDR H2D
    /// fan-out) are issued back-to-back (`issue_ns` apart) even though
    /// each completes only after the full `overhead_ns` latency.
    #[allow(clippy::too_many_arguments)]
    pub fn raw_transfer_issue(
        &mut self,
        plan: &mut Plan,
        src: DeviceId,
        dst: DeviceId,
        bytes: u64,
        overhead_ns: u64,
        issue_ns: u64,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let route = self
            .cluster
            .route(src, dst)
            .expect("raw_transfer: no route");
        plan.push(
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns,
                issue_ns,
                bw_cap: None,
            },
            deps,
            label,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::{flat, kesch};

    #[test]
    fn send_emits_single_op_for_ipc() {
        let c = kesch(1, 2).unwrap();
        let mut comm = Comm::new(&c);
        let mut plan = Plan::new();
        let id = comm.send(&mut plan, 0, 1, 4096, vec![], Some((1, 0)));
        assert_eq!(plan.len(), 1);
        assert_eq!(id, 0);
    }

    #[test]
    fn send_emits_two_ops_for_staged() {
        let c = kesch(1, 16).unwrap();
        let mut comm = Comm::new(&c);
        let mut plan = Plan::new();
        // rank 0 (socket 0) -> rank 8 (socket 1): staged
        let id = comm.send(&mut plan, 0, 8, 4096, vec![], Some((8, 0)));
        assert_eq!(plan.len(), 2);
        assert_eq!(id, 1);
        // delivery label on the second op only
        assert_eq!(plan.deliveries().get(&(8, 0)), Some(&1));
    }

    #[test]
    fn estimate_matches_execution_uncontended() {
        let c = flat(2).unwrap();
        let mut comm = Comm::new(&c);
        let est = comm.estimate_ns(0, 1, 1 << 20);
        let mut plan = Plan::new();
        comm.send(&mut plan, 0, 1, 1 << 20, vec![], Some((1, 0)));
        let mut engine = Engine::new(&c);
        let r = engine.execute(&plan);
        assert_eq!(r.makespan, est);
    }

    #[test]
    fn cache_hits_are_consistent() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let a = comm.estimate_ns(0, 9, 1024);
        let b = comm.estimate_ns(0, 9, 1024);
        assert_eq!(a, b);
    }

    #[test]
    fn path_plans_are_visit_order_independent() {
        // canonical per-class selection: estimates must not depend on
        // which byte value first warmed the (src, dst, class) entry
        let c = kesch(2, 8).unwrap();
        let sizes = [256u64 << 20, 4, 1 << 20, (16 << 10) + 1];
        let mut fwd = Comm::new(&c);
        for &b in &sizes {
            let _ = fwd.estimate_ns(0, 9, b);
        }
        let mut bwd = Comm::new(&c);
        for &b in sizes.iter().rev() {
            let _ = bwd.estimate_ns(0, 9, b);
        }
        for &b in &sizes {
            assert_eq!(
                fwd.estimate_ns(0, 9, b),
                bwd.estimate_ns(0, 9, b),
                "estimate at {b}B depends on cache warm order"
            );
        }
    }

    #[test]
    fn small_intranode_faster_than_internode() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let intra = comm.estimate_ns(0, 1, 4);
        let inter = comm.estimate_ns(0, 8, 4);
        assert!(intra < inter);
    }
}
