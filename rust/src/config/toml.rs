//! TOML-subset parser.
//!
//! Supported grammar (sufficient for our config files):
//!
//! ```toml
//! # comment
//! top_level_key = 3
//! [section]
//! name = "kesch"          # strings
//! gpus_per_node = 16      # integers
//! bandwidth_gbps = 6.8    # floats
//! multirail = true        # booleans
//! sizes = ["4", "8K"]     # homogeneous arrays of the above
//! ```
//!
//! Not supported (and not needed): nested tables, inline tables, dates,
//! multi-line strings, array-of-tables.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(xs) => Some(xs),
            _ => None,
        }
    }
}

/// A parsed document: `section -> key -> value`. Top-level keys live in
/// the section named `""`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    Error::Config(format!("line {}: unterminated section", lineno + 1))
                })?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)?;
        TomlDoc::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(|v| v.as_f64())
            .unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key)
            .and_then(|v| v.as_bool())
            .unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(Vec::new()));
        }
        let items = split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split on commas that are not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = TomlDoc::parse(
            r#"
            # experiment config
            seed = 42
            [cluster]
            preset = "kesch"     # Cray CS-Storm
            nodes = 8
            link_gbps = 6.8
            multirail = true
            sizes = ["4", "8K", "128M"]
            counts = [2, 4, 8]
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("", "seed", 0), 42);
        assert_eq!(doc.str_or("cluster", "preset", "?"), "kesch");
        assert_eq!(doc.i64_or("cluster", "nodes", 0), 8);
        assert!((doc.f64_or("cluster", "link_gbps", 0.0) - 6.8).abs() < 1e-12);
        assert!(doc.bool_or("cluster", "multirail", false));
        let sizes = doc.get("cluster", "sizes").unwrap().as_arr().unwrap();
        assert_eq!(sizes.len(), 3);
        assert_eq!(sizes[1].as_str(), Some("8K"));
        let counts = doc.get("cluster", "counts").unwrap().as_arr().unwrap();
        assert_eq!(counts[2].as_i64(), Some(8));
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("x", "y", 7), 7);
        assert_eq!(doc.str_or("x", "y", "dflt"), "dflt");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn underscored_ints() {
        let doc = TomlDoc::parse("n = 1_000_000").unwrap();
        assert_eq!(doc.i64_or("", "n", 0), 1_000_000);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a#b");
    }
}
