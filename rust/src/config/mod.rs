//! Configuration system.
//!
//! Experiments, topologies and training runs are configurable through a
//! TOML-subset file format (the `toml` crate is not available offline).
//! [`toml::TomlDoc`] parses the subset we need — `[section]` headers,
//! `key = value` with strings/ints/floats/bools/arrays, comments — and
//! [`schema`] maps documents onto typed config structs with validation.

pub mod schema;
pub mod toml;

pub use schema::{BenchConfig, ClusterConfig, TrainConfig};
pub use toml::TomlDoc;
