//! Typed configuration schemas with validation.
//!
//! These structs are the bridge between config files / CLI options and the
//! library APIs. Every experiment binary builds one of these (from
//! defaults, a TOML document, or flags) and hands it to the relevant
//! subsystem.

use crate::error::{Error, Result};
use crate::util::bytes;

use super::toml::TomlDoc;

/// Which machine model to instantiate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClusterPreset {
    /// KESCH (Cray CS-Storm): 2 sockets × (2 PLX × 2 K80 boards) = 16 CUDA
    /// devices/node, dual-rail IB FDR — the paper's testbed.
    Kesch,
    /// NVIDIA DGX-1: 8× P100, NVLink cube mesh, 4× IB EDR.
    Dgx1,
    /// NVIDIA DGX-1V: 8× V100, NVLink2.
    Dgx1V,
    /// A flat homogeneous fabric (every pair one hop, uniform B) — used to
    /// validate the simulator against the paper's analytic models, which
    /// assume exactly this.
    Flat,
}

impl ClusterPreset {
    pub fn parse(s: &str) -> Result<ClusterPreset> {
        match s.to_ascii_lowercase().as_str() {
            "kesch" | "cs-storm" => Ok(ClusterPreset::Kesch),
            "dgx1" | "dgx-1" => Ok(ClusterPreset::Dgx1),
            "dgx1v" | "dgx-1v" => Ok(ClusterPreset::Dgx1V),
            "flat" | "uniform" => Ok(ClusterPreset::Flat),
            other => Err(Error::Config(format!("unknown cluster preset '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClusterPreset::Kesch => "kesch",
            ClusterPreset::Dgx1 => "dgx1",
            ClusterPreset::Dgx1V => "dgx1v",
            ClusterPreset::Flat => "flat",
        }
    }
}

/// Cluster instantiation parameters.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub preset: ClusterPreset,
    pub nodes: usize,
    /// GPUs used per node (≤ the preset's physical count).
    pub gpus_per_node: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            preset: ClusterPreset::Kesch,
            nodes: 1,
            gpus_per_node: 16,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(Error::Config("nodes must be >= 1".into()));
        }
        if self.gpus_per_node == 0 {
            return Err(Error::Config("gpus_per_node must be >= 1".into()));
        }
        let max = match self.preset {
            ClusterPreset::Kesch => 16,
            ClusterPreset::Dgx1 | ClusterPreset::Dgx1V => 8,
            ClusterPreset::Flat => 4096,
        };
        if self.gpus_per_node > max {
            return Err(Error::Config(format!(
                "preset {} has at most {max} GPUs per node (asked for {})",
                self.preset.name(),
                self.gpus_per_node
            )));
        }
        Ok(())
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<ClusterConfig> {
        let cfg = ClusterConfig {
            preset: ClusterPreset::parse(&doc.str_or("cluster", "preset", "kesch"))?,
            nodes: doc.i64_or("cluster", "nodes", 1) as usize,
            gpus_per_node: doc.i64_or("cluster", "gpus_per_node", 16) as usize,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// A parsed `--topology` CLI spec for the datacenter-scale structured
/// fabrics: `kind:key=value,...`. Distinct from [`ClusterPreset`]
/// because these fabrics are parameterized by fabric shape (pods,
/// rails, groups), not by a `nodes × gpus_per_node` grid.
///
/// Examples:
/// `fat-tree:pods=4,leaves=8,gpus=32,rails=2,spines=2`,
/// `rail:nodes=128,gpus=8`, `nvswitch:nodes=16,gpus=8`,
/// `dragonfly:groups=8,routers=8,gpus=2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricSpec {
    FatTree {
        pods: usize,
        leaves_per_pod: usize,
        gpus_per_leaf: usize,
        rails: usize,
        spines_per_pod: usize,
    },
    RailOptimized {
        nodes: usize,
        gpus_per_node: usize,
    },
    NvSwitch {
        nodes: usize,
        gpus_per_node: usize,
    },
    Dragonfly {
        groups: usize,
        routers_per_group: usize,
        gpus_per_router: usize,
    },
}

impl FabricSpec {
    /// Parse `kind:key=value,...`. Unknown keys are rejected; omitted
    /// keys take the documented defaults.
    pub fn parse(s: &str) -> Result<FabricSpec> {
        let (kind, rest) = match s.split_once(':') {
            Some((k, r)) => (k, r),
            None => (s, ""),
        };
        let mut kv: Vec<(&str, usize)> = Vec::new();
        for part in rest.split(',').filter(|p| !p.is_empty()) {
            let (key, value) = part.split_once('=').ok_or_else(|| {
                Error::Usage(format!(
                    "--topology: expected key=value, got '{part}' in '{s}'"
                ))
            })?;
            let value: usize = value.trim().parse().map_err(|_| {
                Error::Usage(format!("--topology: bad value '{value}' for key '{key}'"))
            })?;
            kv.push((key.trim(), value));
        }
        let lookup = |keys: &[&str], default: usize| -> usize {
            kv.iter()
                .find(|(k, _)| keys.contains(k))
                .map(|&(_, v)| v)
                .unwrap_or(default)
        };
        let check_keys = |allowed: &[&[&str]]| -> Result<()> {
            for &(k, _) in &kv {
                if !allowed.iter().any(|group| group.contains(&k)) {
                    return Err(Error::Usage(format!(
                        "--topology: unknown key '{k}' in '{s}'"
                    )));
                }
            }
            Ok(())
        };
        match kind.to_ascii_lowercase().as_str() {
            "fat-tree" | "fattree" | "fat_tree" => {
                check_keys(&[
                    &["pods"],
                    &["leaves", "leaves_per_pod"],
                    &["gpus", "gpus_per_leaf"],
                    &["rails"],
                    &["spines", "spines_per_pod"],
                ])?;
                Ok(FabricSpec::FatTree {
                    pods: lookup(&["pods"], 2),
                    leaves_per_pod: lookup(&["leaves", "leaves_per_pod"], 4),
                    gpus_per_leaf: lookup(&["gpus", "gpus_per_leaf"], 8),
                    rails: lookup(&["rails"], 2),
                    spines_per_pod: lookup(&["spines", "spines_per_pod"], 2),
                })
            }
            "rail" | "rail-optimized" | "rail_optimized" => {
                check_keys(&[&["nodes"], &["gpus", "gpus_per_node"]])?;
                Ok(FabricSpec::RailOptimized {
                    nodes: lookup(&["nodes"], 16),
                    gpus_per_node: lookup(&["gpus", "gpus_per_node"], 8),
                })
            }
            "nvswitch" | "nv-switch" => {
                check_keys(&[&["nodes"], &["gpus", "gpus_per_node"]])?;
                Ok(FabricSpec::NvSwitch {
                    nodes: lookup(&["nodes"], 16),
                    gpus_per_node: lookup(&["gpus", "gpus_per_node"], 8),
                })
            }
            "dragonfly" => {
                check_keys(&[
                    &["groups"],
                    &["routers", "routers_per_group"],
                    &["gpus", "gpus_per_router"],
                ])?;
                Ok(FabricSpec::Dragonfly {
                    groups: lookup(&["groups"], 4),
                    routers_per_group: lookup(&["routers", "routers_per_group"], 4),
                    gpus_per_router: lookup(&["gpus", "gpus_per_router"], 4),
                })
            }
            other => Err(Error::Usage(format!(
                "--topology: unknown fabric kind '{other}' \
                 (expected fat-tree | rail | nvswitch | dragonfly)"
            ))),
        }
    }
}

/// Micro-benchmark sweep parameters (osu_bcast methodology).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Message sizes to sweep (bytes).
    pub sizes: Vec<u64>,
    /// Timed iterations per size.
    pub iters: usize,
    /// Warmup iterations per size (excluded from stats).
    pub warmup: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            sizes: bytes::pow2_sweep(4, 128 << 20),
            iters: 100,
            warmup: 10,
        }
    }
}

impl BenchConfig {
    pub fn validate(&self) -> Result<()> {
        if self.sizes.is_empty() {
            return Err(Error::Config("bench sizes empty".into()));
        }
        if self.iters == 0 {
            return Err(Error::Config("bench iters must be >= 1".into()));
        }
        Ok(())
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<BenchConfig> {
        let mut cfg = BenchConfig::default();
        if let Some(arr) = doc.get("bench", "sizes").and_then(|v| v.as_arr()) {
            cfg.sizes = arr
                .iter()
                .map(|v| match v {
                    super::toml::TomlValue::Str(s) => bytes::parse_size(s),
                    super::toml::TomlValue::Int(i) => Ok(*i as u64),
                    _ => Err(Error::Config("bad size entry".into())),
                })
                .collect::<Result<Vec<u64>>>()?;
        }
        cfg.iters = doc.i64_or("bench", "iters", cfg.iters as i64) as usize;
        cfg.warmup = doc.i64_or("bench", "warmup", cfg.warmup as i64) as usize;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Data-parallel training run parameters (the CNTK role).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model descriptor name: lenet | alexnet | googlenet | resnet50 | vgg16 | vgg-mini.
    pub model: String,
    /// Total data-parallel ranks (GPUs).
    pub gpus: usize,
    /// Minibatches (iterations) to run/simulate.
    pub iterations: usize,
    /// Global minibatch size (split across ranks).
    pub batch_size: usize,
    /// Per-GPU compute time for one fwd+bwd on its shard, in µs. When the
    /// E2E driver runs, this is *measured* via PJRT instead.
    pub compute_us: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "vgg16".into(),
            gpus: 32,
            iterations: 100,
            batch_size: 256,
            compute_us: 0.0,
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        if self.gpus == 0 {
            return Err(Error::Config("gpus must be >= 1".into()));
        }
        if self.iterations == 0 {
            return Err(Error::Config("iterations must be >= 1".into()));
        }
        if self.batch_size == 0 {
            return Err(Error::Config("batch_size must be >= 1".into()));
        }
        Ok(())
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<TrainConfig> {
        let cfg = TrainConfig {
            model: doc.str_or("train", "model", "vgg16"),
            gpus: doc.i64_or("train", "gpus", 32) as usize,
            iterations: doc.i64_or("train", "iterations", 100) as usize,
            batch_size: doc.i64_or("train", "batch_size", 256) as usize,
            compute_us: doc.f64_or("train", "compute_us", 0.0),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_parse() {
        assert_eq!(ClusterPreset::parse("KESCH").unwrap(), ClusterPreset::Kesch);
        assert_eq!(ClusterPreset::parse("dgx-1v").unwrap(), ClusterPreset::Dgx1V);
        assert!(ClusterPreset::parse("hal9000").is_err());
    }

    #[test]
    fn fabric_spec_parse() {
        assert_eq!(
            FabricSpec::parse("fat-tree:pods=4,leaves=8,gpus=32,rails=2,spines=2").unwrap(),
            FabricSpec::FatTree {
                pods: 4,
                leaves_per_pod: 8,
                gpus_per_leaf: 32,
                rails: 2,
                spines_per_pod: 2
            }
        );
        // defaults fill omitted keys
        assert_eq!(
            FabricSpec::parse("rail:nodes=128").unwrap(),
            FabricSpec::RailOptimized {
                nodes: 128,
                gpus_per_node: 8
            }
        );
        assert_eq!(
            FabricSpec::parse("nvswitch").unwrap(),
            FabricSpec::NvSwitch {
                nodes: 16,
                gpus_per_node: 8
            }
        );
        assert_eq!(
            FabricSpec::parse("dragonfly:groups=8,routers=8,gpus=2").unwrap(),
            FabricSpec::Dragonfly {
                groups: 8,
                routers_per_group: 8,
                gpus_per_router: 2
            }
        );
        assert!(FabricSpec::parse("torus:x=4").is_err());
        assert!(FabricSpec::parse("fat-tree:bogus=1").is_err());
        assert!(FabricSpec::parse("fat-tree:pods").is_err());
        assert!(FabricSpec::parse("fat-tree:pods=many").is_err());
    }

    #[test]
    fn cluster_validation() {
        let mut c = ClusterConfig::default();
        c.validate().unwrap();
        c.gpus_per_node = 17;
        assert!(c.validate().is_err());
        c.gpus_per_node = 16;
        c.nodes = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn full_doc_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
            [cluster]
            preset = "kesch"
            nodes = 4
            gpus_per_node = 16
            [bench]
            sizes = ["4", "8K", 64]
            iters = 50
            warmup = 5
            [train]
            model = "vgg16"
            gpus = 64
            iterations = 20
            batch_size = 512
            "#,
        )
        .unwrap();
        let cluster = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cluster.total_gpus(), 64);
        let bench = BenchConfig::from_toml(&doc).unwrap();
        assert_eq!(bench.sizes, vec![4, 8192, 64]);
        assert_eq!(bench.iters, 50);
        let train = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(train.gpus, 64);
    }

    #[test]
    fn defaults_are_valid() {
        ClusterConfig::default().validate().unwrap();
        BenchConfig::default().validate().unwrap();
        TrainConfig::default().validate().unwrap();
    }
}
