//! Crate-wide error type.

/// Errors produced by the gdrbcast library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A topology query referenced a device that does not exist.
    #[error("unknown device id {0}")]
    UnknownDevice(usize),

    /// No route exists between two devices.
    #[error("no route between device {src} and device {dst}")]
    NoRoute { src: usize, dst: usize },

    /// A collective was asked to run over an invalid rank set.
    #[error("invalid rank set: {0}")]
    InvalidRanks(String),

    /// A broadcast plan failed validation (a rank did not receive data).
    #[error("broadcast plan invalid: {0}")]
    InvalidPlan(String),

    /// Configuration file / value errors.
    #[error("config error: {0}")]
    Config(String),

    /// CLI usage errors.
    #[error("usage error: {0}")]
    Usage(String),

    /// Artifact discovery / runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// PJRT / XLA errors surfaced from the `xla` crate.
    #[error("xla error: {0}")]
    Xla(String),

    /// IO errors.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
