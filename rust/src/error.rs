//! Crate-wide error type.
//!
//! `Display`/`Error` are hand-implemented: the offline crate universe has
//! no `thiserror` (the seed's derive could never build without registry
//! access).

use std::fmt;

/// Errors produced by the gdrbcast library.
#[derive(Debug)]
pub enum Error {
    /// A topology query referenced a device that does not exist.
    UnknownDevice(usize),

    /// No route exists between two devices.
    NoRoute { src: usize, dst: usize },

    /// A collective was asked to run over an invalid rank set.
    InvalidRanks(String),

    /// A collective plan failed validation (delivery, causality or
    /// reduction-dataflow invariant broken).
    InvalidPlan(String),

    /// Configuration file / value errors.
    Config(String),

    /// CLI usage errors.
    Usage(String),

    /// Artifact discovery / runtime errors.
    Runtime(String),

    /// PJRT / XLA errors surfaced from the `xla` crate.
    Xla(String),

    /// IO errors.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownDevice(id) => write!(f, "unknown device id {id}"),
            Error::NoRoute { src, dst } => {
                write!(f, "no route between device {src} and device {dst}")
            }
            Error::InvalidRanks(msg) => write!(f, "invalid rank set: {msg}"),
            Error::InvalidPlan(msg) => write!(f, "collective plan invalid: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Usage(msg) => write!(f, "usage error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Xla(msg) => write!(f, "xla error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive() {
        assert_eq!(Error::UnknownDevice(3).to_string(), "unknown device id 3");
        assert_eq!(
            Error::NoRoute { src: 1, dst: 2 }.to_string(),
            "no route between device 1 and device 2"
        );
        assert_eq!(Error::Usage("x".into()).to_string(), "usage error: x");
    }

    #[test]
    fn io_error_converts_and_chains() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
