//! Devices: the vertices of the topology graph.

/// Index of a device within a [`super::Cluster`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// Index of a physical node (chassis) within a cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// What a vertex in the fabric graph is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// A CUDA device (one GK210 die of a K80 board, a P100, …).
    Gpu,
    /// A CPU socket with its attached host memory. Host-staging copies
    /// bounce through one of these.
    Host,
    /// The PCIe root complex hanging off one socket.
    PcieRoot,
    /// A PLX PCIe switch (GPUs under the same PLX have peer access).
    PlxSwitch,
    /// An InfiniBand host channel adapter.
    IbHca,
    /// An InfiniBand fabric switch (leaf/spine/core tier or the single
    /// crossbar of the small presets).
    IbSwitch,
    /// An NVSwitch: the full-mesh NVLink crossbar inside an NVSwitch or
    /// rail-optimized node (every GPU one NVLink hop from every other).
    NvSwitch,
}

impl DeviceKind {
    pub fn short(&self) -> &'static str {
        match self {
            DeviceKind::Gpu => "gpu",
            DeviceKind::Host => "host",
            DeviceKind::PcieRoot => "root",
            DeviceKind::PlxSwitch => "plx",
            DeviceKind::IbHca => "hca",
            DeviceKind::IbSwitch => "ibsw",
            DeviceKind::NvSwitch => "nvsw",
        }
    }
}

/// A vertex of the fabric graph.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: DeviceId,
    pub kind: DeviceKind,
    /// Which physical node (chassis) this device lives in. The IB switch
    /// belongs to a pseudo-node with index `usize::MAX`.
    pub node: NodeId,
    /// Which CPU socket's PCIe domain this device hangs off (0/1); the IB
    /// switch uses 0.
    pub socket: u8,
    /// Human-readable name, e.g. `n0.s1.plx0.gpu2`.
    pub name: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_short_names_unique() {
        let kinds = [
            DeviceKind::Gpu,
            DeviceKind::Host,
            DeviceKind::PcieRoot,
            DeviceKind::PlxSwitch,
            DeviceKind::IbHca,
            DeviceKind::IbSwitch,
            DeviceKind::NvSwitch,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.short()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), kinds.len());
    }
}
