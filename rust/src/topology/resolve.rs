//! Algebraic route resolvers for structured fabrics.
//!
//! The small presets (kesch/dgx1/flat) resolve routes by BFS, interned
//! once per (src, dst) pair. That is O(V+E) per cold pair and O(pairs)
//! table growth — fine at 128 GPUs, hopeless at 64k. Structured fabrics
//! (fat-tree, rail-optimized, NVSwitch, dragonfly) are regular enough
//! that the shortest route between two GPUs follows from coordinate
//! arithmetic alone: pod/rail/switch indices select the exact uplink and
//! downlink ports in O(path length), no graph search.
//!
//! Each generator in [`super::presets`] records, while it wires the
//! graph, the [`LinkId`] port tables its resolver needs, and installs the
//! resolver on the returned [`Cluster`](super::Cluster). `Cluster::route`
//! consults the resolver first and falls back to BFS whenever the
//! resolver declines (non-GPU endpoint, arbitrary mutated graph) or the
//! algebraic route would cross a link removed by `kill_link` — so fault
//! recovery keeps working on structured fabrics, just through the slower
//! golden path. BFS also remains the *reference*: parity tests assert
//! algebraic routes match BFS hop counts and aggregates on small
//! instances of every fabric.

use super::device::DeviceId;
use super::link::LinkId;

/// Which structured family a cluster belongs to. `Generic` covers the
/// BFS-resolved presets and any hand-built graph. Plan-template caches
/// key on this: two clusters of different families never share
/// templates even if rank counts agree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TopologyKind {
    #[default]
    Generic,
    FatTree,
    RailOptimized,
    NvSwitch,
    Dragonfly,
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Generic => "generic",
            TopologyKind::FatTree => "fat-tree",
            TopologyKind::RailOptimized => "rail-optimized",
            TopologyKind::NvSwitch => "nvswitch",
            TopologyKind::Dragonfly => "dragonfly",
        }
    }
}

/// Route resolution strategy for a cluster. A plain enum (not a trait
/// object) so `Cluster` stays `Clone` and the engine's hot path stays
/// monomorphic.
#[derive(Debug, Clone, Default)]
pub enum Resolver {
    /// Graph search through the interning table — the golden reference
    /// and the only strategy valid for arbitrary graphs.
    #[default]
    Bfs,
    FatTree(FatTreeGeo),
    RailOptimized(RailGeo),
    NvSwitch(NvSwitchGeo),
    Dragonfly(DragonflyGeo),
}

impl Resolver {
    pub fn kind(&self) -> TopologyKind {
        match self {
            Resolver::Bfs => TopologyKind::Generic,
            Resolver::FatTree(_) => TopologyKind::FatTree,
            Resolver::RailOptimized(_) => TopologyKind::RailOptimized,
            Resolver::NvSwitch(_) => TopologyKind::NvSwitch,
            Resolver::Dragonfly(_) => TopologyKind::Dragonfly,
        }
    }

    pub fn is_algebraic(&self) -> bool {
        !matches!(self, Resolver::Bfs)
    }

    /// Shortest route from `src` to `dst` by coordinate arithmetic.
    /// `None` means the resolver does not cover this pair (either
    /// endpoint is not a fabric GPU) and the caller must BFS.
    pub fn resolve(&self, src: DeviceId, dst: DeviceId) -> Option<Vec<LinkId>> {
        match self {
            Resolver::Bfs => None,
            Resolver::FatTree(g) => g.resolve(src, dst),
            Resolver::RailOptimized(g) => g.resolve(src, dst),
            Resolver::NvSwitch(g) => g.resolve(src, dst),
            Resolver::Dragonfly(g) => g.resolve(src, dst),
        }
    }
}

/// Map device ids to fabric coordinates: `coord_of[dev] == u32::MAX`
/// for every non-GPU device. Coordinates are generation-time GPU
/// indices, stable across `retain_ranks` renumbering (they index port
/// tables, not the live rank order).
fn coord(coord_of: &[u32], dev: DeviceId) -> Option<usize> {
    match coord_of.get(dev.0) {
        Some(&c) if c != u32::MAX => Some(c as usize),
        _ => None,
    }
}

/// Multi-rail fat-tree: per rail, each GPU hangs off a leaf switch;
/// leaves uplink to every pod spine of their pod; spine `s` of every pod
/// uplinks to core `s` of its rail. Routes are 2 hops (same leaf),
/// 4 hops (same pod) or 6 hops (cross pod); rail and spine are chosen
/// by (src + dst) arithmetic so distinct pairs spread over the fabric
/// deterministically.
#[derive(Debug, Clone)]
pub struct FatTreeGeo {
    pub pods: usize,
    pub leaves_per_pod: usize,
    pub gpus_per_leaf: usize,
    pub rails: usize,
    pub spines_per_pod: usize,
    pub(super) coord_of: Vec<u32>,
    /// gpu -> leaf, per (gpu coord, rail).
    pub(super) gpu_up: Vec<LinkId>,
    /// leaf -> gpu, per (gpu coord, rail).
    pub(super) gpu_down: Vec<LinkId>,
    /// leaf -> spine, per (pod, leaf, rail, spine).
    pub(super) leaf_up: Vec<LinkId>,
    /// spine -> leaf, per (pod, leaf, rail, spine).
    pub(super) leaf_down: Vec<LinkId>,
    /// spine -> core, per (pod, rail, spine).
    pub(super) spine_up: Vec<LinkId>,
    /// core -> spine, per (pod, rail, spine).
    pub(super) spine_down: Vec<LinkId>,
}

impl FatTreeGeo {
    pub(super) fn sized(
        pods: usize,
        leaves_per_pod: usize,
        gpus_per_leaf: usize,
        rails: usize,
        spines_per_pod: usize,
    ) -> FatTreeGeo {
        let n_gpus = pods * leaves_per_pod * gpus_per_leaf;
        let none = LinkId(usize::MAX);
        FatTreeGeo {
            pods,
            leaves_per_pod,
            gpus_per_leaf,
            rails,
            spines_per_pod,
            coord_of: Vec::new(),
            gpu_up: vec![none; n_gpus * rails],
            gpu_down: vec![none; n_gpus * rails],
            leaf_up: vec![none; pods * leaves_per_pod * rails * spines_per_pod],
            leaf_down: vec![none; pods * leaves_per_pod * rails * spines_per_pod],
            spine_up: vec![none; pods * rails * spines_per_pod],
            spine_down: vec![none; pods * rails * spines_per_pod],
        }
    }

    pub(super) fn leaf_idx(&self, pod: usize, leaf: usize, rail: usize, spine: usize) -> usize {
        ((pod * self.leaves_per_pod + leaf) * self.rails + rail) * self.spines_per_pod + spine
    }

    pub(super) fn spine_idx(&self, pod: usize, rail: usize, spine: usize) -> usize {
        (pod * self.rails + rail) * self.spines_per_pod + spine
    }

    fn resolve(&self, src: DeviceId, dst: DeviceId) -> Option<Vec<LinkId>> {
        let s = coord(&self.coord_of, src)?;
        let d = coord(&self.coord_of, dst)?;
        if s == d {
            return None; // trivial routes are the cluster's business
        }
        let gpl = self.gpus_per_leaf;
        let lpp = self.leaves_per_pod;
        let (sl, dl) = (s / gpl, d / gpl); // global leaf index
        let (sp, dp) = (sl / lpp, dl / lpp); // pod index
        let rail = (s + d) % self.rails;
        let mut hops = Vec::with_capacity(6);
        hops.push(self.gpu_up[s * self.rails + rail]);
        if sl != dl {
            let spine = (sl + dl) % self.spines_per_pod;
            hops.push(self.leaf_up[self.leaf_idx(sp, sl % lpp, rail, spine)]);
            if sp != dp {
                hops.push(self.spine_up[self.spine_idx(sp, rail, spine)]);
                hops.push(self.spine_down[self.spine_idx(dp, rail, spine)]);
            }
            hops.push(self.leaf_down[self.leaf_idx(dp, dl % lpp, rail, spine)]);
        }
        hops.push(self.gpu_down[d * self.rails + rail]);
        Some(hops)
    }
}

/// Rail-optimized node pod: every node has an NVSwitch crossbar; each
/// GPU's HCA uplinks to the rail switch of its *local index*, so
/// same-index GPUs across nodes talk switch-direct and cross-index
/// traffic first hops to the same-node peer over NVLink (the
/// NCCL-style rail alignment).
#[derive(Debug, Clone)]
pub struct RailGeo {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub(super) coord_of: Vec<u32>,
    /// gpu -> node NVSwitch, per gpu coord.
    pub(super) nv_up: Vec<LinkId>,
    /// node NVSwitch -> gpu.
    pub(super) nv_down: Vec<LinkId>,
    /// gpu -> its HCA.
    pub(super) hca_up: Vec<LinkId>,
    /// HCA -> gpu.
    pub(super) hca_down: Vec<LinkId>,
    /// HCA -> rail switch of the gpu's local index.
    pub(super) rail_up: Vec<LinkId>,
    /// rail switch -> HCA.
    pub(super) rail_down: Vec<LinkId>,
}

impl RailGeo {
    pub(super) fn sized(nodes: usize, gpus_per_node: usize) -> RailGeo {
        let n = nodes * gpus_per_node;
        let none = LinkId(usize::MAX);
        RailGeo {
            nodes,
            gpus_per_node,
            coord_of: Vec::new(),
            nv_up: vec![none; n],
            nv_down: vec![none; n],
            hca_up: vec![none; n],
            hca_down: vec![none; n],
            rail_up: vec![none; n],
            rail_down: vec![none; n],
        }
    }

    fn resolve(&self, src: DeviceId, dst: DeviceId) -> Option<Vec<LinkId>> {
        let s = coord(&self.coord_of, src)?;
        let d = coord(&self.coord_of, dst)?;
        if s == d {
            return None;
        }
        let gpn = self.gpus_per_node;
        let (sn, si) = (s / gpn, s % gpn);
        let (dn, di) = (d / gpn, d % gpn);
        if sn == dn {
            return Some(vec![self.nv_up[s], self.nv_down[d]]);
        }
        if si == di {
            // rail-aligned: HCA -> rail switch -> HCA
            return Some(vec![
                self.hca_up[s],
                self.rail_up[s],
                self.rail_down[d],
                self.hca_down[d],
            ]);
        }
        // cross-rail: hop to the same-node peer on the destination's rail
        // over NVLink, then ride that rail across
        let peer = sn * gpn + di;
        Some(vec![
            self.nv_up[s],
            self.nv_down[peer],
            self.hca_up[peer],
            self.rail_up[peer],
            self.rail_down[d],
            self.hca_down[d],
        ])
    }
}

/// NVSwitch full-mesh nodes behind one IB core: every GPU is one
/// NVLink hop from its node siblings (via the NVSwitch) and four hops
/// from any remote GPU (HCA -> core -> HCA).
#[derive(Debug, Clone)]
pub struct NvSwitchGeo {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub(super) coord_of: Vec<u32>,
    pub(super) nv_up: Vec<LinkId>,
    pub(super) nv_down: Vec<LinkId>,
    pub(super) hca_up: Vec<LinkId>,
    pub(super) hca_down: Vec<LinkId>,
    /// HCA -> the single IB core switch.
    pub(super) core_up: Vec<LinkId>,
    /// core switch -> HCA.
    pub(super) core_down: Vec<LinkId>,
}

impl NvSwitchGeo {
    pub(super) fn sized(nodes: usize, gpus_per_node: usize) -> NvSwitchGeo {
        let n = nodes * gpus_per_node;
        let none = LinkId(usize::MAX);
        NvSwitchGeo {
            nodes,
            gpus_per_node,
            coord_of: Vec::new(),
            nv_up: vec![none; n],
            nv_down: vec![none; n],
            hca_up: vec![none; n],
            hca_down: vec![none; n],
            core_up: vec![none; n],
            core_down: vec![none; n],
        }
    }

    fn resolve(&self, src: DeviceId, dst: DeviceId) -> Option<Vec<LinkId>> {
        let s = coord(&self.coord_of, src)?;
        let d = coord(&self.coord_of, dst)?;
        if s == d {
            return None;
        }
        if s / self.gpus_per_node == d / self.gpus_per_node {
            return Some(vec![self.nv_up[s], self.nv_down[d]]);
        }
        Some(vec![
            self.hca_up[s],
            self.core_up[s],
            self.core_down[d],
            self.hca_down[d],
        ])
    }
}

/// Dragonfly: groups of routers in a local full mesh; router 0 of each
/// group is the gateway carrying one global link per peer group.
/// Aggregating global ports on a gateway keeps minimal routing
/// provably min-hop (any detour through a third group costs a second
/// global hop), which is what lets BFS stay the exact golden reference.
#[derive(Debug, Clone)]
pub struct DragonflyGeo {
    pub groups: usize,
    pub routers_per_group: usize,
    pub gpus_per_router: usize,
    pub(super) coord_of: Vec<u32>,
    /// gpu -> its router.
    pub(super) gpu_up: Vec<LinkId>,
    /// router -> gpu.
    pub(super) gpu_down: Vec<LinkId>,
    /// intra-group mesh, per (group, src router, dst router); the
    /// diagonal holds `LinkId(usize::MAX)`.
    pub(super) local: Vec<LinkId>,
    /// gateway-to-gateway, per (src group, dst group); diagonal MAX.
    pub(super) global: Vec<LinkId>,
}

impl DragonflyGeo {
    pub(super) fn sized(groups: usize, routers_per_group: usize, gpus_per_router: usize) -> DragonflyGeo {
        let n = groups * routers_per_group * gpus_per_router;
        let none = LinkId(usize::MAX);
        DragonflyGeo {
            groups,
            routers_per_group,
            gpus_per_router,
            coord_of: Vec::new(),
            gpu_up: vec![none; n],
            gpu_down: vec![none; n],
            local: vec![none; groups * routers_per_group * routers_per_group],
            global: vec![none; groups * groups],
        }
    }

    pub(super) fn local_idx(&self, group: usize, from: usize, to: usize) -> usize {
        (group * self.routers_per_group + from) * self.routers_per_group + to
    }

    fn resolve(&self, src: DeviceId, dst: DeviceId) -> Option<Vec<LinkId>> {
        let s = coord(&self.coord_of, src)?;
        let d = coord(&self.coord_of, dst)?;
        if s == d {
            return None;
        }
        let per_group = self.routers_per_group * self.gpus_per_router;
        let (sg, dg) = (s / per_group, d / per_group);
        let sr = (s / self.gpus_per_router) % self.routers_per_group;
        let dr = (d / self.gpus_per_router) % self.routers_per_group;
        let mut hops = Vec::with_capacity(5);
        hops.push(self.gpu_up[s]);
        if sg == dg {
            if sr != dr {
                hops.push(self.local[self.local_idx(sg, sr, dr)]);
            }
        } else {
            if sr != 0 {
                hops.push(self.local[self.local_idx(sg, sr, 0)]);
            }
            hops.push(self.global[sg * self.groups + dg]);
            if dr != 0 {
                hops.push(self.local[self.local_idx(dg, 0, dr)]);
            }
        }
        hops.push(self.gpu_down[d]);
        Some(hops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_resolver_declines_everything() {
        let r = Resolver::Bfs;
        assert_eq!(r.kind(), TopologyKind::Generic);
        assert!(!r.is_algebraic());
        assert!(r.resolve(DeviceId(0), DeviceId(1)).is_none());
    }

    #[test]
    fn kind_names_unique() {
        let kinds = [
            TopologyKind::Generic,
            TopologyKind::FatTree,
            TopologyKind::RailOptimized,
            TopologyKind::NvSwitch,
            TopologyKind::Dragonfly,
        ];
        let mut names: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), kinds.len());
    }
}
