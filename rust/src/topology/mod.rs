//! Cluster topology model.
//!
//! Dense multi-GPU nodes are modelled as an explicit graph of devices
//! (GPUs, CPU sockets/host memory, PCIe root complexes, PLX switches,
//! InfiniBand HCAs and switches) connected by typed links (PCIe gen3,
//! PLX fan-out, QPI, NVLink, IB FDR/EDR). Broadcast performance on this
//! class of machine is dominated by *which* path a transfer takes — the
//! paper's wins come from avoiding bad paths (GDR reads across QPI) and
//! exploiting good ones (CUDA IPC under a PLX switch, dual-rail IB) — so
//! the topology layer exposes exactly those predicates.
//!
//! Presets: [`presets::kesch`] (the paper's Cray CS-Storm testbed),
//! [`presets::dgx1`], and [`presets::flat`] (the idealised uniform
//! fabric the paper's analytic models assume) resolve routes by BFS;
//! the datacenter-scale fabrics ([`presets::fat_tree`],
//! [`presets::rail_optimized`], [`presets::nvswitch`],
//! [`presets::dragonfly`]) install algebraic [`resolve::Resolver`]s
//! that compute routes from coordinates in O(path length) per pair.

pub mod cluster;
pub mod device;
pub mod link;
pub mod path;
pub mod presets;
pub mod resolve;

pub use cluster::{Cluster, NodeMeta};
pub use device::{Device, DeviceId, DeviceKind, NodeId};
pub use link::{Link, LinkId, LinkKind};
pub use path::{Route, RouteId, RouteMeta, RouteTable};
pub use resolve::{Resolver, TopologyKind};

use crate::config::schema::{ClusterConfig, ClusterPreset, FabricSpec};
use crate::error::Result;

/// Instantiate a cluster from a config.
pub fn build(config: &ClusterConfig) -> Result<Cluster> {
    config.validate()?;
    match config.preset {
        ClusterPreset::Kesch => presets::kesch(config.nodes, config.gpus_per_node),
        ClusterPreset::Dgx1 => presets::dgx1(config.nodes, config.gpus_per_node, false),
        ClusterPreset::Dgx1V => presets::dgx1(config.nodes, config.gpus_per_node, true),
        ClusterPreset::Flat => presets::flat(config.total_gpus()),
    }
}

/// Instantiate a structured fabric from a parsed `--topology` spec.
pub fn build_fabric(spec: &FabricSpec) -> Result<Cluster> {
    match *spec {
        FabricSpec::FatTree {
            pods,
            leaves_per_pod,
            gpus_per_leaf,
            rails,
            spines_per_pod,
        } => presets::fat_tree(pods, leaves_per_pod, gpus_per_leaf, rails, spines_per_pod),
        FabricSpec::RailOptimized {
            nodes,
            gpus_per_node,
        } => presets::rail_optimized(nodes, gpus_per_node),
        FabricSpec::NvSwitch {
            nodes,
            gpus_per_node,
        } => presets::nvswitch(nodes, gpus_per_node),
        FabricSpec::Dragonfly {
            groups,
            routers_per_group,
            gpus_per_router,
        } => presets::dragonfly(groups, routers_per_group, gpus_per_router),
    }
}
