//! Cluster topology model.
//!
//! Dense multi-GPU nodes are modelled as an explicit graph of devices
//! (GPUs, CPU sockets/host memory, PCIe root complexes, PLX switches,
//! InfiniBand HCAs and switches) connected by typed links (PCIe gen3,
//! PLX fan-out, QPI, NVLink, IB FDR/EDR). Broadcast performance on this
//! class of machine is dominated by *which* path a transfer takes — the
//! paper's wins come from avoiding bad paths (GDR reads across QPI) and
//! exploiting good ones (CUDA IPC under a PLX switch, dual-rail IB) — so
//! the topology layer exposes exactly those predicates.
//!
//! Presets: [`presets::kesch`] (the paper's Cray CS-Storm testbed),
//! [`presets::dgx1`], [`presets::dgx1v`], and [`presets::flat`] (the
//! idealised uniform fabric the paper's analytic models assume).

pub mod cluster;
pub mod device;
pub mod link;
pub mod path;
pub mod presets;

pub use cluster::{Cluster, NodeMeta};
pub use device::{Device, DeviceId, DeviceKind, NodeId};
pub use link::{Link, LinkId, LinkKind};
pub use path::{Route, RouteId, RouteMeta, RouteTable};

use crate::config::schema::{ClusterConfig, ClusterPreset};
use crate::error::Result;

/// Instantiate a cluster from a config.
pub fn build(config: &ClusterConfig) -> Result<Cluster> {
    config.validate()?;
    Ok(match config.preset {
        ClusterPreset::Kesch => presets::kesch(config.nodes, config.gpus_per_node),
        ClusterPreset::Dgx1 => presets::dgx1(config.nodes, config.gpus_per_node, false),
        ClusterPreset::Dgx1V => presets::dgx1(config.nodes, config.gpus_per_node, true),
        ClusterPreset::Flat => presets::flat(config.total_gpus()),
    })
}
