//! Links: the edges of the topology graph.
//!
//! All links are stored as *directed* edges (a physical full-duplex cable
//! becomes two directed links), so per-direction occupancy falls out of
//! the simulator naturally.
//!
//! Bandwidth constants are *effective* (post-protocol-overhead) figures
//! for the hardware generations in the paper's testbed; sources noted per
//! constant. Shapes, not absolute numbers, are what the reproduction is
//! judged on — see DESIGN.md §4 Calibration.

use super::device::DeviceId;

/// Index of a directed link within a [`super::Cluster`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub usize);

/// Physical technology of a link. Determines default bandwidth/latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// PCIe gen3 ×16: ~15.75 GB/s raw, ~12 GB/s effective for DMA.
    PcieG3x16,
    /// Intel QPI between sockets: crossing it costs bandwidth and breaks
    /// GPU peer access (the GDR-read bottleneck of [26] in the paper).
    Qpi,
    /// Host memory bus (socket ↔ its PCIe root): generous, rarely the
    /// bottleneck.
    HostBus,
    /// NVLink 1.0 (P100): 20 GB/s per direction per brick.
    NvLink1,
    /// NVLink 2.0 (V100): 25 GB/s per direction per brick.
    NvLink2,
    /// InfiniBand FDR (56 Gb/s): ~6.8 GB/s effective — KESCH's rails.
    IbFdr,
    /// InfiniBand EDR (100 Gb/s): ~12 GB/s effective.
    IbEdr,
    /// Idealised uniform link for the `flat` validation preset.
    Ideal,
}

impl LinkKind {
    /// Effective bandwidth in bytes/second.
    pub fn default_bandwidth(&self) -> f64 {
        const GB: f64 = 1.0e9;
        match self {
            LinkKind::PcieG3x16 => 12.0 * GB,
            LinkKind::Qpi => 8.0 * GB,
            LinkKind::HostBus => 25.0 * GB,
            LinkKind::NvLink1 => 18.0 * GB,
            LinkKind::NvLink2 => 22.0 * GB,
            LinkKind::IbFdr => 6.8 * GB,
            LinkKind::IbEdr => 12.0 * GB,
            LinkKind::Ideal => 10.0 * GB,
        }
    }

    /// Per-hop propagation/forwarding latency in nanoseconds.
    pub fn default_latency_ns(&self) -> u64 {
        match self {
            LinkKind::PcieG3x16 => 300,
            LinkKind::Qpi => 200,
            LinkKind::HostBus => 100,
            LinkKind::NvLink1 | LinkKind::NvLink2 => 150,
            LinkKind::IbFdr => 700,
            LinkKind::IbEdr => 600,
            LinkKind::Ideal => 0,
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            LinkKind::PcieG3x16 => "pcie3x16",
            LinkKind::Qpi => "qpi",
            LinkKind::HostBus => "hostbus",
            LinkKind::NvLink1 => "nvlink1",
            LinkKind::NvLink2 => "nvlink2",
            LinkKind::IbFdr => "ib-fdr",
            LinkKind::IbEdr => "ib-edr",
            LinkKind::Ideal => "ideal",
        }
    }
}

/// A directed edge of the fabric graph.
#[derive(Debug, Clone)]
pub struct Link {
    pub id: LinkId,
    pub src: DeviceId,
    pub dst: DeviceId,
    pub kind: LinkKind,
    /// Effective bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Propagation/forwarding latency, nanoseconds.
    pub latency_ns: u64,
}

impl Link {
    /// Time to push `bytes` through this link (transmission only), ns.
    /// A non-positive/NaN bandwidth saturates to the unreachable sentinel
    /// (see [`crate::netsim::time::tx_ns`]) instead of casting `inf`.
    #[inline]
    pub fn transmission_ns(&self, bytes: u64) -> u64 {
        crate::netsim::time::tx_ns(bytes, self.bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::device::DeviceId;

    #[test]
    fn fdr_slower_than_pcie() {
        assert!(LinkKind::IbFdr.default_bandwidth() < LinkKind::PcieG3x16.default_bandwidth());
    }

    #[test]
    fn transmission_time_scales_linearly() {
        let l = Link {
            id: LinkId(0),
            src: DeviceId(0),
            dst: DeviceId(1),
            kind: LinkKind::PcieG3x16,
            bandwidth: 12.0e9,
            latency_ns: 300,
        };
        let t1 = l.transmission_ns(1 << 20);
        let t2 = l.transmission_ns(2 << 20);
        assert!((t2 as f64 / t1 as f64 - 2.0).abs() < 0.01);
        // 1 MiB over 12 GB/s ≈ 87.4 µs
        assert!((t1 as f64 - 87_381.0).abs() < 200.0);
    }

    #[test]
    fn zero_bytes_zero_time() {
        let l = Link {
            id: LinkId(0),
            src: DeviceId(0),
            dst: DeviceId(1),
            kind: LinkKind::Ideal,
            bandwidth: 1.0e9,
            latency_ns: 0,
        };
        assert_eq!(l.transmission_ns(0), 0);
    }
}
