//! The cluster graph: devices + directed links + queries.

use std::collections::HashMap;

use super::device::{Device, DeviceId, DeviceKind, NodeId};
use super::link::{Link, LinkId, LinkKind};
use super::path::{self, Route, RouteId, RouteMeta, RouteTable};
use super::resolve::{Resolver, TopologyKind};
use crate::error::{Error, Result};

/// Per-chassis metadata.
#[derive(Debug, Clone)]
pub struct NodeMeta {
    pub id: NodeId,
    /// GPUs in this node, in local-rank order.
    pub gpus: Vec<DeviceId>,
    /// Host (socket) devices in this node.
    pub hosts: Vec<DeviceId>,
    /// HCAs in this node (one per rail).
    pub hcas: Vec<DeviceId>,
}

/// A fabric graph for one cluster instantiation.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    devices: Vec<Device>,
    links: Vec<Link>,
    /// Outgoing link ids per device.
    adjacency: Vec<Vec<LinkId>>,
    nodes: Vec<NodeMeta>,
    /// GPUs in global rank order (node-major).
    gpu_ranks: Vec<DeviceId>,
    /// Directed links administratively removed by [`Cluster::kill_link`].
    /// Dead links are skipped by BFS so re-planned routes avoid them —
    /// distinct from zero-bandwidth links, which stay routable and cost
    /// the `UNREACHABLE_NS` sentinel at execution time.
    dead_links: Vec<bool>,
    /// Interned routes: route resolution runs at most once per (src, dst)
    /// pair; plans and path caches carry cheap [`RouteId`]s
    /// (DESIGN.md §Perf).
    routes: RouteTable,
    /// How cold pairs are resolved before interning: coordinate
    /// arithmetic on structured fabrics, BFS everywhere else
    /// (DESIGN.md §Topologies & routing).
    resolver: Resolver,
}

impl Cluster {
    pub fn new(name: impl Into<String>) -> Cluster {
        Cluster {
            name: name.into(),
            devices: Vec::new(),
            links: Vec::new(),
            adjacency: Vec::new(),
            nodes: Vec::new(),
            gpu_ranks: Vec::new(),
            dead_links: Vec::new(),
            routes: RouteTable::new(),
            resolver: Resolver::Bfs,
        }
    }

    // ---- construction ---------------------------------------------------

    pub fn add_device(&mut self, kind: DeviceKind, node: NodeId, socket: u8, name: String) -> DeviceId {
        self.routes.clear();
        // an arbitrary structural mutation invalidates any algebraic
        // geometry (generators install their resolver after wiring)
        self.resolver = Resolver::Bfs;
        let id = DeviceId(self.devices.len());
        self.devices.push(Device {
            id,
            kind,
            node,
            socket,
            name,
        });
        self.adjacency.push(Vec::new());
        id
    }

    /// Add a full-duplex link (two directed edges) with the kind's default
    /// bandwidth and latency.
    pub fn connect(&mut self, a: DeviceId, b: DeviceId, kind: LinkKind) -> (LinkId, LinkId) {
        self.connect_custom(a, b, kind, kind.default_bandwidth(), kind.default_latency_ns())
    }

    /// Add a full-duplex link with explicit bandwidth/latency.
    pub fn connect_custom(
        &mut self,
        a: DeviceId,
        b: DeviceId,
        kind: LinkKind,
        bandwidth: f64,
        latency_ns: u64,
    ) -> (LinkId, LinkId) {
        let fwd = self.push_link(a, b, kind, bandwidth, latency_ns);
        let rev = self.push_link(b, a, kind, bandwidth, latency_ns);
        (fwd, rev)
    }

    fn push_link(
        &mut self,
        src: DeviceId,
        dst: DeviceId,
        kind: LinkKind,
        bandwidth: f64,
        latency_ns: u64,
    ) -> LinkId {
        self.routes.clear();
        self.resolver = Resolver::Bfs;
        let id = LinkId(self.links.len());
        self.links.push(Link {
            id,
            src,
            dst,
            kind,
            bandwidth,
            latency_ns,
        });
        self.adjacency[src.0].push(id);
        self.dead_links.push(false);
        id
    }

    pub fn push_node_meta(&mut self, meta: NodeMeta) {
        for &g in &meta.gpus {
            self.gpu_ranks.push(g);
        }
        self.nodes.push(meta);
    }

    // ---- recovery mutations ----------------------------------------------

    /// Administratively remove a directed link from the routable topology.
    /// BFS will never traverse it again, so every route interned after this
    /// call detours around the failure. Bumps the topology generation:
    /// existing `RouteId`s, engines and templates keyed on the old
    /// generation must be rebuilt.
    pub fn kill_link(&mut self, id: LinkId) -> Result<()> {
        if id.0 >= self.links.len() {
            return Err(Error::Config(format!(
                "kill_link: link index {} out of range (cluster has {} directed links)",
                id.0,
                self.links.len()
            )));
        }
        self.routes.clear();
        self.dead_links[id.0] = true;
        Ok(())
    }

    /// Whether a directed link is still routable (not removed by
    /// [`Cluster::kill_link`]).
    pub fn link_alive(&self, id: LinkId) -> bool {
        !self.dead_links[id.0]
    }

    /// Count of administratively dead directed links.
    pub fn n_dead_links(&self) -> usize {
        self.dead_links.iter().filter(|&&d| d).count()
    }

    /// Shrink the communicator to a subset of the current ranks: `alive`
    /// holds rank indices into the *current* rank order, in ascending
    /// order. Surviving GPUs are renumbered densely (rank `i` becomes the
    /// `i`-th surviving GPU); dead GPUs stay in the device graph but no
    /// longer back any rank. Bumps the topology generation.
    pub fn retain_ranks(&mut self, alive: &[usize]) -> Result<()> {
        if alive.is_empty() {
            return Err(Error::InvalidRanks("retain_ranks: empty rank set".into()));
        }
        if alive.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::InvalidRanks(
                "retain_ranks: rank set must be strictly ascending".into(),
            ));
        }
        if *alive.last().unwrap() >= self.gpu_ranks.len() {
            return Err(Error::InvalidRanks(format!(
                "retain_ranks: rank {} out of range (world size {})",
                alive.last().unwrap(),
                self.gpu_ranks.len()
            )));
        }
        self.routes.clear();
        let kept: Vec<DeviceId> = alive.iter().map(|&r| self.gpu_ranks[r]).collect();
        for meta in &mut self.nodes {
            meta.gpus.retain(|g| kept.contains(g));
        }
        self.gpu_ranks = kept;
        Ok(())
    }

    // ---- queries ---------------------------------------------------------

    pub fn device(&self, id: DeviceId) -> &Device {
        &self.devices[id.0]
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    pub fn nodes(&self) -> &[NodeMeta] {
        &self.nodes
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// GPUs in global MPI-rank order (node-major, then socket/PLX order).
    pub fn gpu_ranks(&self) -> &[DeviceId] {
        &self.gpu_ranks
    }

    pub fn n_gpus(&self) -> usize {
        self.gpu_ranks.len()
    }

    /// The GPU device backing MPI rank `r`.
    pub fn rank_device(&self, rank: usize) -> DeviceId {
        self.gpu_ranks[rank]
    }

    /// The host (socket) device a given device should stage through: the
    /// host on the same socket of the same node.
    pub fn staging_host(&self, dev: DeviceId) -> Result<DeviceId> {
        let d = self.device(dev);
        let node = self
            .nodes
            .get(d.node.0)
            .ok_or(Error::UnknownDevice(dev.0))?;
        node.hosts
            .iter()
            .copied()
            .find(|&h| self.device(h).socket == d.socket)
            .or_else(|| node.hosts.first().copied())
            .ok_or(Error::NoRoute { src: dev.0, dst: dev.0 })
    }

    /// The HCA a GPU uses for internode traffic: same-socket rail first
    /// (multi-rail policy), falling back to any rail in the node.
    pub fn hca_for(&self, dev: DeviceId) -> Result<DeviceId> {
        let d = self.device(dev);
        let node = self
            .nodes
            .get(d.node.0)
            .ok_or(Error::UnknownDevice(dev.0))?;
        node.hcas
            .iter()
            .copied()
            .find(|&h| self.device(h).socket == d.socket)
            .or_else(|| node.hcas.first().copied())
            .ok_or(Error::NoRoute { src: dev.0, dst: dev.0 })
    }

    pub fn same_node(&self, a: DeviceId, b: DeviceId) -> bool {
        self.device(a).node == self.device(b).node
    }

    pub fn same_socket(&self, a: DeviceId, b: DeviceId) -> bool {
        self.same_node(a, b) && self.device(a).socket == self.device(b).socket
    }

    /// CUDA peer access: two GPUs can do P2P DMA iff a route exists that
    /// stays inside the PCIe/NVLink fabric of one PCIe domain — i.e. the
    /// shortest route crosses neither a Host device nor a QPI link.
    /// (Crossing QPI is exactly the GDR-read bottleneck case of the
    /// paper's ref. [26].)
    pub fn peer_access(&self, a: DeviceId, b: DeviceId) -> bool {
        if !self.same_node(a, b) || a == b {
            return false;
        }
        match self.route(a, b) {
            Ok(id) => !self.route_hops(id).iter().any(|&l| {
                self.link(l).kind == LinkKind::Qpi
                    || self.device(self.link(l).dst).kind == DeviceKind::Host
                    || self.device(self.link(l).src).kind == DeviceKind::Host
            }),
            Err(_) => false,
        }
    }

    // ---- routes ----------------------------------------------------------

    /// Shortest route (min hops, tie-broken by max bottleneck bandwidth)
    /// from `src` to `dst`, as an interned [`RouteId`]: a cached lookup
    /// after the first call per pair — resolution runs at most once per
    /// (src, dst). Structured fabrics resolve by coordinate arithmetic
    /// ([`Resolver`]); BFS covers everything the resolver declines, and
    /// any algebraic route that would cross a link removed by
    /// [`Cluster::kill_link`] falls back to BFS so recovery re-routes
    /// around the failure.
    pub fn route(&self, src: DeviceId, dst: DeviceId) -> Result<RouteId> {
        if src.0 >= self.devices.len() {
            return Err(Error::UnknownDevice(src.0));
        }
        if dst.0 >= self.devices.len() {
            return Err(Error::UnknownDevice(dst.0));
        }
        if let Some(id) = self.routes.lookup(src, dst) {
            return Ok(id);
        }
        if src == dst {
            return Ok(self.routes.insert(src, dst, &[], f64::INFINITY, 0));
        }
        let hops = match self.resolver.resolve(src, dst) {
            Some(h) if h.iter().all(|&l| !self.dead_links[l.0]) => h,
            _ => self.bfs(src, dst)?,
        };
        let (bw, lat) = path::aggregates(&hops, self);
        Ok(self.routes.insert(src, dst, &hops, bw, lat))
    }

    /// Route that explicitly passes through `via` (e.g. staging host),
    /// interned under its own (src, via, dst) key.
    pub fn route_via(&self, src: DeviceId, via: DeviceId, dst: DeviceId) -> Result<RouteId> {
        if let Some(id) = self.routes.lookup_via(src, via, dst) {
            return Ok(id);
        }
        let a = self.route(src, via)?;
        let b = self.route(via, dst)?;
        let mut hops: Vec<LinkId> = self.route_hops(a).to_vec();
        hops.extend_from_slice(&self.route_hops(b));
        let (bw, lat) = path::aggregates(&hops, self);
        Ok(self.routes.insert_via(src, via, dst, &hops, bw, lat))
    }

    /// The intern table itself (cache metrics, tests).
    pub fn routes(&self) -> &RouteTable {
        &self.routes
    }

    // ---- resolver seam ---------------------------------------------------

    /// Install an algebraic resolver. Generators call this once, after
    /// wiring the graph; the route cache is dropped so nothing interned
    /// under BFS survives the switch.
    pub(super) fn set_resolver(&mut self, resolver: Resolver) {
        self.routes.clear();
        self.resolver = resolver;
    }

    /// The active route resolution strategy.
    pub fn resolver(&self) -> &Resolver {
        &self.resolver
    }

    /// Which structured fabric family this cluster belongs to
    /// (`Generic` when routes come from BFS). Template caches key on it.
    pub fn topology_kind(&self) -> TopologyKind {
        self.resolver.kind()
    }

    /// Whether routes come from coordinate arithmetic rather than BFS.
    pub fn has_algebraic_resolver(&self) -> bool {
        self.resolver.is_algebraic()
    }

    /// Drop the algebraic resolver and re-resolve everything by BFS —
    /// the golden reference for parity tests. Bumps the generation.
    pub fn force_bfs_resolver(&mut self) {
        self.set_resolver(Resolver::Bfs);
    }

    /// Test-only: intern an arbitrary hop chain as the (src, dst) route,
    /// bypassing resolver and BFS — lets the verifier's broken-path
    /// check (PL017) be exercised without building a buggy resolver.
    #[cfg(test)]
    pub fn intern_raw_route_for_test(
        &self,
        src: DeviceId,
        dst: DeviceId,
        hops: &[LinkId],
    ) -> RouteId {
        let (bw, lat) = path::aggregates(hops, self);
        self.routes.insert(src, dst, hops, bw, lat)
    }

    /// Rank blocks for hierarchical (intra-stage / inter-stage)
    /// collectives: leaf blocks on fat-tree, group blocks on dragonfly,
    /// node blocks everywhere else. Blocks are contiguous in rank order
    /// by construction, and any partition remains functionally valid
    /// after `retain_ranks` renumbering.
    pub fn rank_groups(&self) -> Vec<Vec<usize>> {
        let n = self.gpu_ranks.len();
        let block = match &self.resolver {
            Resolver::FatTree(g) => g.gpus_per_leaf,
            Resolver::Dragonfly(g) => g.routers_per_group * g.gpus_per_router,
            _ => 0,
        };
        if block > 1 {
            let mut groups = Vec::with_capacity(n.div_ceil(block));
            let mut start = 0;
            while start < n {
                let end = (start + block).min(n);
                groups.push((start..end).collect());
                start = end;
            }
            return groups;
        }
        // node-major default: exactly the NodeMeta grouping, in rank order
        let mut rank_of: HashMap<DeviceId, usize> = HashMap::new();
        for (i, &g) in self.gpu_ranks.iter().enumerate() {
            rank_of.insert(g, i);
        }
        self.nodes
            .iter()
            .map(|m| {
                m.gpus
                    .iter()
                    .filter_map(|g| rank_of.get(g).copied())
                    .collect::<Vec<usize>>()
            })
            .filter(|v| !v.is_empty())
            .collect()
    }

    /// Topology generation: bumped by `add_device`/`connect`. Anything
    /// derived from the graph (routes, engine scratch, plan templates)
    /// keys on this to fail fast — or miss — after a mutation.
    pub fn generation(&self) -> u32 {
        self.routes.generation()
    }

    /// Cached aggregates of an interned route, by value (hot path).
    pub fn route_meta(&self, id: RouteId) -> RouteMeta {
        self.routes.meta(id)
    }

    /// Whether an interned `RouteId` still resolves against the current
    /// topology generation (the static verifier's stale-route probe).
    pub fn route_current(&self, id: RouteId) -> bool {
        self.routes.is_current(id)
    }

    /// Hop list of an interned route, borrowed from the arena (hot path —
    /// no copy). Drop the guard before any call that may intern
    /// (`route`, `route_via`, `peer_access` on a cold pair): interning
    /// while the guard is held panics with a `RefCell` borrow error —
    /// fail-fast rather than serving a reallocated arena.
    pub fn route_hops(&self, id: RouteId) -> std::cell::Ref<'_, [LinkId]> {
        self.routes.hops(id)
    }

    /// Uncontended transfer estimate along an interned route, ns.
    pub fn route_uncontended_ns(&self, id: RouteId, bytes: u64) -> u64 {
        self.routes.meta(id).uncontended_ns(bytes)
    }

    /// Materialize an interned route into an owning [`Route`] view
    /// (display, tests — not the hot path).
    pub fn route_view(&self, id: RouteId) -> Route {
        let meta = self.routes.meta(id);
        Route {
            src: meta.src,
            dst: meta.dst,
            hops: self.route_hops(id).to_vec(),
            bottleneck_bw: meta.bottleneck_bw,
            latency_ns: meta.latency_ns,
        }
    }

    /// Shortest route materialized as an owning [`Route`] (convenience
    /// for tests and inspection).
    pub fn route_info(&self, src: DeviceId, dst: DeviceId) -> Result<Route> {
        Ok(self.route_view(self.route(src, dst)?))
    }

    /// BFS layers; among equal-hop predecessors keep the one maximising
    /// the bottleneck bandwidth so routes prefer fat paths.
    fn bfs(&self, src: DeviceId, dst: DeviceId) -> Result<Vec<LinkId>> {
        let n = self.devices.len();
        let mut dist: Vec<u32> = vec![u32::MAX; n];
        let mut best_bw: Vec<f64> = vec![0.0; n];
        let mut pred: Vec<Option<LinkId>> = vec![None; n];
        dist[src.0] = 0;
        best_bw[src.0] = f64::INFINITY;
        let mut frontier = vec![src];
        while !frontier.is_empty() && dist[dst.0] == u32::MAX {
            let mut next: Vec<DeviceId> = Vec::new();
            for &u in &frontier {
                let du = dist[u.0];
                for &lid in &self.adjacency[u.0] {
                    if self.dead_links[lid.0] {
                        continue;
                    }
                    let link = &self.links[lid.0];
                    let v = link.dst;
                    let bw = best_bw[u.0].min(link.bandwidth);
                    if dist[v.0] == u32::MAX {
                        dist[v.0] = du + 1;
                        best_bw[v.0] = bw;
                        pred[v.0] = Some(lid);
                        next.push(v);
                    } else if dist[v.0] == du + 1 && bw > best_bw[v.0] {
                        best_bw[v.0] = bw;
                        pred[v.0] = Some(lid);
                    }
                }
            }
            frontier = next;
        }
        if dist[dst.0] == u32::MAX {
            return Err(Error::NoRoute {
                src: src.0,
                dst: dst.0,
            });
        }
        let mut hops = Vec::new();
        let mut cur = dst;
        while cur != src {
            let lid = pred[cur.0].expect("pred chain broken");
            hops.push(lid);
            cur = self.links[lid.0].src;
        }
        hops.reverse();
        Ok(hops)
    }

    /// Total directed-link count between every adjacent device pair —
    /// sanity metric used by tests.
    pub fn degree(&self, dev: DeviceId) -> usize {
        self.adjacency[dev.0].len()
    }

    /// Dump a human-readable topology description.
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "cluster '{}': {} nodes, {} gpus, {} devices, {} directed links",
            self.name,
            self.nodes.len(),
            self.gpu_ranks.len(),
            self.devices.len(),
            self.links.len()
        );
        let mut kind_counts: HashMap<&'static str, usize> = HashMap::new();
        for d in &self.devices {
            *kind_counts.entry(d.kind.short()).or_insert(0) += 1;
        }
        let mut kinds: Vec<_> = kind_counts.into_iter().collect();
        kinds.sort();
        for (k, c) in kinds {
            let _ = writeln!(out, "  {k:>6} x{c}");
        }
        let mut link_counts: HashMap<&'static str, usize> = HashMap::new();
        for l in &self.links {
            *link_counts.entry(l.kind.short()).or_insert(0) += 1;
        }
        let mut lks: Vec<_> = link_counts.into_iter().collect();
        lks.sort();
        for (k, c) in lks {
            let _ = writeln!(out, "  link {k:>9} x{c}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cluster {
        // gpu0 -- plx -- gpu1, plx -- root -- host
        let mut c = Cluster::new("tiny");
        let g0 = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "g0".into());
        let g1 = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "g1".into());
        let plx = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "plx".into());
        let root = c.add_device(DeviceKind::PcieRoot, NodeId(0), 0, "root".into());
        let host = c.add_device(DeviceKind::Host, NodeId(0), 0, "host".into());
        c.connect(g0, plx, LinkKind::PcieG3x16);
        c.connect(g1, plx, LinkKind::PcieG3x16);
        c.connect(plx, root, LinkKind::PcieG3x16);
        c.connect(root, host, LinkKind::HostBus);
        c.push_node_meta(NodeMeta {
            id: NodeId(0),
            gpus: vec![g0, g1],
            hosts: vec![host],
            hcas: vec![],
        });
        c
    }

    #[test]
    fn route_gpu_to_gpu() {
        let c = tiny();
        let r = c.route_info(DeviceId(0), DeviceId(1)).unwrap();
        assert_eq!(r.hops.len(), 2); // g0->plx->g1
        assert_eq!(r.src, DeviceId(0));
        assert_eq!(r.dst, DeviceId(1));
    }

    #[test]
    fn trivial_route() {
        let c = tiny();
        let r = c.route_info(DeviceId(0), DeviceId(0)).unwrap();
        assert!(r.hops.is_empty());
    }

    #[test]
    fn peer_access_under_plx() {
        let c = tiny();
        assert!(c.peer_access(DeviceId(0), DeviceId(1)));
        assert!(!c.peer_access(DeviceId(0), DeviceId(0)));
    }

    #[test]
    fn staging_host_found() {
        let c = tiny();
        let h = c.staging_host(DeviceId(0)).unwrap();
        assert_eq!(c.device(h).kind, DeviceKind::Host);
    }

    #[test]
    fn route_via_concatenates() {
        let c = tiny();
        let host = c.staging_host(DeviceId(0)).unwrap();
        let id = c.route_via(DeviceId(0), host, DeviceId(1)).unwrap();
        // g0->plx->root->host->root->plx->g1
        assert_eq!(c.route_view(id).hops.len(), 6);
        // the via-route is cached under its own key
        assert_eq!(c.route_via(DeviceId(0), host, DeviceId(1)).unwrap(), id);
    }

    #[test]
    fn unknown_device_errors() {
        let c = tiny();
        assert!(c.route(DeviceId(0), DeviceId(99)).is_err());
    }

    #[test]
    fn describe_mentions_counts() {
        let d = tiny().describe();
        assert!(d.contains("2 gpus"));
    }

    #[test]
    fn kill_link_detours_and_bumps_generation() {
        // diamond: a -> {b, c} -> d, two equal-hop routes
        let mut c = Cluster::new("diamond");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "b".into());
        let cc = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "c".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        // fat path through b, thin through c: BFS prefers b
        let (ab, _) = c.connect_custom(a, b, LinkKind::PcieG3x16, 32.0, 0);
        c.connect_custom(b, d, LinkKind::PcieG3x16, 32.0, 0);
        c.connect_custom(a, cc, LinkKind::PcieG3x16, 1.0, 0);
        c.connect_custom(cc, d, LinkKind::PcieG3x16, 1.0, 0);
        let via_b = c.route_info(a, d).unwrap();
        assert!(via_b.hops.contains(&ab));
        let g0 = c.generation();
        c.kill_link(ab).unwrap();
        assert_ne!(c.generation(), g0, "kill_link must bump the generation");
        assert!(!c.link_alive(ab));
        assert_eq!(c.n_dead_links(), 1);
        let via_c = c.route_info(a, d).unwrap();
        assert!(!via_c.hops.contains(&ab), "route must avoid the dead link");
        assert_eq!(via_c.hops.len(), 2);
    }

    #[test]
    fn kill_link_out_of_range_errors() {
        let mut c = tiny();
        let err = c.kill_link(LinkId(999)).unwrap_err();
        assert!(err.to_string().contains("out of range"));
    }

    #[test]
    fn retain_ranks_renumbers_surviving_gpus() {
        let mut c = tiny();
        let g1 = c.rank_device(1);
        let g0_old = c.generation();
        c.retain_ranks(&[1]).unwrap();
        assert_eq!(c.n_gpus(), 1);
        assert_eq!(c.rank_device(0), g1);
        assert_eq!(c.nodes()[0].gpus, vec![g1]);
        assert_ne!(c.generation(), g0_old);
    }

    #[test]
    fn retain_ranks_validates() {
        let mut c = tiny();
        assert!(c.retain_ranks(&[]).is_err());
        assert!(c.retain_ranks(&[1, 0]).is_err());
        assert!(c.retain_ranks(&[0, 7]).is_err());
        // original rank set untouched after rejected calls
        assert_eq!(c.n_gpus(), 2);
    }
}
