//! Cluster presets.
//!
//! * [`kesch`] — the paper's testbed: Cray CS-Storm, 12 nodes, 8× K80
//!   boards (16 CUDA devices) per node, dual-rail IB FDR.
//! * [`dgx1`] — NVIDIA DGX-1(V): 8 GPUs, NVLink cube mesh, IB EDR.
//! * [`flat`] — the idealised uniform fabric assumed by the paper's
//!   analytic models (§III): every rank pair communicates at the same
//!   (t_s, B); used to validate simulator vs closed forms.

use super::cluster::{Cluster, NodeMeta};
use super::device::{DeviceId, DeviceKind, NodeId};
use super::link::LinkKind;

/// Build a KESCH-like cluster.
///
/// Per node: 2 sockets; per socket: host + PCIe root + 1 IB FDR HCA +
/// 2 PLX switches; per PLX: 4 CUDA devices (2 K80 boards). 16 CUDA
/// devices/node total, enumerated socket-major then PLX-major, which is
/// also the MPI rank order used in the paper's runs.
///
/// `gpus_per_node` ≤ 16 selects a prefix of that enumeration (the paper's
/// 2/4/8-GPU intranode configurations).
pub fn kesch(nodes: usize, gpus_per_node: usize) -> Cluster {
    assert!(gpus_per_node >= 1 && gpus_per_node <= 16);
    let mut c = Cluster::new(format!("kesch-{nodes}x{gpus_per_node}"));
    let ib_switch = c.add_device(
        DeviceKind::IbSwitch,
        NodeId(usize::MAX),
        0,
        "ibsw".into(),
    );
    for n in 0..nodes {
        let node = NodeId(n);
        let mut gpus: Vec<DeviceId> = Vec::new();
        let mut hosts = Vec::new();
        let mut hcas = Vec::new();
        for s in 0..2u8 {
            let host = c.add_device(DeviceKind::Host, node, s, format!("n{n}.s{s}.host"));
            let root = c.add_device(DeviceKind::PcieRoot, node, s, format!("n{n}.s{s}.root"));
            c.connect(host, root, LinkKind::HostBus);
            hosts.push(host);
            // one FDR rail per socket (dual-rail node)
            let hca = c.add_device(DeviceKind::IbHca, node, s, format!("n{n}.s{s}.hca"));
            c.connect(root, hca, LinkKind::PcieG3x16);
            c.connect(hca, ib_switch, LinkKind::IbFdr);
            hcas.push(hca);
            for p in 0..2usize {
                let plx = c.add_device(
                    DeviceKind::PlxSwitch,
                    node,
                    s,
                    format!("n{n}.s{s}.plx{p}"),
                );
                c.connect(plx, root, LinkKind::PcieG3x16);
                for g in 0..4usize {
                    let gpu = c.add_device(
                        DeviceKind::Gpu,
                        node,
                        s,
                        format!("n{n}.s{s}.plx{p}.gpu{g}"),
                    );
                    c.connect(gpu, plx, LinkKind::PcieG3x16);
                    gpus.push(gpu);
                }
            }
        }
        // QPI between the two sockets' hosts
        c.connect(hosts[0], hosts[1], LinkKind::Qpi);
        gpus.truncate(gpus_per_node);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts,
            hcas,
        });
    }
    c
}

/// Build a DGX-1 (`v100 = false`) or DGX-1V (`v100 = true`) cluster.
///
/// 8 GPUs per node in an NVLink hybrid cube-mesh (each GPU has 4 NVLink
/// bricks; the mesh connects GPU i to i^1, i^2, i^4 and the ring partner),
/// plus the PCIe tree (2 sockets × 2 PLX × 2 GPUs) and 4 IB EDR rails.
pub fn dgx1(nodes: usize, gpus_per_node: usize, v100: bool) -> Cluster {
    assert!(gpus_per_node >= 1 && gpus_per_node <= 8);
    let nv = if v100 {
        LinkKind::NvLink2
    } else {
        LinkKind::NvLink1
    };
    let mut c = Cluster::new(format!(
        "dgx1{}-{nodes}x{gpus_per_node}",
        if v100 { "v" } else { "" }
    ));
    let ib_switch = c.add_device(
        DeviceKind::IbSwitch,
        NodeId(usize::MAX),
        0,
        "ibsw".into(),
    );
    for n in 0..nodes {
        let node = NodeId(n);
        let mut gpus = Vec::new();
        let mut hosts = Vec::new();
        let mut hcas = Vec::new();
        for s in 0..2u8 {
            let host = c.add_device(DeviceKind::Host, node, s, format!("n{n}.s{s}.host"));
            let root = c.add_device(DeviceKind::PcieRoot, node, s, format!("n{n}.s{s}.root"));
            c.connect(host, root, LinkKind::HostBus);
            hosts.push(host);
            for p in 0..2usize {
                let plx = c.add_device(
                    DeviceKind::PlxSwitch,
                    node,
                    s,
                    format!("n{n}.s{s}.plx{p}"),
                );
                c.connect(plx, root, LinkKind::PcieG3x16);
                // one EDR HCA per PLX (4 rails/node, as in DGX-1)
                let hca = c.add_device(DeviceKind::IbHca, node, s, format!("n{n}.s{s}.hca{p}"));
                c.connect(plx, hca, LinkKind::PcieG3x16);
                c.connect(hca, ib_switch, LinkKind::IbEdr);
                hcas.push(hca);
                for g in 0..2usize {
                    let gpu = c.add_device(
                        DeviceKind::Gpu,
                        node,
                        s,
                        format!("n{n}.s{s}.plx{p}.gpu{g}"),
                    );
                    c.connect(gpu, plx, LinkKind::PcieG3x16);
                    gpus.push(gpu);
                }
            }
        }
        c.connect(hosts[0], hosts[1], LinkKind::Qpi);
        // NVLink hybrid cube-mesh over the 8 GPUs
        let mesh: &[(usize, usize)] = &[
            (0, 1),
            (0, 2),
            (0, 3),
            (1, 2),
            (1, 3),
            (2, 3),
            (4, 5),
            (4, 6),
            (4, 7),
            (5, 6),
            (5, 7),
            (6, 7),
            (0, 4),
            (1, 5),
            (2, 6),
            (3, 7),
        ];
        for &(a, b) in mesh {
            if a < gpus.len() && b < gpus.len() {
                c.connect(gpus[a], gpus[b], nv);
            }
        }
        gpus.truncate(gpus_per_node);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts,
            hcas,
        });
    }
    c
}

/// Build the idealised flat fabric: `n` GPUs, each with a dedicated
/// full-duplex `Ideal` link into a single crossbar, zero propagation
/// latency. A transfer between any pair costs exactly `bytes / B` plus
/// whatever protocol overhead the comm layer adds — i.e. the `t_s + M/B`
/// of the paper's Eqs. (1)–(5).
pub fn flat(n: usize) -> Cluster {
    assert!(n >= 1);
    let mut c = Cluster::new(format!("flat-{n}"));
    let xbar = c.add_device(DeviceKind::IbSwitch, NodeId(usize::MAX), 0, "xbar".into());
    // one pseudo-node per GPU so every pair is "internode"
    for i in 0..n {
        let node = NodeId(i);
        let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("g{i}"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("h{i}"));
        c.connect(gpu, xbar, LinkKind::Ideal);
        c.connect(gpu, host, LinkKind::HostBus);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus: vec![gpu],
            hosts: vec![host],
            hcas: vec![],
        });
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kesch_shape() {
        let c = kesch(12, 16);
        assert_eq!(c.n_nodes(), 12);
        assert_eq!(c.n_gpus(), 192);
        // per node: 2 hosts + 2 roots + 2 hcas + 4 plx + 16 gpus = 26
        assert_eq!(c.n_devices(), 12 * 26 + 1);
    }

    #[test]
    fn kesch_gpu_prefix() {
        let c = kesch(1, 2);
        assert_eq!(c.n_gpus(), 2);
        // first two GPUs share a PLX -> peer access
        let (a, b) = (c.rank_device(0), c.rank_device(1));
        assert!(c.peer_access(a, b));
    }

    #[test]
    fn kesch_cross_socket_no_peer_access() {
        let c = kesch(1, 16);
        let a = c.rank_device(0); // socket 0
        let b = c.rank_device(8); // socket 1
        assert!(!c.same_socket(a, b));
        assert!(!c.peer_access(a, b));
        // same socket, different PLX: route crosses the PCIe root but not
        // the host, so peer access holds
        let d = c.rank_device(4);
        assert!(c.peer_access(a, d));
    }

    #[test]
    fn kesch_internode_route_uses_ib() {
        let c = kesch(2, 16);
        let a = c.rank_device(0);
        let b = c.rank_device(16);
        assert!(!c.same_node(a, b));
        let r = c.route_info(a, b).unwrap();
        let has_ib = r
            .hops
            .iter()
            .any(|&l| c.link(l).kind == LinkKind::IbFdr);
        assert!(has_ib);
        // bottleneck is the FDR rail
        assert_eq!(r.bottleneck_bw, LinkKind::IbFdr.default_bandwidth());
    }

    #[test]
    fn kesch_multirail_hca_per_socket() {
        let c = kesch(1, 16);
        let g0 = c.rank_device(0);
        let g8 = c.rank_device(8);
        let h0 = c.hca_for(g0).unwrap();
        let h8 = c.hca_for(g8).unwrap();
        assert_ne!(h0, h8, "sockets use distinct rails");
    }

    #[test]
    fn dgx1_nvlink_peer() {
        let c = dgx1(1, 8, false);
        assert_eq!(c.n_gpus(), 8);
        let r = c.route_info(c.rank_device(0), c.rank_device(1)).unwrap();
        assert_eq!(r.n_hops(), 1, "NVLink direct");
        assert_eq!(r.bottleneck_bw, LinkKind::NvLink1.default_bandwidth());
    }

    #[test]
    fn dgx1v_uses_nvlink2() {
        let c = dgx1(1, 8, true);
        let r = c.route_info(c.rank_device(0), c.rank_device(4)).unwrap();
        assert_eq!(r.bottleneck_bw, LinkKind::NvLink2.default_bandwidth());
    }

    #[test]
    fn flat_uniform() {
        let c = flat(8);
        assert_eq!(c.n_gpus(), 8);
        for i in 1..8 {
            let r = c.route_info(c.rank_device(0), c.rank_device(i)).unwrap();
            assert_eq!(r.n_hops(), 2);
            assert_eq!(r.latency_ns, 0);
            assert_eq!(r.bottleneck_bw, LinkKind::Ideal.default_bandwidth());
        }
    }

    #[test]
    fn rank_order_is_node_major() {
        let c = kesch(2, 4);
        assert_eq!(c.device(c.rank_device(0)).node, NodeId(0));
        assert_eq!(c.device(c.rank_device(4)).node, NodeId(1));
    }
}
