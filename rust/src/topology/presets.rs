//! Cluster presets.
//!
//! Paper-scale machines (BFS-resolved):
//!
//! * [`kesch`] — the paper's testbed: Cray CS-Storm, 12 nodes, 8× K80
//!   boards (16 CUDA devices) per node, dual-rail IB FDR.
//! * [`dgx1`] — NVIDIA DGX-1(V): 8 GPUs, NVLink cube mesh, IB EDR.
//! * [`flat`] — the idealised uniform fabric assumed by the paper's
//!   analytic models (§III): every rank pair communicates at the same
//!   (t_s, B); used to validate simulator vs closed forms.
//!
//! Datacenter-scale structured fabrics (algebraic resolvers, 1k–64k
//! GPUs; see DESIGN.md §Topologies & routing):
//!
//! * [`fat_tree`] — multi-rail three-tier fat-tree (leaf / pod spine /
//!   core), one pseudo-node per GPU.
//! * [`rail_optimized`] — NVSwitch nodes whose GPU *i* HCAs all uplink
//!   to rail switch *i* (NCCL-style rail alignment).
//! * [`nvswitch`] — NVSwitch full-mesh nodes behind a single IB core.
//! * [`dragonfly`] — router groups in a local full mesh with one
//!   gateway-attached global link per group pair.
//!
//! All constructors validate their parameters and return a typed
//! [`Error::Usage`] instead of building degenerate clusters.

use super::cluster::{Cluster, NodeMeta};
use super::device::{DeviceId, DeviceKind, NodeId};
use super::link::{LinkId, LinkKind};
use super::resolve::{DragonflyGeo, FatTreeGeo, NvSwitchGeo, RailGeo, Resolver};
use crate::error::{Error, Result};

/// Largest GPU count any structured generator will build — a guard
/// against typo'd parameters allocating the machine away, not a
/// simulator limit.
pub const MAX_FABRIC_GPUS: usize = 1 << 20;

fn require(ok: bool, msg: impl FnOnce() -> String) -> Result<()> {
    if ok {
        Ok(())
    } else {
        Err(Error::Usage(msg()))
    }
}

/// Build a KESCH-like cluster.
///
/// Per node: 2 sockets; per socket: host + PCIe root + 1 IB FDR HCA +
/// 2 PLX switches; per PLX: 4 CUDA devices (2 K80 boards). 16 CUDA
/// devices/node total, enumerated socket-major then PLX-major, which is
/// also the MPI rank order used in the paper's runs.
///
/// `gpus_per_node` ≤ 16 selects a prefix of that enumeration (the paper's
/// 2/4/8-GPU intranode configurations).
pub fn kesch(nodes: usize, gpus_per_node: usize) -> Result<Cluster> {
    require(nodes >= 1, || "kesch: nodes must be >= 1".into())?;
    require((1..=16).contains(&gpus_per_node), || {
        format!("kesch: gpus_per_node must be in 1..=16 (got {gpus_per_node})")
    })?;
    let mut c = Cluster::new(format!("kesch-{nodes}x{gpus_per_node}"));
    let ib_switch = c.add_device(
        DeviceKind::IbSwitch,
        NodeId(usize::MAX),
        0,
        "ibsw".into(),
    );
    for n in 0..nodes {
        let node = NodeId(n);
        let mut gpus: Vec<DeviceId> = Vec::new();
        let mut hosts = Vec::new();
        let mut hcas = Vec::new();
        for s in 0..2u8 {
            let host = c.add_device(DeviceKind::Host, node, s, format!("n{n}.s{s}.host"));
            let root = c.add_device(DeviceKind::PcieRoot, node, s, format!("n{n}.s{s}.root"));
            c.connect(host, root, LinkKind::HostBus);
            hosts.push(host);
            // one FDR rail per socket (dual-rail node)
            let hca = c.add_device(DeviceKind::IbHca, node, s, format!("n{n}.s{s}.hca"));
            c.connect(root, hca, LinkKind::PcieG3x16);
            c.connect(hca, ib_switch, LinkKind::IbFdr);
            hcas.push(hca);
            for p in 0..2usize {
                let plx = c.add_device(
                    DeviceKind::PlxSwitch,
                    node,
                    s,
                    format!("n{n}.s{s}.plx{p}"),
                );
                c.connect(plx, root, LinkKind::PcieG3x16);
                for g in 0..4usize {
                    let gpu = c.add_device(
                        DeviceKind::Gpu,
                        node,
                        s,
                        format!("n{n}.s{s}.plx{p}.gpu{g}"),
                    );
                    c.connect(gpu, plx, LinkKind::PcieG3x16);
                    gpus.push(gpu);
                }
            }
        }
        // QPI between the two sockets' hosts
        c.connect(hosts[0], hosts[1], LinkKind::Qpi);
        gpus.truncate(gpus_per_node);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts,
            hcas,
        });
    }
    Ok(c)
}

/// Build a DGX-1 (`v100 = false`) or DGX-1V (`v100 = true`) cluster.
///
/// 8 GPUs per node in an NVLink hybrid cube-mesh (each GPU has 4 NVLink
/// bricks; the mesh connects GPU i to i^1, i^2, i^4 and the ring partner),
/// plus the PCIe tree (2 sockets × 2 PLX × 2 GPUs) and 4 IB EDR rails.
pub fn dgx1(nodes: usize, gpus_per_node: usize, v100: bool) -> Result<Cluster> {
    require(nodes >= 1, || "dgx1: nodes must be >= 1".into())?;
    require((1..=8).contains(&gpus_per_node), || {
        format!("dgx1: gpus_per_node must be in 1..=8 (got {gpus_per_node})")
    })?;
    let nv = if v100 {
        LinkKind::NvLink2
    } else {
        LinkKind::NvLink1
    };
    let mut c = Cluster::new(format!(
        "dgx1{}-{nodes}x{gpus_per_node}",
        if v100 { "v" } else { "" }
    ));
    let ib_switch = c.add_device(
        DeviceKind::IbSwitch,
        NodeId(usize::MAX),
        0,
        "ibsw".into(),
    );
    for n in 0..nodes {
        let node = NodeId(n);
        let mut gpus = Vec::new();
        let mut hosts = Vec::new();
        let mut hcas = Vec::new();
        for s in 0..2u8 {
            let host = c.add_device(DeviceKind::Host, node, s, format!("n{n}.s{s}.host"));
            let root = c.add_device(DeviceKind::PcieRoot, node, s, format!("n{n}.s{s}.root"));
            c.connect(host, root, LinkKind::HostBus);
            hosts.push(host);
            for p in 0..2usize {
                let plx = c.add_device(
                    DeviceKind::PlxSwitch,
                    node,
                    s,
                    format!("n{n}.s{s}.plx{p}"),
                );
                c.connect(plx, root, LinkKind::PcieG3x16);
                // one EDR HCA per PLX (4 rails/node, as in DGX-1)
                let hca = c.add_device(DeviceKind::IbHca, node, s, format!("n{n}.s{s}.hca{p}"));
                c.connect(plx, hca, LinkKind::PcieG3x16);
                c.connect(hca, ib_switch, LinkKind::IbEdr);
                hcas.push(hca);
                for g in 0..2usize {
                    let gpu = c.add_device(
                        DeviceKind::Gpu,
                        node,
                        s,
                        format!("n{n}.s{s}.plx{p}.gpu{g}"),
                    );
                    c.connect(gpu, plx, LinkKind::PcieG3x16);
                    gpus.push(gpu);
                }
            }
        }
        c.connect(hosts[0], hosts[1], LinkKind::Qpi);
        // NVLink hybrid cube-mesh over the 8 GPUs
        let mesh: &[(usize, usize)] = &[
            (0, 1),
            (0, 2),
            (0, 3),
            (1, 2),
            (1, 3),
            (2, 3),
            (4, 5),
            (4, 6),
            (4, 7),
            (5, 6),
            (5, 7),
            (6, 7),
            (0, 4),
            (1, 5),
            (2, 6),
            (3, 7),
        ];
        for &(a, b) in mesh {
            if a < gpus.len() && b < gpus.len() {
                c.connect(gpus[a], gpus[b], nv);
            }
        }
        gpus.truncate(gpus_per_node);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts,
            hcas,
        });
    }
    Ok(c)
}

/// Build the idealised flat fabric: `n` GPUs, each with a dedicated
/// full-duplex `Ideal` link into a single crossbar, zero propagation
/// latency. A transfer between any pair costs exactly `bytes / B` plus
/// whatever protocol overhead the comm layer adds — i.e. the `t_s + M/B`
/// of the paper's Eqs. (1)–(5).
pub fn flat(n: usize) -> Result<Cluster> {
    require(n >= 1, || "flat: gpu count must be >= 1".into())?;
    let mut c = Cluster::new(format!("flat-{n}"));
    let xbar = c.add_device(DeviceKind::IbSwitch, NodeId(usize::MAX), 0, "xbar".into());
    // one pseudo-node per GPU so every pair is "internode"
    for i in 0..n {
        let node = NodeId(i);
        let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("g{i}"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("h{i}"));
        c.connect(gpu, xbar, LinkKind::Ideal);
        c.connect(gpu, host, LinkKind::HostBus);
        c.push_node_meta(NodeMeta {
            id: node,
            gpus: vec![gpu],
            hosts: vec![host],
            hcas: vec![],
        });
    }
    Ok(c)
}

/// Build a multi-rail three-tier fat-tree.
///
/// Per rail, each GPU attaches to the leaf switch of its (pod, leaf)
/// slot; leaves uplink to every pod spine; pod spine `s` of rail `r`
/// uplinks to core `(r, s)`. GPUs are one-per-pseudo-node (NIC-attached,
/// like [`flat`]), enumerated pod-major then leaf-major — also the rank
/// order. Total GPUs = `pods * leaves_per_pod * gpus_per_leaf`.
///
/// Routes are algebraic: 2 hops inside a leaf, 4 inside a pod, 6 across
/// pods, with rail and spine chosen by (src + dst) arithmetic.
pub fn fat_tree(
    pods: usize,
    leaves_per_pod: usize,
    gpus_per_leaf: usize,
    rails: usize,
    spines_per_pod: usize,
) -> Result<Cluster> {
    require(pods >= 1, || "fat-tree: pods must be >= 1".into())?;
    require(leaves_per_pod >= 1, || {
        "fat-tree: leaves_per_pod must be >= 1".into()
    })?;
    require(gpus_per_leaf >= 1, || {
        "fat-tree: gpus_per_leaf must be >= 1".into()
    })?;
    require(rails >= 1, || "fat-tree: rails must be >= 1".into())?;
    require(spines_per_pod >= 1, || {
        "fat-tree: spines_per_pod must be >= 1".into()
    })?;
    let n_gpus = pods * leaves_per_pod * gpus_per_leaf;
    require(n_gpus <= MAX_FABRIC_GPUS, || {
        format!("fat-tree: {n_gpus} GPUs exceeds the {MAX_FABRIC_GPUS} cap")
    })?;
    let mut c = Cluster::new(format!(
        "fat-tree-{pods}x{leaves_per_pod}x{gpus_per_leaf}r{rails}"
    ));
    let mut geo = FatTreeGeo::sized(pods, leaves_per_pod, gpus_per_leaf, rails, spines_per_pod);
    let fabric = NodeId(usize::MAX);

    // core tier: one core switch per (rail, spine)
    let mut cores = vec![DeviceId(usize::MAX); rails * spines_per_pod];
    for r in 0..rails {
        for s in 0..spines_per_pod {
            cores[r * spines_per_pod + s] =
                c.add_device(DeviceKind::IbSwitch, fabric, 0, format!("core.r{r}.s{s}"));
        }
    }
    // pod spines and leaves
    let mut leaves = vec![DeviceId(usize::MAX); pods * leaves_per_pod * rails];
    let mut spines = vec![DeviceId(usize::MAX); pods * rails * spines_per_pod];
    for p in 0..pods {
        for r in 0..rails {
            for s in 0..spines_per_pod {
                let sp =
                    c.add_device(DeviceKind::IbSwitch, fabric, 0, format!("pod{p}.spine.r{r}.{s}"));
                let idx = geo.spine_idx(p, r, s);
                spines[idx] = sp;
                let (up, down) = c.connect(sp, cores[r * spines_per_pod + s], LinkKind::IbEdr);
                geo.spine_up[idx] = up;
                geo.spine_down[idx] = down;
            }
        }
        for l in 0..leaves_per_pod {
            for r in 0..rails {
                let leaf =
                    c.add_device(DeviceKind::IbSwitch, fabric, 0, format!("pod{p}.leaf{l}.r{r}"));
                leaves[(p * leaves_per_pod + l) * rails + r] = leaf;
                for s in 0..spines_per_pod {
                    let (up, down) = c.connect(leaf, spines[geo.spine_idx(p, r, s)], LinkKind::IbEdr);
                    let idx = geo.leaf_idx(p, l, r, s);
                    geo.leaf_up[idx] = up;
                    geo.leaf_down[idx] = down;
                }
            }
        }
    }
    // GPUs, rank-major over (pod, leaf, slot); one pseudo-node per GPU
    for rank in 0..n_gpus {
        let p = rank / (leaves_per_pod * gpus_per_leaf);
        let l = (rank / gpus_per_leaf) % leaves_per_pod;
        let node = NodeId(rank);
        let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("g{rank}"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("h{rank}"));
        c.connect(gpu, host, LinkKind::HostBus);
        for r in 0..rails {
            let leaf = leaves[(p * leaves_per_pod + l) * rails + r];
            let (up, down) = c.connect(gpu, leaf, LinkKind::PcieG3x16);
            geo.gpu_up[rank * rails + r] = up;
            geo.gpu_down[rank * rails + r] = down;
        }
        c.push_node_meta(NodeMeta {
            id: node,
            gpus: vec![gpu],
            hosts: vec![host],
            hcas: vec![],
        });
    }
    geo.coord_of = vec![u32::MAX; c.n_devices()];
    for (i, &g) in c.gpu_ranks().iter().enumerate() {
        geo.coord_of[g.0] = i as u32;
    }
    c.set_resolver(Resolver::FatTree(geo));
    Ok(c)
}

/// Build a rail-optimized pod: `nodes` NVSwitch nodes of `gpus_per_node`
/// GPUs; GPU `i` of every node uplinks (via its own HCA) to rail switch
/// `i`, so same-index GPUs are 4 switch-direct hops apart and
/// cross-index traffic first hops to the same-node peer over NVLink —
/// the rail-aligned traffic pattern NCCL's ring/tree orderings assume.
pub fn rail_optimized(nodes: usize, gpus_per_node: usize) -> Result<Cluster> {
    require(nodes >= 1, || "rail-optimized: nodes must be >= 1".into())?;
    require((1..=64).contains(&gpus_per_node), || {
        format!("rail-optimized: gpus_per_node must be in 1..=64 (got {gpus_per_node})")
    })?;
    require(nodes * gpus_per_node <= MAX_FABRIC_GPUS, || {
        format!(
            "rail-optimized: {} GPUs exceeds the {MAX_FABRIC_GPUS} cap",
            nodes * gpus_per_node
        )
    })?;
    let mut c = Cluster::new(format!("rail-{nodes}x{gpus_per_node}"));
    let mut geo = RailGeo::sized(nodes, gpus_per_node);
    // one rail switch per local GPU index
    let mut rails = vec![DeviceId(usize::MAX); gpus_per_node];
    for (i, rail) in rails.iter_mut().enumerate() {
        *rail = c.add_device(DeviceKind::IbSwitch, NodeId(usize::MAX), 0, format!("rail{i}"));
    }
    for n in 0..nodes {
        let node = NodeId(n);
        let nvsw = c.add_device(DeviceKind::NvSwitch, node, 0, format!("n{n}.nvsw"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("n{n}.host"));
        c.connect(host, nvsw, LinkKind::HostBus);
        let mut gpus = Vec::with_capacity(gpus_per_node);
        let mut hcas = Vec::with_capacity(gpus_per_node);
        for i in 0..gpus_per_node {
            let rank = n * gpus_per_node + i;
            let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("n{n}.g{i}"));
            let (nu, nd) = c.connect(gpu, nvsw, LinkKind::NvLink2);
            geo.nv_up[rank] = nu;
            geo.nv_down[rank] = nd;
            let hca = c.add_device(DeviceKind::IbHca, node, 0, format!("n{n}.hca{i}"));
            let (hu, hd) = c.connect(gpu, hca, LinkKind::PcieG3x16);
            geo.hca_up[rank] = hu;
            geo.hca_down[rank] = hd;
            let (ru, rd) = c.connect(hca, rails[i], LinkKind::IbEdr);
            geo.rail_up[rank] = ru;
            geo.rail_down[rank] = rd;
            gpus.push(gpu);
            hcas.push(hca);
        }
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts: vec![host],
            hcas,
        });
    }
    geo.coord_of = vec![u32::MAX; c.n_devices()];
    for (i, &g) in c.gpu_ranks().iter().enumerate() {
        geo.coord_of[g.0] = i as u32;
    }
    c.set_resolver(Resolver::RailOptimized(geo));
    Ok(c)
}

/// Build NVSwitch full-mesh nodes behind a single IB core switch: every
/// GPU reaches node siblings in 2 NVLink hops (through the NVSwitch) and
/// remote GPUs in 4 hops (own HCA -> core -> remote HCA).
pub fn nvswitch(nodes: usize, gpus_per_node: usize) -> Result<Cluster> {
    require(nodes >= 1, || "nvswitch: nodes must be >= 1".into())?;
    require((1..=64).contains(&gpus_per_node), || {
        format!("nvswitch: gpus_per_node must be in 1..=64 (got {gpus_per_node})")
    })?;
    require(nodes * gpus_per_node <= MAX_FABRIC_GPUS, || {
        format!(
            "nvswitch: {} GPUs exceeds the {MAX_FABRIC_GPUS} cap",
            nodes * gpus_per_node
        )
    })?;
    let mut c = Cluster::new(format!("nvswitch-{nodes}x{gpus_per_node}"));
    let mut geo = NvSwitchGeo::sized(nodes, gpus_per_node);
    let core = c.add_device(DeviceKind::IbSwitch, NodeId(usize::MAX), 0, "core".into());
    for n in 0..nodes {
        let node = NodeId(n);
        let nvsw = c.add_device(DeviceKind::NvSwitch, node, 0, format!("n{n}.nvsw"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("n{n}.host"));
        c.connect(host, nvsw, LinkKind::HostBus);
        let mut gpus = Vec::with_capacity(gpus_per_node);
        let mut hcas = Vec::with_capacity(gpus_per_node);
        for i in 0..gpus_per_node {
            let rank = n * gpus_per_node + i;
            let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("n{n}.g{i}"));
            let (nu, nd) = c.connect(gpu, nvsw, LinkKind::NvLink2);
            geo.nv_up[rank] = nu;
            geo.nv_down[rank] = nd;
            let hca = c.add_device(DeviceKind::IbHca, node, 0, format!("n{n}.hca{i}"));
            let (hu, hd) = c.connect(gpu, hca, LinkKind::PcieG3x16);
            geo.hca_up[rank] = hu;
            geo.hca_down[rank] = hd;
            let (cu, cd) = c.connect(hca, core, LinkKind::IbEdr);
            geo.core_up[rank] = cu;
            geo.core_down[rank] = cd;
            gpus.push(gpu);
            hcas.push(hca);
        }
        c.push_node_meta(NodeMeta {
            id: node,
            gpus,
            hosts: vec![host],
            hcas,
        });
    }
    geo.coord_of = vec![u32::MAX; c.n_devices()];
    for (i, &g) in c.gpu_ranks().iter().enumerate() {
        geo.coord_of[g.0] = i as u32;
    }
    c.set_resolver(Resolver::NvSwitch(geo));
    Ok(c)
}

/// Build a dragonfly: `groups` groups of `routers_per_group` routers in
/// a local full mesh (EDR), `gpus_per_router` NIC-attached GPUs per
/// router, and one global FDR link per group pair attached at each
/// group's gateway (router 0). Gateway aggregation keeps minimal
/// routing provably min-hop, so BFS stays an exact golden reference for
/// the algebraic resolver.
pub fn dragonfly(
    groups: usize,
    routers_per_group: usize,
    gpus_per_router: usize,
) -> Result<Cluster> {
    require(groups >= 1, || "dragonfly: groups must be >= 1".into())?;
    require(routers_per_group >= 1, || {
        "dragonfly: routers_per_group must be >= 1".into()
    })?;
    require(gpus_per_router >= 1, || {
        "dragonfly: gpus_per_router must be >= 1".into()
    })?;
    let n_gpus = groups * routers_per_group * gpus_per_router;
    require(n_gpus <= MAX_FABRIC_GPUS, || {
        format!("dragonfly: {n_gpus} GPUs exceeds the {MAX_FABRIC_GPUS} cap")
    })?;
    let mut c = Cluster::new(format!(
        "dragonfly-{groups}x{routers_per_group}x{gpus_per_router}"
    ));
    let mut geo = DragonflyGeo::sized(groups, routers_per_group, gpus_per_router);
    let fabric = NodeId(usize::MAX);
    let a = routers_per_group;
    let mut routers = vec![DeviceId(usize::MAX); groups * a];
    for g in 0..groups {
        for r in 0..a {
            routers[g * a + r] = c.add_device(DeviceKind::IbSwitch, fabric, 0, format!("d{g}.r{r}"));
        }
    }
    // intra-group full mesh
    for g in 0..groups {
        for i in 0..a {
            for j in (i + 1)..a {
                let (f, b) = c.connect(routers[g * a + i], routers[g * a + j], LinkKind::IbEdr);
                geo.local[geo.local_idx(g, i, j)] = f;
                geo.local[geo.local_idx(g, j, i)] = b;
            }
        }
    }
    // one global link per group pair, gateway (router 0) to gateway
    for x in 0..groups {
        for y in (x + 1)..groups {
            let (f, b) = c.connect(routers[x * a], routers[y * a], LinkKind::IbFdr);
            geo.global[x * groups + y] = f;
            geo.global[y * groups + x] = b;
        }
    }
    // GPUs, rank-major over (group, router, slot); one pseudo-node each
    for rank in 0..n_gpus {
        let g = rank / (a * gpus_per_router);
        let r = (rank / gpus_per_router) % a;
        let node = NodeId(rank);
        let gpu = c.add_device(DeviceKind::Gpu, node, 0, format!("g{rank}"));
        let host = c.add_device(DeviceKind::Host, node, 0, format!("h{rank}"));
        c.connect(gpu, host, LinkKind::HostBus);
        let (up, down) = c.connect(gpu, routers[g * a + r], LinkKind::PcieG3x16);
        geo.gpu_up[rank] = up;
        geo.gpu_down[rank] = down;
        c.push_node_meta(NodeMeta {
            id: node,
            gpus: vec![gpu],
            hosts: vec![host],
            hcas: vec![],
        });
    }
    geo.coord_of = vec![u32::MAX; c.n_devices()];
    for (i, &g) in c.gpu_ranks().iter().enumerate() {
        geo.coord_of[g.0] = i as u32;
    }
    c.set_resolver(Resolver::Dragonfly(geo));
    Ok(c)
}

/// Sanity probe used by generator tests: every recorded port table
/// entry must have been filled in (no `LinkId(usize::MAX)` left).
#[cfg(test)]
fn assert_ports_filled(table: &[LinkId], what: &str) {
    assert!(
        table.iter().all(|l| l.0 != usize::MAX),
        "{what}: unfilled port table entry"
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::resolve::TopologyKind;

    #[test]
    fn kesch_shape() {
        let c = kesch(12, 16).unwrap();
        assert_eq!(c.n_nodes(), 12);
        assert_eq!(c.n_gpus(), 192);
        // per node: 2 hosts + 2 roots + 2 hcas + 4 plx + 16 gpus = 26
        assert_eq!(c.n_devices(), 12 * 26 + 1);
        assert_eq!(c.topology_kind(), TopologyKind::Generic);
    }

    #[test]
    fn kesch_gpu_prefix() {
        let c = kesch(1, 2).unwrap();
        assert_eq!(c.n_gpus(), 2);
        // first two GPUs share a PLX -> peer access
        let (a, b) = (c.rank_device(0), c.rank_device(1));
        assert!(c.peer_access(a, b));
    }

    #[test]
    fn kesch_cross_socket_no_peer_access() {
        let c = kesch(1, 16).unwrap();
        let a = c.rank_device(0); // socket 0
        let b = c.rank_device(8); // socket 1
        assert!(!c.same_socket(a, b));
        assert!(!c.peer_access(a, b));
        // same socket, different PLX: route crosses the PCIe root but not
        // the host, so peer access holds
        let d = c.rank_device(4);
        assert!(c.peer_access(a, d));
    }

    #[test]
    fn kesch_internode_route_uses_ib() {
        let c = kesch(2, 16).unwrap();
        let a = c.rank_device(0);
        let b = c.rank_device(16);
        assert!(!c.same_node(a, b));
        let r = c.route_info(a, b).unwrap();
        let has_ib = r
            .hops
            .iter()
            .any(|&l| c.link(l).kind == LinkKind::IbFdr);
        assert!(has_ib);
        // bottleneck is the FDR rail
        assert_eq!(r.bottleneck_bw, LinkKind::IbFdr.default_bandwidth());
    }

    #[test]
    fn kesch_multirail_hca_per_socket() {
        let c = kesch(1, 16).unwrap();
        let g0 = c.rank_device(0);
        let g8 = c.rank_device(8);
        let h0 = c.hca_for(g0).unwrap();
        let h8 = c.hca_for(g8).unwrap();
        assert_ne!(h0, h8, "sockets use distinct rails");
    }

    #[test]
    fn dgx1_nvlink_peer() {
        let c = dgx1(1, 8, false).unwrap();
        assert_eq!(c.n_gpus(), 8);
        let r = c.route_info(c.rank_device(0), c.rank_device(1)).unwrap();
        assert_eq!(r.n_hops(), 1, "NVLink direct");
        assert_eq!(r.bottleneck_bw, LinkKind::NvLink1.default_bandwidth());
    }

    #[test]
    fn dgx1v_uses_nvlink2() {
        let c = dgx1(1, 8, true).unwrap();
        let r = c.route_info(c.rank_device(0), c.rank_device(4)).unwrap();
        assert_eq!(r.bottleneck_bw, LinkKind::NvLink2.default_bandwidth());
    }

    #[test]
    fn flat_uniform() {
        let c = flat(8).unwrap();
        assert_eq!(c.n_gpus(), 8);
        for i in 1..8 {
            let r = c.route_info(c.rank_device(0), c.rank_device(i)).unwrap();
            assert_eq!(r.n_hops(), 2);
            assert_eq!(r.latency_ns, 0);
            assert_eq!(r.bottleneck_bw, LinkKind::Ideal.default_bandwidth());
        }
    }

    #[test]
    fn rank_order_is_node_major() {
        let c = kesch(2, 4).unwrap();
        assert_eq!(c.device(c.rank_device(0)).node, NodeId(0));
        assert_eq!(c.device(c.rank_device(4)).node, NodeId(1));
    }

    #[test]
    fn degenerate_params_rejected_with_usage_error() {
        for err in [
            kesch(0, 4).unwrap_err(),
            kesch(1, 0).unwrap_err(),
            kesch(1, 17).unwrap_err(),
            dgx1(0, 8, false).unwrap_err(),
            dgx1(1, 0, true).unwrap_err(),
            dgx1(1, 9, false).unwrap_err(),
            flat(0).unwrap_err(),
            fat_tree(0, 1, 1, 1, 1).unwrap_err(),
            fat_tree(1, 0, 1, 1, 1).unwrap_err(),
            fat_tree(1, 1, 0, 1, 1).unwrap_err(),
            fat_tree(1, 1, 1, 0, 1).unwrap_err(),
            fat_tree(1, 1, 1, 1, 0).unwrap_err(),
            rail_optimized(0, 4).unwrap_err(),
            rail_optimized(2, 0).unwrap_err(),
            rail_optimized(2, 65).unwrap_err(),
            nvswitch(0, 4).unwrap_err(),
            nvswitch(2, 0).unwrap_err(),
            dragonfly(0, 2, 2).unwrap_err(),
            dragonfly(2, 0, 2).unwrap_err(),
            dragonfly(2, 2, 0).unwrap_err(),
        ] {
            assert!(
                matches!(err, Error::Usage(_)),
                "expected Error::Usage, got {err:?}"
            );
            assert!(err.to_string().starts_with("usage error:"), "{err}");
        }
    }

    #[test]
    fn fat_tree_shape_and_hop_counts() {
        let c = fat_tree(2, 2, 2, 2, 2).unwrap();
        assert_eq!(c.n_gpus(), 8);
        assert_eq!(c.topology_kind(), TopologyKind::FatTree);
        // ranks: pod = r/4, leaf = (r/2)%2
        let same_leaf = c.route_info(c.rank_device(0), c.rank_device(1)).unwrap();
        assert_eq!(same_leaf.n_hops(), 2);
        let same_pod = c.route_info(c.rank_device(0), c.rank_device(2)).unwrap();
        assert_eq!(same_pod.n_hops(), 4);
        let cross_pod = c.route_info(c.rank_device(0), c.rank_device(7)).unwrap();
        assert_eq!(cross_pod.n_hops(), 6);
        // resolver is consulted, not BFS: route count tracks routed pairs
        assert_eq!(c.routes().n_routes(), 3);
    }

    #[test]
    fn fat_tree_port_tables_filled() {
        let c = fat_tree(2, 3, 2, 2, 2).unwrap();
        let Resolver::FatTree(geo) = c.resolver() else {
            panic!("fat_tree must install the FatTree resolver");
        };
        assert_ports_filled(&geo.gpu_up, "gpu_up");
        assert_ports_filled(&geo.gpu_down, "gpu_down");
        assert_ports_filled(&geo.leaf_up, "leaf_up");
        assert_ports_filled(&geo.leaf_down, "leaf_down");
        assert_ports_filled(&geo.spine_up, "spine_up");
        assert_ports_filled(&geo.spine_down, "spine_down");
    }

    #[test]
    fn rail_optimized_routes() {
        let c = rail_optimized(2, 4).unwrap();
        assert_eq!(c.n_gpus(), 8);
        assert_eq!(c.topology_kind(), TopologyKind::RailOptimized);
        // same node: 2 NVLink hops through the NVSwitch -> peer access
        let same = c.route_info(c.rank_device(0), c.rank_device(1)).unwrap();
        assert_eq!(same.n_hops(), 2);
        assert_eq!(same.bottleneck_bw, LinkKind::NvLink2.default_bandwidth());
        assert!(c.peer_access(c.rank_device(0), c.rank_device(1)));
        // rail-aligned cross-node: 4 hops, no NVLink
        let aligned = c.route_info(c.rank_device(1), c.rank_device(5)).unwrap();
        assert_eq!(aligned.n_hops(), 4);
        // cross-rail cross-node: NVLink to the peer, then the rail
        let cross = c.route_info(c.rank_device(0), c.rank_device(5)).unwrap();
        assert_eq!(cross.n_hops(), 6);
    }

    #[test]
    fn nvswitch_routes() {
        let c = nvswitch(2, 4).unwrap();
        assert_eq!(c.n_gpus(), 8);
        assert_eq!(c.topology_kind(), TopologyKind::NvSwitch);
        let same = c.route_info(c.rank_device(0), c.rank_device(3)).unwrap();
        assert_eq!(same.n_hops(), 2);
        assert_eq!(same.bottleneck_bw, LinkKind::NvLink2.default_bandwidth());
        let cross = c.route_info(c.rank_device(0), c.rank_device(4)).unwrap();
        assert_eq!(cross.n_hops(), 4);
        assert_eq!(cross.bottleneck_bw, LinkKind::IbEdr.default_bandwidth());
    }

    #[test]
    fn dragonfly_routes() {
        let c = dragonfly(3, 2, 2).unwrap();
        assert_eq!(c.n_gpus(), 12);
        assert_eq!(c.topology_kind(), TopologyKind::Dragonfly);
        // ranks: group = r/4, router = (r/2)%2
        let same_router = c.route_info(c.rank_device(0), c.rank_device(1)).unwrap();
        assert_eq!(same_router.n_hops(), 2);
        let same_group = c.route_info(c.rank_device(0), c.rank_device(2)).unwrap();
        assert_eq!(same_group.n_hops(), 3);
        // gateway to gateway, no local detour
        let gw = c.route_info(c.rank_device(0), c.rank_device(4)).unwrap();
        assert_eq!(gw.n_hops(), 3);
        assert_eq!(gw.bottleneck_bw, LinkKind::IbFdr.default_bandwidth());
        // both endpoints off-gateway: two local detours
        let far = c.route_info(c.rank_device(2), c.rank_device(6)).unwrap();
        assert_eq!(far.n_hops(), 5);
    }

    #[test]
    fn structured_fabrics_have_staging_hosts() {
        for c in [
            fat_tree(2, 2, 2, 2, 1).unwrap(),
            rail_optimized(2, 2).unwrap(),
            nvswitch(2, 2).unwrap(),
            dragonfly(2, 2, 1).unwrap(),
        ] {
            let g = c.rank_device(0);
            let h = c.staging_host(g).unwrap();
            assert_eq!(c.device(h).kind, DeviceKind::Host);
            // staging route exists (BFS fallback handles non-GPU pairs)
            assert!(c.route(g, h).is_ok());
        }
    }
}
