//! Routes: ordered hop lists with cached aggregates.

use super::cluster::Cluster;
use super::device::DeviceId;
use super::link::LinkId;

/// A directed path through the fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    pub src: DeviceId,
    pub dst: DeviceId,
    pub hops: Vec<LinkId>,
    /// min over hop bandwidths (bytes/s); `f64::INFINITY` for the trivial
    /// route.
    pub bottleneck_bw: f64,
    /// sum of hop latencies (ns).
    pub latency_ns: u64,
}

impl Route {
    pub fn trivial(dev: DeviceId) -> Route {
        Route {
            src: dev,
            dst: dev,
            hops: Vec::new(),
            bottleneck_bw: f64::INFINITY,
            latency_ns: 0,
        }
    }

    pub fn from_hops(src: DeviceId, dst: DeviceId, hops: Vec<LinkId>, cluster: &Cluster) -> Route {
        let mut bw = f64::INFINITY;
        let mut lat = 0u64;
        for &h in &hops {
            let link = cluster.link(h);
            bw = bw.min(link.bandwidth);
            lat += link.latency_ns;
        }
        Route {
            src,
            dst,
            hops,
            bottleneck_bw: bw,
            latency_ns: lat,
        }
    }

    /// Concatenate two routes sharing an endpoint.
    pub fn concat(&self, other: &Route, cluster: &Cluster) -> Route {
        assert_eq!(self.dst, other.src, "routes must share endpoint");
        let mut hops = self.hops.clone();
        hops.extend_from_slice(&other.hops);
        Route::from_hops(self.src, other.dst, hops, cluster)
    }

    pub fn n_hops(&self) -> usize {
        self.hops.len()
    }

    /// Pure (uncontended) time to move `bytes` along this route with
    /// cut-through forwarding: propagation + bytes / bottleneck-bandwidth.
    pub fn uncontended_ns(&self, bytes: u64) -> u64 {
        let bw = if self.bottleneck_bw.is_finite() {
            self.bottleneck_bw
        } else {
            return self.latency_ns;
        };
        self.latency_ns + (bytes as f64 / bw * 1.0e9).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::device::{DeviceKind, NodeId};
    use crate::topology::link::LinkKind;

    #[test]
    fn aggregates_computed() {
        let mut c = Cluster::new("t");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect_custom(a, b, LinkKind::PcieG3x16, 10.0e9, 100);
        c.connect_custom(b, d, LinkKind::PcieG3x16, 5.0e9, 200);
        let r = c.route(a, d).unwrap();
        assert_eq!(r.latency_ns, 300);
        assert_eq!(r.bottleneck_bw, 5.0e9);
        // 5 GB/s for 5 MB = 1 ms + 300ns
        let t = r.uncontended_ns(5_000_000);
        assert_eq!(t, 1_000_300);
    }

    #[test]
    fn trivial_route_is_free() {
        let r = Route::trivial(DeviceId(3));
        assert_eq!(r.uncontended_ns(1 << 30), 0);
    }
}
