//! Routes: interned hop lists with cached aggregates.
//!
//! Routes used to be owned `Vec<LinkId>` values cloned into every
//! simulator op; on the plan-build hot path those clones dominated the
//! allocation profile (DESIGN.md §Perf). They are now *interned* once per
//! (src, dst) pair in a [`RouteTable`] hanging off the
//! [`Cluster`](super::cluster::Cluster): BFS runs at most once per pair,
//! plans carry a copyable [`RouteId`], and the engine resolves hops /
//! bottleneck bandwidth / latency through the table without touching the
//! heap. The owned [`Route`] struct survives as a *materialized view* for
//! display, tests and topology inspection.

use std::cell::{Ref, RefCell};
use std::collections::HashMap;

use super::cluster::Cluster;
use super::device::DeviceId;
use super::link::LinkId;

/// Handle to an interned route. Cheap to copy, trivially hashable;
/// resolves through the owning cluster's [`RouteTable`]. Carries the
/// table generation it was interned under, so resolving an id that
/// outlived a topology mutation fails fast in debug builds instead of
/// silently aliasing another route.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RouteId {
    index: u32,
    generation: u32,
}

/// The cached aggregates of one interned route. `Copy` so the engine can
/// pull it out of the table by value on the hot path.
#[derive(Debug, Clone, Copy)]
pub struct RouteMeta {
    pub src: DeviceId,
    pub dst: DeviceId,
    /// Range of this route's hops in the table's flat hop arena.
    pub hop_start: u32,
    pub hop_len: u32,
    /// min over hop bandwidths (bytes/s); `f64::INFINITY` for the trivial
    /// route.
    pub bottleneck_bw: f64,
    /// sum of hop latencies (ns).
    pub latency_ns: u64,
}

impl RouteMeta {
    pub fn n_hops(&self) -> usize {
        self.hop_len as usize
    }

    /// Pure (uncontended) time to move `bytes` along this route with
    /// cut-through forwarding: propagation + bytes / bottleneck-bandwidth.
    /// A non-positive bottleneck (dead link on the path) saturates to the
    /// [`crate::netsim::UNREACHABLE_NS`] sentinel instead of overflowing;
    /// the trivial route's infinite bandwidth stays free.
    pub fn uncontended_ns(&self, bytes: u64) -> u64 {
        self.latency_ns
            .saturating_add(crate::netsim::time::tx_ns(bytes, self.bottleneck_bw))
    }
}

#[derive(Debug, Clone, Default)]
struct RouteTableInner {
    /// (src, dst) -> interned shortest route.
    by_pair: HashMap<(DeviceId, DeviceId), RouteId>,
    /// (src, via, dst) -> interned concatenated route.
    by_via: HashMap<(DeviceId, DeviceId, DeviceId), RouteId>,
    metas: Vec<RouteMeta>,
    /// Flat hop arena; each meta indexes a contiguous range.
    hops: Vec<LinkId>,
    /// Bumped on every [`RouteTable::clear`]; stale ids are rejected.
    generation: u32,
}

/// The per-cluster route intern table. Interior-mutable (`RefCell`) so
/// lookups cache through `&Cluster`; deliberately **not** `Sync` — the
/// parallel tuning sweep gives each worker thread its own cluster clone
/// instead of fencing every hot-path read with an atomic.
#[derive(Debug, Clone, Default)]
pub struct RouteTable {
    inner: RefCell<RouteTableInner>,
}

impl RouteTable {
    pub fn new() -> RouteTable {
        RouteTable::default()
    }

    /// Number of interned routes (tests assert BFS runs once per pair).
    pub fn n_routes(&self) -> usize {
        self.inner.borrow().metas.len()
    }

    /// The table generation — bumped by every topology mutation.
    /// `RouteId`s, engine scratch and plan-template caches keyed on it
    /// become stale when it changes.
    pub fn generation(&self) -> u32 {
        self.inner.borrow().generation
    }

    /// Drop every cached route. Only the cluster's `&mut self` topology
    /// mutators call this — exposing it on `&self` would let stale
    /// `RouteId`s be invalidated out from under live plans.
    pub(super) fn clear(&self) {
        let mut inner = self.inner.borrow_mut();
        inner.by_pair.clear();
        inner.by_via.clear();
        inner.metas.clear();
        inner.hops.clear();
        inner.generation = inner.generation.wrapping_add(1);
    }

    pub fn lookup(&self, src: DeviceId, dst: DeviceId) -> Option<RouteId> {
        self.inner.borrow().by_pair.get(&(src, dst)).copied()
    }

    pub(super) fn lookup_via(
        &self,
        src: DeviceId,
        via: DeviceId,
        dst: DeviceId,
    ) -> Option<RouteId> {
        self.inner.borrow().by_via.get(&(src, via, dst)).copied()
    }

    /// Intern a computed route under its (src, dst) key.
    pub(super) fn insert(
        &self,
        src: DeviceId,
        dst: DeviceId,
        hops: &[LinkId],
        bottleneck_bw: f64,
        latency_ns: u64,
    ) -> RouteId {
        let mut inner = self.inner.borrow_mut();
        let id = Self::push_route(&mut inner, src, dst, hops, bottleneck_bw, latency_ns);
        inner.by_pair.insert((src, dst), id);
        id
    }

    /// Intern a concatenated route under its (src, via, dst) key.
    pub(super) fn insert_via(
        &self,
        src: DeviceId,
        via: DeviceId,
        dst: DeviceId,
        hops: &[LinkId],
        bottleneck_bw: f64,
        latency_ns: u64,
    ) -> RouteId {
        let mut inner = self.inner.borrow_mut();
        let id = Self::push_route(&mut inner, src, dst, hops, bottleneck_bw, latency_ns);
        inner.by_via.insert((src, via, dst), id);
        id
    }

    fn push_route(
        inner: &mut RouteTableInner,
        src: DeviceId,
        dst: DeviceId,
        hops: &[LinkId],
        bottleneck_bw: f64,
        latency_ns: u64,
    ) -> RouteId {
        let id = RouteId {
            index: inner.metas.len() as u32,
            generation: inner.generation,
        };
        let hop_start = inner.hops.len() as u32;
        inner.hops.extend_from_slice(hops);
        inner.metas.push(RouteMeta {
            src,
            dst,
            hop_start,
            hop_len: hops.len() as u32,
            bottleneck_bw,
            latency_ns,
        });
        id
    }

    /// Whether `id` still resolves against this table: interned under the
    /// current generation and in range. The static verifier uses this to
    /// flag stale routes as a diagnostic instead of tripping the
    /// debug-assert in [`Self::meta`].
    pub fn is_current(&self, id: RouteId) -> bool {
        let inner = self.inner.borrow();
        id.generation == inner.generation && (id.index as usize) < inner.metas.len()
    }

    /// The cached aggregates, by value.
    pub fn meta(&self, id: RouteId) -> RouteMeta {
        let inner = self.inner.borrow();
        debug_assert_eq!(
            id.generation, inner.generation,
            "stale RouteId: topology changed since this route was interned"
        );
        inner.metas[id.index as usize]
    }

    /// The hop list, borrowed straight out of the arena (no copy). The
    /// returned guard must be dropped before any interning call.
    pub fn hops(&self, id: RouteId) -> Ref<'_, [LinkId]> {
        Ref::map(self.inner.borrow(), |inner| {
            debug_assert_eq!(
                id.generation, inner.generation,
                "stale RouteId: topology changed since this route was interned"
            );
            let m = &inner.metas[id.index as usize];
            &inner.hops[m.hop_start as usize..(m.hop_start + m.hop_len) as usize]
        })
    }
}

/// A directed path through the fabric — the materialized (owning) view of
/// an interned route, for display, tests and topology inspection. The
/// simulator hot path never builds these; it works on [`RouteId`]s.
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    pub src: DeviceId,
    pub dst: DeviceId,
    pub hops: Vec<LinkId>,
    /// min over hop bandwidths (bytes/s); `f64::INFINITY` for the trivial
    /// route.
    pub bottleneck_bw: f64,
    /// sum of hop latencies (ns).
    pub latency_ns: u64,
}

impl Route {
    pub fn trivial(dev: DeviceId) -> Route {
        Route {
            src: dev,
            dst: dev,
            hops: Vec::new(),
            bottleneck_bw: f64::INFINITY,
            latency_ns: 0,
        }
    }

    pub fn n_hops(&self) -> usize {
        self.hops.len()
    }

    /// Pure (uncontended) time to move `bytes` along this route with
    /// cut-through forwarding: propagation + bytes / bottleneck-bandwidth.
    /// Saturating, mirroring [`RouteMeta::uncontended_ns`].
    pub fn uncontended_ns(&self, bytes: u64) -> u64 {
        self.latency_ns
            .saturating_add(crate::netsim::time::tx_ns(bytes, self.bottleneck_bw))
    }
}

/// (bottleneck bandwidth, total latency) of a hop list.
pub(super) fn aggregates(hops: &[LinkId], cluster: &Cluster) -> (f64, u64) {
    let mut bw = f64::INFINITY;
    let mut lat = 0u64;
    for &h in hops {
        let link = cluster.link(h);
        bw = bw.min(link.bandwidth);
        lat += link.latency_ns;
    }
    (bw, lat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::device::{DeviceKind, NodeId};
    use crate::topology::link::LinkKind;

    #[test]
    fn aggregates_computed() {
        let mut c = Cluster::new("t");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect_custom(a, b, LinkKind::PcieG3x16, 10.0e9, 100);
        c.connect_custom(b, d, LinkKind::PcieG3x16, 5.0e9, 200);
        let r = c.route_info(a, d).unwrap();
        assert_eq!(r.latency_ns, 300);
        assert_eq!(r.bottleneck_bw, 5.0e9);
        // 5 GB/s for 5 MB = 1 ms + 300ns
        let t = r.uncontended_ns(5_000_000);
        assert_eq!(t, 1_000_300);
        // the interned meta agrees with the materialized view
        let id = c.route(a, d).unwrap();
        let meta = c.route_meta(id);
        assert_eq!(meta.latency_ns, r.latency_ns);
        assert_eq!(meta.bottleneck_bw, r.bottleneck_bw);
        assert_eq!(meta.uncontended_ns(5_000_000), t);
    }

    #[test]
    fn trivial_route_is_free() {
        let r = Route::trivial(DeviceId(3));
        assert_eq!(r.uncontended_ns(1 << 30), 0);
    }

    #[test]
    fn interning_caches_bfs() {
        let mut c = Cluster::new("t");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::PlxSwitch, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect(a, b, LinkKind::PcieG3x16);
        c.connect(b, d, LinkKind::PcieG3x16);
        let first = c.route(a, d).unwrap();
        let second = c.route(a, d).unwrap();
        assert_eq!(first, second, "same pair must intern to the same id");
        assert_eq!(c.routes().n_routes(), 1);
        // the reverse direction is a distinct interned route
        let back = c.route(d, a).unwrap();
        assert_ne!(first, back);
        assert_eq!(c.routes().n_routes(), 2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "stale RouteId")]
    fn stale_route_id_rejected_in_debug() {
        let mut c = Cluster::new("t");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "b".into());
        c.connect(a, b, LinkKind::PcieG3x16);
        let id = c.route(a, b).unwrap();
        c.connect(a, b, LinkKind::NvLink2); // clears the route cache
        let _ = c.route_meta(id);
    }

    #[test]
    fn topology_mutation_invalidates_cache() {
        let mut c = Cluster::new("t");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "b".into());
        c.connect_custom(a, b, LinkKind::PcieG3x16, 10.0e9, 100);
        let before = c.route(a, b).unwrap();
        assert_eq!(c.route_meta(before).latency_ns, 100);
        // adding a faster parallel path must not serve the stale route
        c.connect_custom(a, b, LinkKind::NvLink2, 22.0e9, 50);
        let after = c.route(a, b).unwrap();
        assert_eq!(c.route_meta(after).latency_ns, 50);
    }
}
