//! # gdrbcast
//!
//! A reproduction of *"Optimized Broadcast for Deep Learning Workloads on
//! Dense-GPU InfiniBand Clusters: MPI or NCCL?"* (Awan, Chu, Subramoni,
//! Panda — OSU, 2017) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper proposes a **pipelined chain design for `MPI_Bcast`** and an
//! enhanced collective tuning framework inside the CUDA-aware MPI runtime
//! MVAPICH2-GDR, and evaluates it against NVIDIA NCCL broadcast and a
//! NCCL-integrated `MPI_Bcast` hybrid — with analytic models,
//! micro-benchmarks on a dense multi-GPU InfiniBand cluster (KESCH), and
//! data-parallel VGG training under Microsoft CNTK.
//!
//! This crate contains the Layer-3 system:
//!
//! * [`topology`] — explicit device/link graphs for dense multi-GPU nodes
//!   (KESCH Cray CS-Storm, DGX-1, DGX-1V presets) with PCIe/PLX/QPI/NVLink/
//!   InfiniBand link models and routing.
//! * [`netsim`] — a deterministic discrete-event fabric simulator with
//!   cut-through transfers and selectable per-link contention: exclusive
//!   FIFO occupancy (default) or progressive-filling max-min fair
//!   bandwidth sharing ([`netsim::LinkModel`]).
//! * [`comm`] — the CUDA-aware point-to-point engine: GDR read/write, CUDA
//!   IPC, host staging, SGL eager — with the mechanism-selection logic that
//!   MVAPICH2-GDR's wins come from.
//! * [`analysis`] — the static plan verifier: proves DAG/route/dataflow
//!   invariants over any plan *before* execution, with typed `PL*`
//!   diagnostics (debug builds verify every plan automatically).
//! * [`collectives`] — broadcast algorithms: direct, chain, **pipelined
//!   chain (the paper's contribution)**, k-nomial, binomial,
//!   scatter-ring-allgather, host-staged k-nomial, ring.
//! * [`nccl`] — an NCCL 1.3 behavioural model (ring broadcast, kernel
//!   launch overheads) and the NCCL-integrated `MPI_Bcast` hybrid of [4].
//! * [`analytic`] — the closed-form cost models of the paper's §III/§IV
//!   (Eqs. 1–6) and a simulator-vs-model validation harness.
//! * [`tuning`] — the enhanced collective tuning framework: sweep,
//!   dispatch-table generation, runtime selection ("MV2-GDR-Opt").
//! * [`models`] — DNN parameter-shape descriptors (LeNet/AlexNet/VGG/
//!   GoogLeNet/ResNet) and CNTK-style broadcast message partitioning.
//! * [`coordinator`] — the data-parallel training coordinator that plays
//!   the role of CA-CNTK: per-iteration parameter broadcast + measured
//!   compute.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   training step (`artifacts/*.hlo.txt`).
//! * [`bench`] — the statistical benchmark harness (criterion replacement)
//!   and the osu_bcast-equivalent micro-benchmark.
//! * [`util`] — zero-dependency substrates: RNG, stats, CLI parsing, JSON,
//!   property testing.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod analytic;
pub mod bench;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod models;
pub mod nccl;
pub mod netsim;
pub mod runtime;
pub mod topology;
pub mod tuning;
pub mod util;

pub use error::{Error, Result};
