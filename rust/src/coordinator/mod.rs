//! The data-parallel training coordinator — the role CA-CNTK plays in the
//! paper's application study (§V-D, Fig. 3), extended with the modern
//! allreduce-based gradient exchange.
//!
//! Responsibilities:
//!
//! * [`schedule`] — turn a model + scale into the per-iteration exchange
//!   schedule and cost it on the simulator: the partitioned broadcast
//!   schedule under either comm backend (MV2-GDR-Opt or NCCL-MV2-GDR),
//!   its gather-based aggregation leg, and the bucketed gradient
//!   allreduce ([`schedule::TrainingMode`]);
//! * [`train`] — the Fig. 3 estimator: compute-time model × simulated
//!   communication, per GPU count — plus the mode-aware full-exchange
//!   estimator ([`train::estimate_training_iteration`]);
//! * [`timeline`] — the compute/comm *overlap* timeline: per-layer
//!   backprop delays + bucketed exchange stitched into one engine DAG
//!   whose makespan is the overlapped iteration time
//!   (`ExchangeOptions { overlap: true, .. }`);
//! * [`leader`] / [`worker`] — the actual data-parallel execution engine
//!   (leader owns parameters, workers compute gradient shards; threaded
//!   over channels, or serial for non-`Send` backends like PJRT);
//! * [`metrics`] — per-iteration accounting.

pub mod leader;
pub mod metrics;
pub mod recovery;
pub mod schedule;
pub mod timeline;
pub mod train;
pub mod worker;

pub use leader::{run_serial, run_threaded, SgdConfig};
pub use metrics::{IterationMetrics, TrainingMetrics};
pub use recovery::{
    run_collective_job, run_training_job, JobOutcome, RecoveryConfig, RecoveryPolicy,
};
pub use schedule::{
    aggregation_time_ns, allreduce_time_ns, comm_time_ns, BcastBackend, TrainingMode,
};
pub use timeline::{overlap_iteration_ns, ExchangeUnit};
pub use train::{estimate_training_iteration, estimate_training_iteration_opts, ExchangeOptions};
pub use worker::ComputeBackend;
