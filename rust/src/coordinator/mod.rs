//! The data-parallel training coordinator — the role CA-CNTK plays in the
//! paper's application study (§V-D, Fig. 3).
//!
//! Responsibilities:
//!
//! * [`schedule`] — turn a model + scale into the per-iteration broadcast
//!   schedule and cost it on the simulator under either comm backend
//!   (MV2-GDR-Opt or NCCL-MV2-GDR);
//! * [`train`] — the Fig. 3 estimator: compute-time model × simulated
//!   communication, per GPU count;
//! * [`leader`] / [`worker`] — the actual data-parallel execution engine
//!   (leader owns parameters, workers compute gradient shards; threaded
//!   over channels, or serial for non-`Send` backends like PJRT);
//! * [`metrics`] — per-iteration accounting.

pub mod leader;
pub mod metrics;
pub mod schedule;
pub mod train;
pub mod worker;

pub use leader::{run_serial, run_threaded, SgdConfig};
pub use metrics::{IterationMetrics, TrainingMetrics};
pub use schedule::{comm_time_ns, BcastBackend};
pub use worker::ComputeBackend;
