//! Per-iteration exchange-schedule costing: the paper's partitioned
//! broadcast schedule, its gradient-aggregation leg, and the modern
//! bucketed-allreduce alternative.

use crate::collectives::{Algorithm, BcastSpec, CollectiveSpec};
use crate::comm::Comm;
use crate::models::messages::BcastMsg;
use crate::nccl::{hierarchical, NcclParams};
use crate::netsim::Engine;
use crate::tuning::Selector;

/// Which runtime carries the parameter broadcasts.
pub enum BcastBackend<'a> {
    /// The paper's proposed tuned MPI runtime.
    Mv2Opt(&'a Selector),
    /// The NCCL-integrated MPI_Bcast baseline [4].
    NcclMv2(&'a NcclParams),
}

impl<'a> BcastBackend<'a> {
    pub fn label(&self) -> &'static str {
        match self {
            BcastBackend::Mv2Opt(_) => "MV2-GDR-Opt",
            BcastBackend::NcclMv2(_) => "NCCL-MV2-GDR",
        }
    }
}

/// How the data-parallel training loop exchanges model state each
/// iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainingMode {
    /// The CA-CNTK scheme the paper studies (§V-D): every rank first
    /// sends its local gradient slice of block `i` to block `i`'s owner
    /// (gather-based aggregation), then the owners broadcast their
    /// updated blocks — the partitioned `MPI_Bcast` schedule.
    PartitionedBcast,
    /// The modern scheme: the flattened gradient vector is fused into
    /// buckets and each bucket is allreduced (the workload of
    /// arXiv:1810.11112 / 1802.06949).
    AllreduceGradients,
}

impl TrainingMode {
    pub fn label(&self) -> &'static str {
        match self {
            TrainingMode::PartitionedBcast => "partitioned-bcast",
            TrainingMode::AllreduceGradients => "allreduce",
        }
    }

    pub fn parse(s: &str) -> Option<TrainingMode> {
        match s.to_ascii_lowercase().as_str() {
            "partitioned-bcast" | "bcast" => Some(TrainingMode::PartitionedBcast),
            "allreduce" => Some(TrainingMode::AllreduceGradients),
            _ => None,
        }
    }
}

/// Simulated time for one iteration's broadcast calls.
///
/// CA-CNTK issues the per-block `MPI_Bcast`s back-to-back; blocks rooted
/// at different ranks overlap on the fabric wherever their paths don't
/// contend. We model that by merging every block's plan into a single
/// op DAG and letting the engine resolve the shared-link contention —
/// the makespan is the iteration's parameter-exchange time.
///
/// For the MV2-GDR-Opt backend the *enhanced tuning framework* is
/// workload-aware (§IV): besides the per-message isolated-latency picks,
/// it evaluates uniform algorithm choices against the whole concurrent
/// schedule and dispatches the fastest. Under concurrency the
/// topology-ordered pipelined chain — which crosses each node boundary
/// exactly once — typically beats latency-optimal trees that flood the
/// IB rails; this is precisely the paper's "conventional intuition needs
/// to be revisited" point.
pub fn comm_time_ns(
    comm: &mut Comm,
    engine: &mut Engine,
    backend: &BcastBackend,
    messages: &[BcastMsg],
) -> u64 {
    match backend {
        BcastBackend::NcclMv2(params) => {
            // template-cached: one hierarchical DAG per (root, chunk
            // shape), rescaled across the schedule's message sizes
            let merged = merge_schedule(comm, messages, |comm, spec, out| {
                out.merge(
                    &hierarchical::cached(comm, params, spec, hierarchical::DEFAULT_CHUNK).plan,
                );
            });
            execute(engine, merged)
        }
        BcastBackend::Mv2Opt(sel) => {
            // candidate 1: per-message isolated-latency tuned picks
            let mut best = execute(
                engine,
                merge_schedule(comm, messages, |comm, spec, out| {
                    out.merge(&sel.cached_plan(comm, spec).plan);
                }),
            );
            // candidates 2..: uniform algorithms judged on the schedule
            for algo in uniform_bcast_candidates() {
                let merged = merge_schedule(comm, messages, |comm, spec, out| {
                    out.merge(&crate::collectives::cached_plan(&algo, comm, spec).plan);
                });
                best = best.min(execute(engine, merged));
            }
            best
        }
    }
}

/// The uniform algorithm candidates MV2-GDR-Opt's workload-aware
/// judging evaluates against a whole concurrent schedule (§IV), shared
/// by the barrier-model scorer ([`comm_time_ns`]) and the overlap
/// timeline ([`super::timeline`]) — which judges them on the *full*
/// overlapped iteration DAG, where the winner under compute overlap can
/// differ from the isolated-latency winner.
pub(crate) fn uniform_bcast_candidates() -> [Algorithm; 5] {
    [
        Algorithm::Knomial { k: 2 },
        Algorithm::PipelinedChain { chunk: 256 << 10 },
        Algorithm::PipelinedChain { chunk: 1 << 20 },
        Algorithm::PipelinedChain { chunk: 4 << 20 },
        Algorithm::HostStagedKnomial { k: 4 },
    ]
}

/// Simulated time for the gradient-aggregation leg of the partitioned
/// schedule: every rank sends its local slice of block `i` (the full
/// block size — each rank holds gradients for the whole model) to block
/// `i`'s owner with plain point-to-point sends, all concurrent on the
/// fabric. This is the unpipelined gather CNTK performs before its
/// owners can broadcast; its all-to-all incast is exactly what makes the
/// partitioned scheme fall behind allreduce at scale.
pub fn aggregation_time_ns(comm: &mut Comm, engine: &mut Engine, messages: &[BcastMsg]) -> u64 {
    let n = comm.cluster().n_gpus();
    let mut plan = crate::netsim::Plan::new();
    for msg in messages {
        if msg.bytes == 0 {
            continue;
        }
        let root = msg.root % n;
        for r in 0..n {
            if r == root {
                continue;
            }
            comm.send(&mut plan, r, root, msg.bytes, vec![], None);
        }
    }
    execute(engine, plan)
}

/// Simulated time for one iteration's bucketed gradient allreduce: each
/// bucket's tuned allreduce plan is merged into one op DAG so buckets
/// overlap on the fabric, like the broadcast schedule above.
pub fn allreduce_time_ns(
    comm: &mut Comm,
    engine: &mut Engine,
    sel: &Selector,
    buckets: &[u64],
) -> u64 {
    let n = comm.cluster().n_gpus();
    let mut merged = crate::netsim::Plan::new();
    for &bytes in buckets {
        if bytes == 0 {
            continue;
        }
        let spec = CollectiveSpec::allreduce(n, bytes);
        // template-cached: equal-size buckets (the common case for fused
        // gradients) rescale the same DAG instead of rebuilding it
        merged.merge(&sel.cached_plan(comm, &spec).plan);
    }
    execute(engine, merged)
}

fn merge_schedule(
    comm: &mut Comm,
    messages: &[BcastMsg],
    // merges its plan into the accumulator — plans may be borrowed out
    // of the comm's template cache, so the callee does the merge while
    // the borrow is live
    mut merge_one: impl FnMut(&mut Comm, &BcastSpec, &mut crate::netsim::Plan),
) -> crate::netsim::Plan {
    let n = comm.cluster().n_gpus();
    let mut merged = crate::netsim::Plan::new();
    for msg in messages {
        if msg.bytes == 0 {
            continue;
        }
        let spec = BcastSpec::new(msg.root % n, n, msg.bytes);
        merge_one(comm, &spec, &mut merged);
    }
    merged
}

fn execute(engine: &mut Engine, merged: crate::netsim::Plan) -> u64 {
    if merged.is_empty() {
        0
    } else {
        // makespan-only path: no per-op timestamp bookkeeping
        engine.makespan_ns(&merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{bcast_messages, zoo::vgg16, MessageSchedule};
    use crate::topology::presets::kesch;

    #[test]
    fn both_backends_cost_vgg_schedule() {
        let cluster = kesch(2, 8).unwrap();
        let sel = Selector::tuned(&cluster);
        let nccl = NcclParams::default();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let msgs = bcast_messages(&vgg16(), 16, MessageSchedule::Partitioned);
        let t_mv2 = comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(&sel), &msgs);
        let t_nccl = comm_time_ns(
            &mut comm,
            &mut engine,
            &BcastBackend::NcclMv2(&nccl),
            &msgs,
        );
        assert!(t_mv2 > 0 && t_nccl > 0);
        // the paper's application-level claim: MV2-GDR-Opt matches or
        // beats NCCL-MV2-GDR
        assert!(t_mv2 <= t_nccl, "mv2 {t_mv2} vs nccl {t_nccl}");
    }

    #[test]
    fn zero_byte_messages_skipped() {
        let cluster = kesch(1, 2).unwrap();
        let sel = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let msgs = [BcastMsg { root: 0, bytes: 0 }];
        assert_eq!(
            comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(&sel), &msgs),
            0
        );
        assert_eq!(aggregation_time_ns(&mut comm, &mut engine, &msgs), 0);
        assert_eq!(allreduce_time_ns(&mut comm, &mut engine, &sel, &[0]), 0);
    }

    #[test]
    fn allreduce_schedule_costs_vgg_buckets() {
        let cluster = kesch(1, 8).unwrap();
        let sel = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let buckets =
            crate::models::allreduce_buckets(&vgg16(), crate::models::DEFAULT_BUCKET_BYTES);
        let t = allreduce_time_ns(&mut comm, &mut engine, &sel, &buckets);
        assert!(t > 0);
        // merged buckets overlap: no meaningfully slower than running
        // them back to back (small slack for FIFO interleaving tails)
        let serial: u64 = buckets
            .iter()
            .map(|&b| {
                let spec = crate::collectives::CollectiveSpec::allreduce(8, b);
                sel.latency_ns(&mut comm, &mut engine, &spec)
            })
            .sum();
        assert!(
            t <= serial + serial / 10,
            "merged {t} vs serial {serial}"
        );
    }

    #[test]
    fn aggregation_grows_with_scale() {
        // the all-to-all gather's incast hurts more at two nodes than one
        let small = kesch(1, 8).unwrap();
        let large = kesch(2, 16).unwrap();
        let mut t = [0u64; 2];
        for (i, cluster) in [&small, &large].into_iter().enumerate() {
            let n = cluster.n_gpus();
            let msgs = bcast_messages(&vgg16(), n, MessageSchedule::Partitioned);
            let mut comm = Comm::new(cluster);
            let mut engine = Engine::new(cluster);
            t[i] = aggregation_time_ns(&mut comm, &mut engine, &msgs);
        }
        assert!(t[0] > 0);
        assert!(t[1] > t[0], "32-GPU aggregation {} vs 8-GPU {}", t[1], t[0]);
    }

    #[test]
    fn training_mode_parse() {
        assert_eq!(
            TrainingMode::parse("bcast"),
            Some(TrainingMode::PartitionedBcast)
        );
        assert_eq!(
            TrainingMode::parse("allreduce"),
            Some(TrainingMode::AllreduceGradients)
        );
        assert_eq!(TrainingMode::parse("nope"), None);
        assert_eq!(TrainingMode::AllreduceGradients.label(), "allreduce");
    }
}
