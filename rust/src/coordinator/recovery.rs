//! The recovery layer: multi-iteration jobs driven *through* faults with
//! pluggable recovery policies (PAPER.md §V's re-formed rings; the
//! communicator-shrink / re-route / restart axis of the GPU-communication
//! survey in PAPERS.md).
//!
//! PR 7's fault model stops at one collective: a killed link either
//! detours inside the engine's retry budget or the run finishes degraded.
//! Real jobs are *sequences* of iterations, and real stacks react — this
//! module simulates an N-iteration job on the virtual clock, observing
//! failures after a configurable **detection latency** (failures are not
//! known the instant a link dies) and then applying a [`RecoveryPolicy`]:
//!
//! * [`RecoveryPolicy::Replan`] — rebuild the collective plan on the
//!   *surviving topology*: observed-dead links are removed from the
//!   routable graph ([`Cluster::kill_link`] bumps the topology
//!   generation, so the fresh `Comm`'s template cache and the re-tuned
//!   selector key on the new generation), ranks the failure disconnected
//!   are dropped, and the job retries the failed iteration. Re-planned
//!   routes avoid dead links entirely — no detour timeouts recur.
//! * [`RecoveryPolicy::Shrink`] — elastic shrink: the topology is left
//!   as-is (transfers crossing dead links keep paying engine-level
//!   detours), but ranks the failure cut off are dropped and the job
//!   continues at world size n−k with per-rank work rescaled (the
//!   partitioned blocks re-tile over fewer ranks; compute per rank grows
//!   by n/(n−k) for a fixed global batch).
//! * [`RecoveryPolicy::Restart`] — checkpoint/restart: pay a
//!   parameterized restore cost, rewind to the last checkpoint
//!   ([`RecoveryConfig::checkpoint_every`]) and replay on pristine
//!   hardware; faults already fired are healed, future ones still
//!   strike ([`FaultSchedule::shifted_healed`]).
//!
//! Every recovery epoch rebuilds `Comm` + `Engine` from the (possibly
//! mutated) topology — the engine's debug generation check makes reuse
//! across a mutation a hard error, which is exactly the invariant this
//! layer leans on. With an empty fault schedule no policy branch ever
//! executes and every policy's job makespan is bit-identical to the
//! no-recovery path (the golden-parity anchor in `rust/tests/recovery.rs`).

use crate::collectives::{self, Algorithm, CollectiveSpec};
use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::models::{allreduce_buckets, bcast_messages, DnnModel, MessageSchedule};
use crate::netsim::{Engine, FaultSchedule, LinkModel, UNREACHABLE_NS};
use crate::topology::{Cluster, LinkId};
use crate::tuning::Selector;

use super::schedule::{
    aggregation_time_ns, allreduce_time_ns, comm_time_ns, BcastBackend, TrainingMode,
};
use super::train::ExchangeOptions;

/// Default failure-detection latency (100 µs of virtual time): the gap
/// between a link dying and the job *observing* it (IB timeout / NCCL
/// watchdog scale, compressed for simulation).
pub const DEFAULT_DETECT_NS: u64 = 100_000;

/// Default virtual-time cost of rebuilding the communicator + plans on a
/// replan/shrink recovery (host-side work, cheap next to a restore).
pub const DEFAULT_REPLAN_NS: u64 = 200_000;

/// Default checkpoint-restore cost for `--recovery restart` when no
/// explicit `:COST` is given (50 ms — reading a checkpoint back beats
/// re-planning by orders of magnitude of virtual time).
pub const DEFAULT_RESTORE_NS: u64 = 50_000_000;

/// Default bound on recovery attempts before the job aborts.
pub const DEFAULT_MAX_RECOVERIES: u32 = 8;

/// What the job does when a failure is observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// PR 7 behavior: the first iteration that loses a rank aborts the
    /// job.
    #[default]
    None,
    /// Re-plan on the surviving topology (dead links unroutable, dead
    /// ranks dropped, plans rebuilt, selector re-tuned).
    Replan,
    /// Elastic shrink: drop cut-off ranks, keep going at n−k.
    Shrink,
    /// Checkpoint/restart: pay `restore_ns`, rewind to the last
    /// checkpoint, replay on healed hardware.
    Restart { restore_ns: u64 },
}

impl RecoveryPolicy {
    /// Parse the `--recovery` CLI value: `none`, `replan`, `shrink`,
    /// `restart` or `restart:<cost>` (duration suffixes as in `--faults`).
    pub fn parse(s: &str) -> Result<RecoveryPolicy> {
        let s = s.trim();
        match s {
            "none" => return Ok(RecoveryPolicy::None),
            "replan" => return Ok(RecoveryPolicy::Replan),
            "shrink" => return Ok(RecoveryPolicy::Shrink),
            "restart" => {
                return Ok(RecoveryPolicy::Restart {
                    restore_ns: DEFAULT_RESTORE_NS,
                })
            }
            _ => {}
        }
        if let Some(cost) = s.strip_prefix("restart:") {
            return Ok(RecoveryPolicy::Restart {
                restore_ns: crate::netsim::faults::parse_ns(cost)?,
            });
        }
        Err(Error::Usage(format!(
            "unknown recovery policy '{s}' (expected none|replan|shrink|restart[:<cost>])"
        )))
    }

    /// Stable short name (report rows, tables).
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryPolicy::None => "none",
            RecoveryPolicy::Replan => "replan",
            RecoveryPolicy::Shrink => "shrink",
            RecoveryPolicy::Restart { .. } => "restart",
        }
    }
}

/// The recovery knobs threaded through [`ExchangeOptions`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    pub policy: RecoveryPolicy,
    /// Virtual time between a kill firing and the job observing it.
    pub detect_ns: u64,
    /// Virtual time charged for a replan/shrink communicator rebuild.
    pub replan_ns: u64,
    /// Recovery attempts before the job gives up.
    pub max_recoveries: u32,
    /// Checkpoint cadence for the restart policy (iterations). The job
    /// rewinds to the highest completed multiple on restart.
    pub checkpoint_every: u32,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            policy: RecoveryPolicy::None,
            detect_ns: DEFAULT_DETECT_NS,
            replan_ns: DEFAULT_REPLAN_NS,
            max_recoveries: DEFAULT_MAX_RECOVERIES,
            checkpoint_every: 1,
        }
    }
}

impl RecoveryConfig {
    /// A config running `policy` with every other knob at its default.
    pub fn with_policy(policy: RecoveryPolicy) -> RecoveryConfig {
        RecoveryConfig {
            policy,
            ..RecoveryConfig::default()
        }
    }
}

/// The outcome of an N-iteration job run through faults.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    /// Iterations requested.
    pub iterations: usize,
    /// Iterations actually completed (== `iterations` unless aborted).
    pub completed: usize,
    /// Total virtual job time: iterations + detection + recovery costs.
    pub total_ns: u64,
    /// The job gave up (policy `None` hit a failure, the communicator
    /// fell below 2 ranks, or the recovery budget ran out).
    pub aborted: bool,
    /// Recovery attempts taken.
    pub recoveries: u32,
    /// Original rank ids of the final communicator, ascending.
    pub alive_ranks: Vec<usize>,
    /// Links observed dead over the job (empty after a restart healed
    /// them).
    pub dead_links: Vec<LinkId>,
    /// Makespan of the last *successful* iteration (0 when none ran) —
    /// the acceptance tests pin it below the unreachable sentinel.
    pub last_iteration_ns: u64,
}

impl JobOutcome {
    /// Surviving world size.
    pub fn final_n_ranks(&self) -> usize {
        self.alive_ranks.len()
    }
}

/// A per-iteration workload the generic job loop drives. Implementations
/// must treat `iteration_ns` as a pure function of the `(topology,
/// engine fault state)` pair so retries are reproducible.
pub trait Workload {
    /// Called at the start of every epoch (initially and after each
    /// topology mutation) with the current topology, before the epoch's
    /// `Comm`/`Engine` are built. Rebuild tuned state here.
    fn on_epoch(&mut self, topo: &Cluster);

    /// One iteration's virtual time on the current communicator. A value
    /// at or above [`UNREACHABLE_NS`] marks a failed iteration (some op
    /// completed at the sentinel).
    fn iteration_ns(&mut self, comm: &mut Comm, engine: &mut Engine) -> u64;
}

/// What the failure handler decided inside the epoch's borrow scope; the
/// topology mutation itself happens after `Comm`/`Engine` are dropped.
enum Pending {
    Abort,
    Replan,
    Shrink,
    Restart { restore_ns: u64 },
}

/// Drive `iterations` of `workload` through `schedule` (absolute virtual
/// time over the whole job) under `rc`. The core recovery loop shared by
/// the collective-level and training-level runners.
pub fn run_job<W: Workload>(
    cluster: &Cluster,
    schedule: &FaultSchedule,
    link_model: LinkModel,
    iterations: usize,
    rc: &RecoveryConfig,
    workload: &mut W,
) -> JobOutcome {
    let n0 = cluster.n_gpus();
    let all: Vec<usize> = (0..n0).collect();
    let mut topo = cluster.clone();
    let mut alive = all.clone();
    // `base` is the schedule re-anchored at `base_t` (restart heals the
    // past by re-basing); each attempt derives its engine-local view by
    // shifting `base` to the current clock.
    let mut base = schedule.clone();
    let mut base_t: u64 = 0;
    let mut clock: u64 = 0;
    let mut completed = 0usize;
    let mut last_ckpt = 0usize;
    let mut recoveries = 0u32;
    let mut dead: Vec<LinkId> = Vec::new();
    let mut aborted = false;
    let mut last_iteration_ns = 0u64;

    'job: while completed < iterations && !aborted {
        workload.on_epoch(&topo);
        let mut pending: Option<Pending> = None;
        {
            let mut comm = Comm::new(&topo);
            let mut engine = Engine::with_model(&topo, link_model);
            loop {
                let active = base.shifted(clock - base_t, &alive);
                if active.is_empty() {
                    engine.set_faults(None);
                } else {
                    engine.set_faults(Some(active.clone()));
                }
                let ns = workload.iteration_ns(&mut comm, &mut engine);
                if ns < UNREACHABLE_NS {
                    clock = clock.saturating_add(ns);
                    last_iteration_ns = ns;
                    completed += 1;
                    if rc.checkpoint_every > 0 && completed % rc.checkpoint_every as usize == 0 {
                        last_ckpt = completed;
                    }
                    if completed >= iterations {
                        break 'job;
                    }
                    continue;
                }
                // failed iteration: the job worked until the first kill,
                // then burned the detection latency before reacting
                let first_kill = active
                    .link_events
                    .iter()
                    .filter(|e| e.bw_factor == 0.0)
                    .map(|e| e.at_ns)
                    .min()
                    .unwrap_or(0);
                clock = clock.saturating_add(first_kill.saturating_add(rc.detect_ns));
                let observed = first_kill.saturating_add(rc.detect_ns);
                for e in active
                    .link_events
                    .iter()
                    .filter(|e| e.bw_factor == 0.0 && e.at_ns <= observed)
                {
                    if !dead.contains(&e.link) {
                        dead.push(e.link);
                    }
                }
                recoveries += 1;
                pending = Some(match rc.policy {
                    RecoveryPolicy::None => Pending::Abort,
                    _ if recoveries > rc.max_recoveries => Pending::Abort,
                    RecoveryPolicy::Replan => Pending::Replan,
                    RecoveryPolicy::Shrink => Pending::Shrink,
                    RecoveryPolicy::Restart { restore_ns } => Pending::Restart { restore_ns },
                });
                break;
            }
        }
        match pending {
            None => {}
            Some(Pending::Abort) => aborted = true,
            Some(Pending::Replan) => {
                clock = clock.saturating_add(rc.replan_ns);
                for &l in &dead {
                    // idempotent; the clone shares the original link ids
                    let _ = topo.kill_link(l);
                }
                let keep = reachable_ranks(&topo);
                if keep.len() < 2 {
                    aborted = true;
                } else if keep.len() < topo.n_gpus() {
                    let prev = alive.clone();
                    alive = keep.iter().map(|&i| prev[i]).collect();
                    topo.retain_ranks(&keep)
                        .expect("reachable_ranks produced an invalid subset");
                }
            }
            Some(Pending::Shrink) => {
                clock = clock.saturating_add(rc.replan_ns);
                // probe reachability on a throwaway clone with the dead
                // links removed; the live topology keeps them routable
                // (transfers detour at the engine level)
                let mut probe = topo.clone();
                for &l in &dead {
                    let _ = probe.kill_link(l);
                }
                let keep = reachable_ranks(&probe);
                if keep.len() < 2 {
                    aborted = true;
                } else if keep.len() < topo.n_gpus() {
                    let prev = alive.clone();
                    alive = keep.iter().map(|&i| prev[i]).collect();
                    topo.retain_ranks(&keep)
                        .expect("reachable_ranks produced an invalid subset");
                }
            }
            Some(Pending::Restart { restore_ns }) => {
                clock = clock.saturating_add(restore_ns);
                completed = last_ckpt;
                topo = cluster.clone();
                alive = all.clone();
                base = schedule.shifted_healed(clock, &all);
                base_t = clock;
                dead.clear();
            }
        }
    }

    JobOutcome {
        iterations,
        completed,
        total_ns: clock,
        aborted,
        recoveries,
        alive_ranks: alive,
        dead_links: dead,
        last_iteration_ns,
    }
}

/// Ranks (current indices, ascending) that can still reach — and be
/// reached by — rank 0 on `topo`'s routable graph. Rank 0 anchors the
/// surviving communicator (the re-formed ring's root).
fn reachable_ranks(topo: &Cluster) -> Vec<usize> {
    let root = topo.rank_device(0);
    (0..topo.n_gpus())
        .filter(|&r| {
            let dev = topo.rank_device(r);
            topo.route(root, dev).is_ok() && topo.route(dev, root).is_ok()
        })
        .collect()
}

/// The repeated-collective workload: one `algo` collective of `bytes`
/// per iteration (the Monte Carlo sweeps' unit of work).
pub struct CollectiveWorkload {
    pub algorithm: Algorithm,
    pub bytes: u64,
}

impl Workload for CollectiveWorkload {
    fn on_epoch(&mut self, _topo: &Cluster) {}

    fn iteration_ns(&mut self, comm: &mut Comm, engine: &mut Engine) -> u64 {
        let n = comm.cluster().n_gpus();
        let spec = CollectiveSpec::new(0, n, self.bytes);
        let cp = collectives::cached_plan(&self.algorithm, comm, &spec);
        engine.makespan_ns(&cp.plan)
    }
}

/// Run an N-iteration repeated-collective job through `schedule` under a
/// recovery policy.
pub fn run_collective_job(
    cluster: &Cluster,
    algorithm: &Algorithm,
    bytes: u64,
    iterations: usize,
    schedule: &FaultSchedule,
    link_model: LinkModel,
    rc: &RecoveryConfig,
) -> JobOutcome {
    let mut w = CollectiveWorkload {
        algorithm: *algorithm,
        bytes,
    };
    run_job(cluster, schedule, link_model, iterations, rc, &mut w)
}

/// The training workload: per iteration, compute (rescaled when the
/// world shrinks — fixed global batch over fewer ranks) plus the full
/// gradient/parameter exchange of `mode`, composed exactly like
/// [`super::train::estimate_training_iteration_opts`]. On a topology
/// mutation the selector re-tunes only the affected size classes
/// ([`Selector::retuned_for`]).
pub struct TrainingWorkload<'a> {
    model: &'a DnnModel,
    base_sel: &'a Selector,
    sel: Selector,
    mode: TrainingMode,
    overlap: bool,
    bucket_bytes: u64,
    compute_ns0: u64,
    n0: usize,
    first_epoch: bool,
}

impl<'a> TrainingWorkload<'a> {
    pub fn new(
        model: &'a DnnModel,
        sel: &'a Selector,
        mode: TrainingMode,
        overlap: bool,
        bucket_bytes: u64,
        compute_ns0: u64,
        n0: usize,
    ) -> TrainingWorkload<'a> {
        TrainingWorkload {
            model,
            base_sel: sel,
            sel: sel.clone(),
            mode,
            overlap,
            bucket_bytes,
            compute_ns0,
            n0,
            first_epoch: true,
        }
    }
}

impl Workload for TrainingWorkload<'_> {
    fn on_epoch(&mut self, topo: &Cluster) {
        if self.first_epoch {
            // the untouched topology dispatches on the caller's selector
            // verbatim — the golden-parity anchor
            self.first_epoch = false;
            return;
        }
        self.sel = self.base_sel.retuned_for(topo);
    }

    fn iteration_ns(&mut self, comm: &mut Comm, engine: &mut Engine) -> u64 {
        let n = comm.cluster().n_gpus();
        // fixed global batch: per-rank compute grows as the world shrinks
        let compute_ns = if n == self.n0 {
            self.compute_ns0
        } else {
            ((self.compute_ns0 as u128 * self.n0 as u128).div_ceil(n as u128)) as u64
        };
        if self.overlap {
            return super::timeline::overlap_iteration_ns(
                comm,
                engine,
                &self.sel,
                self.mode,
                self.model,
                compute_ns,
                self.bucket_bytes,
            );
        }
        let comm_ns = match self.mode {
            TrainingMode::PartitionedBcast => {
                let msgs = bcast_messages(self.model, n, MessageSchedule::Partitioned);
                aggregation_time_ns(comm, engine, &msgs).saturating_add(comm_time_ns(
                    comm,
                    engine,
                    &BcastBackend::Mv2Opt(&self.sel),
                    &msgs,
                ))
            }
            TrainingMode::AllreduceGradients => {
                let buckets = allreduce_buckets(self.model, self.bucket_bytes);
                allreduce_time_ns(comm, engine, &self.sel, &buckets)
            }
        };
        compute_ns.saturating_add(comm_ns)
    }
}

/// Simulate an N-iteration training job through faults: compute + full
/// exchange per iteration, detection + recovery per failure, all on the
/// virtual clock. `opts` carries the exchange shape, the link model, the
/// fault schedule *and* the recovery policy ([`ExchangeOptions::recovery`]).
/// With no faults installed the outcome is `iterations ×` the
/// single-iteration estimate, bit-for-bit, whatever the policy.
#[allow(clippy::too_many_arguments)]
pub fn run_training_job(
    cluster: &Cluster,
    model: &DnnModel,
    sel: &Selector,
    mode: TrainingMode,
    iterations: usize,
    global_batch: usize,
    compute_us_override: f64,
    opts: ExchangeOptions<'_>,
) -> JobOutcome {
    let n0 = cluster.n_gpus();
    let compute_us =
        super::train::compute_us_for(model, n0, global_batch, compute_us_override);
    let compute_ns0 = (compute_us * 1000.0).round() as u64;
    let empty = FaultSchedule::default();
    let schedule = opts.faults.unwrap_or(&empty);
    let mut w = TrainingWorkload::new(
        model,
        sel,
        mode,
        opts.overlap,
        opts.bucket_bytes,
        compute_ns0,
        n0,
    );
    run_job(
        cluster,
        schedule,
        opts.link_model,
        iterations,
        &opts.recovery,
        &mut w,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::LinkEvent;
    use crate::topology::presets::kesch;

    #[test]
    fn policy_parse_round_trip() {
        assert_eq!(RecoveryPolicy::parse("none").unwrap(), RecoveryPolicy::None);
        assert_eq!(
            RecoveryPolicy::parse("replan").unwrap(),
            RecoveryPolicy::Replan
        );
        assert_eq!(
            RecoveryPolicy::parse("shrink").unwrap(),
            RecoveryPolicy::Shrink
        );
        assert_eq!(
            RecoveryPolicy::parse("restart").unwrap(),
            RecoveryPolicy::Restart {
                restore_ns: DEFAULT_RESTORE_NS
            }
        );
        assert_eq!(
            RecoveryPolicy::parse("restart:2ms").unwrap(),
            RecoveryPolicy::Restart {
                restore_ns: 2_000_000
            }
        );
        assert!(RecoveryPolicy::parse("reboot").is_err());
        assert!(RecoveryPolicy::parse("restart:banana").is_err());
        assert_eq!(RecoveryPolicy::Replan.name(), "replan");
        assert_eq!(
            RecoveryPolicy::Restart { restore_ns: 1 }.name(),
            "restart"
        );
    }

    #[test]
    fn healthy_job_is_n_times_one_iteration() {
        let cluster = kesch(1, 4).unwrap();
        let empty = FaultSchedule::default();
        let one = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            1,
            &empty,
            LinkModel::Fifo,
            &RecoveryConfig::default(),
        );
        assert!(!one.aborted);
        for policy in [
            RecoveryPolicy::None,
            RecoveryPolicy::Replan,
            RecoveryPolicy::Shrink,
            RecoveryPolicy::Restart { restore_ns: 1 << 20 },
        ] {
            let job = run_collective_job(
                &cluster,
                &Algorithm::Chain,
                64 << 10,
                5,
                &empty,
                LinkModel::Fifo,
                &RecoveryConfig::with_policy(policy),
            );
            assert!(!job.aborted);
            assert_eq!(job.completed, 5);
            assert_eq!(job.recoveries, 0);
            assert_eq!(job.total_ns, 5 * one.total_ns, "{}", policy.name());
            assert_eq!(job.last_iteration_ns, one.total_ns);
            assert_eq!(job.alive_ranks, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn none_policy_aborts_on_first_failure() {
        // kill every link out of rank 3's GPU so its payload is
        // undeliverable whatever the detour
        let cluster = kesch(1, 4).unwrap();
        let dst = cluster.rank_device(3);
        let mut sched = FaultSchedule::default().with_retry(0, 1000);
        for l in cluster.links() {
            if l.dst == dst || l.src == dst {
                sched.link_events.push(LinkEvent {
                    at_ns: 0,
                    link: l.id,
                    bw_factor: 0.0,
                });
            }
        }
        sched.normalize();
        let job = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            3,
            &sched,
            LinkModel::Fifo,
            &RecoveryConfig::default(),
        );
        assert!(job.aborted);
        assert_eq!(job.completed, 0);
        assert_eq!(job.recoveries, 1);
    }

    #[test]
    fn replan_drops_cut_off_rank_and_finishes() {
        let cluster = kesch(1, 4).unwrap();
        let dst = cluster.rank_device(3);
        let mut sched = FaultSchedule::default().with_retry(0, 1000);
        for l in cluster.links() {
            if l.dst == dst || l.src == dst {
                sched.link_events.push(LinkEvent {
                    at_ns: 0,
                    link: l.id,
                    bw_factor: 0.0,
                });
            }
        }
        sched.normalize();
        let rc = RecoveryConfig::with_policy(RecoveryPolicy::Replan);
        let job = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            3,
            &sched,
            LinkModel::Fifo,
            &rc,
        );
        assert!(!job.aborted, "{job:?}");
        assert_eq!(job.completed, 3);
        assert_eq!(job.recoveries, 1);
        assert_eq!(job.alive_ranks, vec![0, 1, 2], "rank 3 is unreachable");
        assert!(job.last_iteration_ns < UNREACHABLE_NS);
        assert!(!job.dead_links.is_empty());
        // time accounting: detection + replan charges are in the total
        assert!(job.total_ns > 3 * job.last_iteration_ns);
    }

    #[test]
    fn shrink_matches_replan_world_on_isolating_failure() {
        let cluster = kesch(1, 4).unwrap();
        let dst = cluster.rank_device(2);
        let mut sched = FaultSchedule::default().with_retry(0, 1000);
        for l in cluster.links() {
            if l.dst == dst || l.src == dst {
                sched.link_events.push(LinkEvent {
                    at_ns: 0,
                    link: l.id,
                    bw_factor: 0.0,
                });
            }
        }
        sched.normalize();
        let job = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            3,
            &sched,
            LinkModel::Fifo,
            &RecoveryConfig::with_policy(RecoveryPolicy::Shrink),
        );
        assert!(!job.aborted, "{job:?}");
        assert_eq!(job.completed, 3);
        assert_eq!(job.alive_ranks, vec![0, 1, 3]);
    }

    #[test]
    fn restart_replays_from_checkpoint_and_heals() {
        // a kill striking mid-job, late enough that iterations complete
        // before it: restart must rewind to the checkpoint and replay on
        // healed hardware (no further failures → full completion)
        let cluster = kesch(1, 4).unwrap();
        let empty = FaultSchedule::default();
        let one = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            1,
            &empty,
            LinkModel::Fifo,
            &RecoveryConfig::default(),
        )
        .total_ns;
        let dst = cluster.rank_device(1);
        let strike = one * 2 + one / 2; // mid third iteration
        let mut sched = FaultSchedule::default().with_retry(0, 1000);
        for l in cluster.links() {
            if l.dst == dst || l.src == dst {
                sched.link_events.push(LinkEvent {
                    at_ns: strike,
                    link: l.id,
                    bw_factor: 0.0,
                });
            }
        }
        sched.normalize();
        let rc = RecoveryConfig {
            policy: RecoveryPolicy::Restart {
                restore_ns: 5 * one,
            },
            checkpoint_every: 2,
            ..RecoveryConfig::default()
        };
        let job = run_collective_job(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            5,
            &sched,
            LinkModel::Fifo,
            &rc,
        );
        assert!(!job.aborted, "{job:?}");
        assert_eq!(job.completed, 5);
        assert_eq!(job.recoveries, 1);
        assert_eq!(job.alive_ranks.len(), 4, "restart keeps the full world");
        assert!(job.dead_links.is_empty(), "restart heals observed damage");
        // 2 clean + failed 3rd (partial + detect) + restore + replay 3
        assert!(job.total_ns > 5 * one + 5 * one);
    }
}
