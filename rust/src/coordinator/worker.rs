//! Worker side of the data-parallel engine.

/// A gradient computer for one data-parallel rank. The e2e_train example
//  backs this with the AOT-compiled PJRT training step; unit tests use
//  analytic toy problems.
pub trait ComputeBackend {
    /// Compute `(gradient, loss)` for the current parameters on this
    /// worker's shard for iteration `iter`.
    fn grad(&mut self, params: &[f32], iter: u64) -> (Vec<f32>, f32);

    /// Parameter count (must match across workers).
    fn n_params(&self) -> usize;
}

/// A quadratic-bowl toy problem: `loss = Σ (p - target)²`, exact gradient.
/// Converges under SGD from any start — the coordinator's test fixture.
#[derive(Debug, Clone)]
pub struct QuadBackend {
    pub target: Vec<f32>,
}

impl QuadBackend {
    pub fn new(target: Vec<f32>) -> QuadBackend {
        QuadBackend { target }
    }
}

impl ComputeBackend for QuadBackend {
    fn grad(&mut self, params: &[f32], _iter: u64) -> (Vec<f32>, f32) {
        assert_eq!(params.len(), self.target.len());
        let mut g = Vec::with_capacity(params.len());
        let mut loss = 0.0f32;
        for (p, t) in params.iter().zip(&self.target) {
            let d = p - t;
            loss += d * d;
            g.push(2.0 * d);
        }
        (g, loss)
    }

    fn n_params(&self) -> usize {
        self.target.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quad_gradient_points_at_target() {
        let mut b = QuadBackend::new(vec![1.0, -2.0]);
        let (g, loss) = b.grad(&[0.0, 0.0], 0);
        assert_eq!(g, vec![-2.0, 4.0]);
        assert_eq!(loss, 5.0);
        let (g2, l2) = b.grad(&[1.0, -2.0], 1);
        assert_eq!(g2, vec![0.0, 0.0]);
        assert_eq!(l2, 0.0);
    }
}
