//! Training-run accounting.

/// One iteration's record.
#[derive(Debug, Clone)]
pub struct IterationMetrics {
    pub iter: usize,
    /// Mean loss across workers.
    pub loss: f32,
    /// Wall-clock compute time for the gradient phase, ns.
    pub compute_ns: u64,
    /// *Simulated* parameter-broadcast time, ns.
    pub comm_ns: u64,
}

/// A full run.
#[derive(Debug, Clone, Default)]
pub struct TrainingMetrics {
    pub iterations: Vec<IterationMetrics>,
}

impl TrainingMetrics {
    pub fn push(&mut self, m: IterationMetrics) {
        self.iterations.push(m);
    }

    pub fn final_loss(&self) -> f32 {
        self.iterations.last().map(|m| m.loss).unwrap_or(f32::NAN)
    }

    pub fn first_loss(&self) -> f32 {
        self.iterations.first().map(|m| m.loss).unwrap_or(f32::NAN)
    }

    /// Did the loss go down meaningfully over the run?
    pub fn loss_decreased(&self) -> bool {
        !self.iterations.is_empty() && self.final_loss() < self.first_loss() * 0.9
    }

    pub fn total_comm_ns(&self) -> u64 {
        self.iterations.iter().map(|m| m.comm_ns).sum()
    }

    pub fn total_compute_ns(&self) -> u64 {
        self.iterations.iter().map(|m| m.compute_ns).sum()
    }

    /// Render the loss curve as `iter,loss,compute_us,comm_us` CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("iter,loss,compute_us,comm_us\n");
        for m in &self.iterations {
            out.push_str(&format!(
                "{},{:.6},{:.1},{:.1}\n",
                m.iter,
                m.loss,
                m.compute_ns as f64 / 1000.0,
                m.comm_ns as f64 / 1000.0
            ));
        }
        out
    }

    /// A coarse text plot of the loss curve (for terminal logs).
    pub fn loss_sparkline(&self, width: usize) -> String {
        if self.iterations.is_empty() {
            return String::new();
        }
        let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let step = (self.iterations.len() as f64 / width as f64).max(1.0);
        let points: Vec<f32> = (0..width.min(self.iterations.len()))
            .map(|i| self.iterations[(i as f64 * step) as usize].loss)
            .collect();
        let max = points.iter().cloned().fold(f32::MIN, f32::max);
        let min = points.iter().cloned().fold(f32::MAX, f32::min);
        let range = (max - min).max(1e-12);
        points
            .iter()
            .map(|&x| glyphs[(((x - min) / range) * 7.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run() -> TrainingMetrics {
        let mut m = TrainingMetrics::default();
        for i in 0..10 {
            m.push(IterationMetrics {
                iter: i,
                loss: 10.0 / (i as f32 + 1.0),
                compute_ns: 1000,
                comm_ns: 500,
            });
        }
        m
    }

    #[test]
    fn totals_and_convergence() {
        let m = run();
        assert!(m.loss_decreased());
        assert_eq!(m.total_comm_ns(), 5000);
        assert_eq!(m.total_compute_ns(), 10_000);
        assert_eq!(m.final_loss(), 1.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = run().to_csv();
        assert!(csv.starts_with("iter,loss"));
        assert_eq!(csv.lines().count(), 11);
    }

    #[test]
    fn sparkline_renders() {
        let s = run().loss_sparkline(10);
        assert_eq!(s.chars().count(), 10);
        assert!(s.starts_with('█'));
    }

    #[test]
    fn empty_run_safe() {
        let m = TrainingMetrics::default();
        assert!(!m.loss_decreased());
        assert!(m.final_loss().is_nan());
        assert_eq!(m.loss_sparkline(5), "");
    }
}
