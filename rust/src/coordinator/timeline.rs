//! The per-iteration compute/communication overlap timeline.
//!
//! The paper's application study (§V-D) hinges on gradient exchange
//! overlapping backprop, yet the barrier estimators model an iteration
//! as `compute + comm`. This module drops that last analytic shortcut:
//! it emits every rank's backprop as a chain of per-layer [`SimOp::Delay`]
//! ops (reverse layer order, [`DnnModel::layer_compute_split`] durations),
//! buckets the gradient exchange, and stitches each bucket's collective
//! plan into ONE engine DAG — a bucket's first ops depend on the compute
//! of the layers it covers — so the DAG's makespan *is* the overlapped
//! iteration time. Staggered bucket release, exposed communication and
//! fabric contention all fall out of the simulation; there is no
//! `max(compute, comm)` formula anywhere.
//!
//! DAG shape (DESIGN.md §Overlap timeline):
//!
//! * **compute** — per rank, a dependency chain of per-layer delays on
//!   the rank's GPU, highest layer first (backprop order);
//! * **exchange** — EXACTLY the decomposition the barrier estimators
//!   cost (`allreduce_buckets` for the allreduce mode, the partitioned
//!   rank-blocks for CNTK's scheme), merged in the same order with
//!   [`Plan::merge`]/[`Plan::merge_after`], so with zero per-layer
//!   compute the timeline's makespan is bit-identical to the barrier
//!   model's communication time (the golden-parity anchor);
//! * **stitching** — each unit's per-rank entry ops
//!   ([`CollectivePlan::rank_entry_ops`]) gain a dependency on the
//!   issuing rank's delay for the unit's last-computed layer
//!   ([`ExchangeUnit::dep_layer`]; backprop runs backwards, so that is
//!   the *lowest* covered layer index). Data-parallel ranks run
//!   identical compute, so gating entries is timing-exact even for ring
//!   algorithms whose interior ops implicitly use local data;
//! * **contention** — the timeline runs many bucket collectives
//!   *concurrently* on the shared fabric, so the engine's
//!   [`crate::netsim::LinkModel`] matters here more than anywhere else:
//!   under FIFO the concurrent buckets serialize on shared links, under
//!   max-min fair share they progressively fill them. The engine passed
//!   in carries the model (`ExchangeOptions::link_model` upstream);
//!   this module is model-agnostic;
//! * **partitioned mode** keeps CNTK's aggregation→broadcast barrier —
//!   one zero-duration op depending on every aggregation send, handed
//!   to [`Plan::merge_after`] as each broadcast's external dep: the
//!   overlap hides compute behind the exchange, not the exchange's own
//!   synchronization — which is also what keeps the zero-compute
//!   equality exact. Mv2Opt's uniform candidates are judged on the
//!   *full* timeline (delays + aggregation base built once, cloned per
//!   candidate), so the dispatched algorithm is the fastest under
//!   compute overlap.

use crate::collectives::{self, Algorithm, CollectivePlan, CollectiveSpec};
use crate::comm::Comm;
use crate::models::{allreduce_buckets, bcast_messages, DnnModel, MessageSchedule};
use crate::netsim::{Deps, Engine, OpId, Plan, SimOp};
use crate::topology::Cluster;
use crate::tuning::Selector;

use super::schedule::{uniform_bcast_candidates, TrainingMode};

/// One gradient-exchange unit of the timeline: a contiguous byte range
/// of the flattened gradient vector, exchanged as one collective call
/// once every layer it covers has finished backprop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExchangeUnit {
    /// Owner/root rank (the partitioned blocks; 0 for allreduce buckets).
    pub root: usize,
    pub bytes: u64,
    /// Layer (layer-order index) whose backprop completes *last* among
    /// those this unit's byte range covers — since backprop runs in
    /// reverse layer order, the lowest covered index. The unit's release
    /// gate.
    pub dep_layer: usize,
}

/// Map a schedule's contiguous `(root, bytes)` ranges — in order, tiling
/// the flattened gradient vector — onto the layers they cover.
/// Zero-byte parts are dropped, mirroring the barrier estimators.
/// Degenerate models — no layers, or layers with zero total bytes —
/// have nothing to gate an exchange on and yield no units (guarding the
/// `total - 1` below against underflow).
pub fn exchange_units(model: &DnnModel, parts: &[(usize, u64)]) -> Vec<ExchangeUnit> {
    if model.layers.is_empty() {
        return Vec::new();
    }
    let mut prefix = Vec::with_capacity(model.layers.len() + 1);
    let mut acc = 0u64;
    prefix.push(0u64);
    for l in &model.layers {
        acc += l.bytes();
        prefix.push(acc);
    }
    let total = acc;
    if total == 0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    let mut offset = 0u64;
    for &(root, bytes) in parts {
        let start = offset;
        offset += bytes;
        if bytes == 0 {
            continue;
        }
        // the unit's lowest covered layer is the one containing its
        // first byte (layer ranges tile the vector; zero-byte layers
        // can never contain it). Parts past the end of the vector clamp
        // onto the last layer.
        let a = start.min(total - 1);
        let dep_layer = prefix
            .partition_point(|&p| p <= a)
            .saturating_sub(1)
            .min(model.layers.len() - 1);
        out.push(ExchangeUnit {
            root,
            bytes,
            dep_layer,
        });
    }
    out
}

/// Emit every rank's backprop delay chain (reverse layer order, each on
/// the rank's own GPU) into `plan`. Returns `ops[rank][layer]`: the
/// delay computing `layer`'s gradient on `rank` (layer-order indexing —
/// `ops[r][0]` is the last delay of rank `r`'s chain).
pub fn push_backprop_delays(
    plan: &mut Plan,
    cluster: &Cluster,
    layer_ns: &[u64],
) -> Vec<Vec<OpId>> {
    let n = cluster.n_gpus();
    let mut ops = vec![vec![0usize; layer_ns.len()]; n];
    for (r, per_rank) in ops.iter_mut().enumerate() {
        let dev = cluster.rank_device(r);
        let mut prev: Option<OpId> = None;
        for l in (0..layer_ns.len()).rev() {
            let id = plan.push(
                SimOp::Delay {
                    dev,
                    dur_ns: layer_ns[l],
                },
                Deps::from_opt(prev),
                None,
            );
            per_rank[l] = id;
            prev = Some(id);
        }
    }
    ops
}

/// Merge one unit's collective plan into the timeline: entry ops gain
/// the `extra` external deps (the partitioned aggregation barrier) plus,
/// per rank, a dependency on that rank's `dep_layer` delay.
fn stitch_unit(
    timeline: &mut Plan,
    cluster: &Cluster,
    bp: &CollectivePlan,
    delays: &[Vec<OpId>],
    dep_layer: usize,
    extra: &[OpId],
) {
    let entries = bp.rank_entry_ops(cluster);
    let h = timeline.merge_after(&bp.plan, extra);
    for (r, ops) in entries.iter().enumerate() {
        // models without layers emit no delays; nothing to gate on
        let gate = match delays.get(r).and_then(|d| d.get(dep_layer)) {
            Some(&g) => g,
            None => continue,
        };
        for &e in ops {
            timeline.add_dep(h.offset + e, gate);
        }
    }
}

/// A broadcast candidate for the partitioned mode's workload-aware
/// judging: the per-message tuned picks, or one uniform algorithm.
enum BcastCandidate<'s> {
    Tuned(&'s Selector),
    Uniform(Algorithm),
}

impl BcastCandidate<'_> {
    #[allow(clippy::too_many_arguments)]
    fn stitch(
        &self,
        comm: &mut Comm,
        spec: &CollectiveSpec,
        timeline: &mut Plan,
        cluster: &Cluster,
        delays: &[Vec<OpId>],
        dep_layer: usize,
        extra: &[OpId],
    ) {
        match self {
            BcastCandidate::Tuned(sel) => {
                let bp = sel.cached_plan(comm, spec);
                stitch_unit(timeline, cluster, bp, delays, dep_layer, extra);
            }
            BcastCandidate::Uniform(algo) => {
                let bp = collectives::cached_plan(algo, comm, spec);
                stitch_unit(timeline, cluster, bp, delays, dep_layer, extra);
            }
        }
    }
}

/// Makespan of the overlapped allreduce iteration: per-rank backprop
/// delays + every gradient bucket's tuned allreduce, each bucket gated
/// on the compute of the layers it covers.
pub fn allreduce_timeline_ns(
    comm: &mut Comm,
    engine: &mut Engine,
    sel: &Selector,
    model: &DnnModel,
    layer_ns: &[u64],
    bucket_bytes: u64,
) -> u64 {
    let cluster = comm.cluster();
    let n = cluster.n_gpus();
    let parts: Vec<(usize, u64)> = allreduce_buckets(model, bucket_bytes)
        .into_iter()
        .map(|b| (0usize, b))
        .collect();
    let units = exchange_units(model, &parts);
    let mut plan = Plan::new();
    let delays = push_backprop_delays(&mut plan, cluster, layer_ns);
    for u in &units {
        let spec = CollectiveSpec::allreduce(n, u.bytes);
        let bp = sel.cached_plan(comm, &spec);
        stitch_unit(&mut plan, cluster, bp, &delays, u.dep_layer, &[]);
    }
    makespan(engine, &plan)
}

/// Makespan of the best overlapped partitioned (CA-CNTK) iteration over
/// the broadcast candidates: delays + the per-block aggregation sends
/// (each gated on its block's compute) + the owner broadcasts behind
/// the aggregation barrier. The delays + aggregation base is identical
/// across candidates, so it is built once and cloned per candidate;
/// the barrier is one zero-duration op depending on every aggregation
/// send (same ready times as listing all of them on every broadcast
/// entry, at one dependency per entry instead of n·(n−1)).
fn partitioned_best_ns(
    comm: &mut Comm,
    engine: &mut Engine,
    sel: &Selector,
    units: &[ExchangeUnit],
    layer_ns: &[u64],
) -> u64 {
    let cluster = comm.cluster();
    let n = cluster.n_gpus();
    let mut base = Plan::new();
    let delays = push_backprop_delays(&mut base, cluster, layer_ns);
    // aggregation leg: the same sends in the same order as
    // `aggregation_time_ns`, gated per sender on the block's last layer
    let mut agg: Vec<OpId> = Vec::new();
    for u in units {
        let root = u.root % n;
        for r in 0..n {
            if r == root {
                continue;
            }
            let deps = match delays.get(r).and_then(|d| d.get(u.dep_layer)) {
                Some(&gate) => Deps::one(gate),
                None => Deps::none(),
            };
            agg.push(comm.send(&mut base, r, root, u.bytes, deps, None));
        }
    }
    // CNTK's aggregation barrier, reified as one zero-duration op (the
    // exchange's own synchronization is preserved; overlap hides
    // compute only)
    let barrier: Vec<OpId> = if agg.is_empty() {
        Vec::new()
    } else {
        vec![base.push(
            SimOp::Delay {
                dev: cluster.rank_device(0),
                dur_ns: 0,
            },
            agg,
            None,
        )]
    };
    let mut candidates = vec![BcastCandidate::Tuned(sel)];
    candidates.extend(uniform_bcast_candidates().into_iter().map(BcastCandidate::Uniform));
    let mut best = u64::MAX;
    for cand in &candidates {
        let mut plan = base.clone();
        for u in units {
            let spec = CollectiveSpec::new(u.root % n, n, u.bytes);
            cand.stitch(comm, &spec, &mut plan, cluster, &delays, u.dep_layer, &barrier);
        }
        best = best.min(makespan(engine, &plan));
    }
    best
}

/// The overlapped-iteration makespan for a training mode: per-layer
/// backprop + the mode's full exchange in one DAG. For the partitioned
/// mode, Mv2Opt's candidate judging (per-message tuned picks vs the
/// uniform menu) runs on the complete timeline, so the winner is the
/// fastest schedule *under compute overlap* — with zero compute it
/// degenerates to the barrier model's winner exactly.
pub fn overlap_iteration_ns(
    comm: &mut Comm,
    engine: &mut Engine,
    sel: &Selector,
    mode: TrainingMode,
    model: &DnnModel,
    compute_ns: u64,
    bucket_bytes: u64,
) -> u64 {
    let layer_ns = model.layer_compute_split(compute_ns);
    match mode {
        TrainingMode::AllreduceGradients => {
            allreduce_timeline_ns(comm, engine, sel, model, &layer_ns, bucket_bytes)
        }
        TrainingMode::PartitionedBcast => {
            let n = comm.cluster().n_gpus();
            let msgs = bcast_messages(model, n, MessageSchedule::Partitioned);
            let parts: Vec<(usize, u64)> = msgs.iter().map(|m| (m.root, m.bytes)).collect();
            let units = exchange_units(model, &parts);
            partitioned_best_ns(comm, engine, sel, &units, &layer_ns)
        }
    }
}

fn makespan(engine: &mut Engine, plan: &Plan) -> u64 {
    if plan.is_empty() {
        0
    } else {
        engine.makespan_ns(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{googlenet, vgg16};
    use crate::topology::presets::kesch;

    #[test]
    fn exchange_units_map_byte_ranges_to_layers() {
        let m = vgg16();
        let total = m.total_bytes();
        // one unit covering everything waits on layer 0 (computed last)
        let all = exchange_units(&m, &[(0, total)]);
        assert_eq!(all, vec![ExchangeUnit { root: 0, bytes: total, dep_layer: 0 }]);
        // per-layer tiling: each unit's gate is its own layer
        let parts: Vec<(usize, u64)> = m.layers.iter().map(|l| (0, l.bytes())).collect();
        let per_layer = exchange_units(&m, &parts);
        assert_eq!(per_layer.len(), m.layers.len());
        for (i, u) in per_layer.iter().enumerate() {
            assert_eq!(u.dep_layer, i, "unit {i} gates on its own layer");
        }
        // zero-byte parts are dropped
        assert!(exchange_units(&m, &[(0, 0), (1, 0)]).is_empty());
        // a unit straddling layers 0 and 1 gates on layer 0
        let b0 = m.layers[0].bytes();
        let straddle = exchange_units(&m, &[(0, b0 + 4)]);
        assert_eq!(straddle[0].dep_layer, 0);
        // ...and the next unit starts inside layer 1
        let two = exchange_units(&m, &[(0, b0 + 4), (1, 8)]);
        assert_eq!(two[1].dep_layer, 1);
    }

    #[test]
    fn degenerate_models_yield_no_units() {
        // regression: a zero-layer (or zero-param) model used to reach
        // `start.min(total - 1)` territory; both degenerate shapes must
        // short-circuit to an empty unit list instead
        use crate::models::DnnModel;
        let empty = DnnModel::new("empty");
        assert!(exchange_units(&empty, &[(0, 4), (1, 8)]).is_empty());
        let zero_param = DnnModel::new("zero-param").fc("l0", 0, 0).fc("l1", 0, 0);
        assert_eq!(zero_param.total_bytes(), 0);
        assert!(exchange_units(&zero_param, &[(0, 4)]).is_empty());
        assert!(exchange_units(&zero_param, &[]).is_empty());
    }

    #[test]
    fn exchange_unit_layer_mapping_property() {
        // property: every unit's dep_layer is exactly the layer whose
        // [prefix[l], prefix[l+1]) byte range contains the unit's first
        // byte (clamped to the last layer for parts past the end) —
        // driven across randomized partitions, including boundary-exact
        // splits, via the deterministic xorshift the queue tests use
        let m = vgg16();
        let total = m.total_bytes();
        let mut prefix = vec![0u64];
        for l in &m.layers {
            prefix.push(prefix.last().unwrap() + l.bytes());
        }
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for case in 0..200 {
            // random contiguous partition of [0, total + slack)
            let mut parts: Vec<(usize, u64)> = Vec::new();
            let mut used = 0u64;
            while used < total {
                let bytes = match case % 3 {
                    // exact layer-boundary splits
                    0 => {
                        let l = (next() % m.layers.len() as u64) as usize;
                        m.layers[l].bytes()
                    }
                    // byte-granular jitter around boundaries
                    1 => (next() % 5).max(1),
                    // large random spans
                    _ => next() % (total / 4) + 1,
                }
                .min(total - used);
                if next() % 7 == 0 {
                    // zero-byte parts must be dropped without shifting
                    // the byte ranges of their neighbours
                    parts.push(((next() % 4) as usize, 0));
                }
                parts.push(((next() % 4) as usize, bytes));
                used += bytes;
                if parts.len() > 4096 {
                    break;
                }
            }
            let units = exchange_units(&m, &parts);
            let nonzero: Vec<&(usize, u64)> = parts.iter().filter(|p| p.1 > 0).collect();
            assert_eq!(units.len(), nonzero.len(), "zero-byte parts drop");
            let mut start = 0u64;
            let mut ui = 0usize;
            for &(root, bytes) in &parts {
                if bytes == 0 {
                    continue;
                }
                let u = &units[ui];
                ui += 1;
                assert_eq!(u.root, root);
                assert_eq!(u.bytes, bytes);
                let a = start.min(total - 1);
                assert!(
                    prefix[u.dep_layer] <= a && a < prefix[u.dep_layer + 1],
                    "case {case}: first byte {a} outside layer {} = [{}, {})",
                    u.dep_layer,
                    prefix[u.dep_layer],
                    prefix[u.dep_layer + 1]
                );
                start += bytes;
            }
        }
        // boundary spot checks: a unit starting exactly on a layer
        // boundary gates on that layer; the final byte on the last layer
        let b0 = m.layers[0].bytes();
        let at_boundary = exchange_units(&m, &[(0, b0), (0, 4)]);
        assert_eq!(at_boundary[1].dep_layer, 1);
        let last = exchange_units(&m, &[(0, total - 1), (0, 1)]);
        assert_eq!(last[1].dep_layer, m.layers.len() - 1);
        // parts overshooting the vector clamp to the last layer
        let over = exchange_units(&m, &[(0, total), (0, 8)]);
        assert_eq!(over[1].dep_layer, m.layers.len() - 1);
    }

    #[test]
    fn backprop_delays_chain_in_reverse_per_rank() {
        let cluster = kesch(1, 2).unwrap();
        let mut plan = Plan::new();
        let layer_ns = [10u64, 20, 30];
        let ops = push_backprop_delays(&mut plan, &cluster, &layer_ns);
        assert_eq!(ops.len(), 2);
        assert_eq!(plan.len(), 6);
        for per_rank in &ops {
            // layer 2 runs first (no deps), layer 0 last
            assert!(plan.deps[per_rank[2]].is_empty());
            assert_eq!(plan.deps[per_rank[1]].as_slice(), &[per_rank[2]]);
            assert_eq!(plan.deps[per_rank[0]].as_slice(), &[per_rank[1]]);
        }
        // the chain alone costs the summed compute
        let mut engine = Engine::new(&cluster);
        assert_eq!(engine.makespan_ns(&plan), 60);
    }

    #[test]
    fn timeline_reduces_to_comm_time_at_zero_compute() {
        // bit-identical to the barrier model's exchange when every delay
        // is zero — the golden anchor for both training modes
        let cluster = kesch(1, 8).unwrap();
        let sel = Selector::tuned(&cluster);
        let model = googlenet();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let layer_ns = model.layer_compute_split(0);
        let bucket = crate::models::DEFAULT_BUCKET_BYTES;
        let overlapped =
            allreduce_timeline_ns(&mut comm, &mut engine, &sel, &model, &layer_ns, bucket);
        let buckets = allreduce_buckets(&model, bucket);
        let barrier =
            super::super::schedule::allreduce_time_ns(&mut comm, &mut engine, &sel, &buckets);
        assert_eq!(overlapped, barrier);
    }

    #[test]
    fn nonzero_compute_extends_and_overlaps() {
        let cluster = kesch(1, 4).unwrap();
        let sel = Selector::tuned(&cluster);
        let model = googlenet();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        // compute dominates the ~28 MB exchange by an order of magnitude
        // and the small bucket forces many staggered releases, so the
        // strict inequality below has real slack
        let compute_ns: u64 = 50_000_000;
        let layer_ns = model.layer_compute_split(compute_ns);
        let bucket: u64 = 2 << 20;
        let comm_only = allreduce_timeline_ns(
            &mut comm,
            &mut engine,
            &sel,
            &model,
            &model.layer_compute_split(0),
            bucket,
        );
        let overlapped =
            allreduce_timeline_ns(&mut comm, &mut engine, &sel, &model, &layer_ns, bucket);
        // the overlapped iteration contains all the compute...
        assert!(overlapped >= compute_ns);
        // ...and all the exchange's tail, but hides some of the rest
        assert!(overlapped >= comm_only);
        assert!(
            overlapped < compute_ns + comm_only,
            "no overlap at all: {overlapped} vs {compute_ns} + {comm_only}"
        );
    }
}
