//! The Fig. 3 estimator: data-parallel training time per iteration =
//! compute (parallel across ranks) + gradient/parameter exchange
//! (simulated) — under the paper's broadcast-only model
//! ([`estimate_iteration`]) or the full-exchange training modes
//! ([`estimate_training_iteration`]).

use crate::comm::Comm;
use crate::models::{allreduce_buckets, bcast_messages, DnnModel, MessageSchedule};
use crate::netsim::{Engine, FaultSchedule, LinkModel};
use crate::topology::Cluster;
use crate::tuning::Selector;

use super::schedule::{
    aggregation_time_ns, allreduce_time_ns, comm_time_ns, BcastBackend, TrainingMode,
};

/// K80 effective fp32 throughput used by the compute model: 4.37 TFLOP/s
/// peak, ~32% achieved on CNTK conv/FC kernels of the era.
pub const K80_EFF_FLOPS: f64 = 1.4e12;

/// One scale point of the Fig. 3 estimate.
#[derive(Debug, Clone)]
pub struct TrainingEstimate {
    pub gpus: usize,
    pub compute_us: f64,
    pub comm_us: f64,
    pub iter_us: f64,
    /// Samples/second at the given global batch.
    pub throughput: f64,
}

/// The compute half of an estimate, shared across exchange models (and
/// by the recovery runner's training workload).
pub(crate) fn compute_us_for(
    model: &DnnModel,
    gpus: usize,
    global_batch: usize,
    compute_us_override: f64,
) -> f64 {
    let per_gpu_batch = (global_batch as f64 / gpus as f64).ceil().max(1.0);
    if compute_us_override > 0.0 {
        compute_us_override
    } else {
        // fwd + bwd ≈ 3× fwd FLOPs
        3.0 * model.fwd_flops as f64 * per_gpu_batch / K80_EFF_FLOPS * 1e6
    }
}

fn estimate_from(
    gpus: usize,
    global_batch: usize,
    compute_us: f64,
    comm_ns: u64,
) -> TrainingEstimate {
    let comm_us = comm_ns as f64 / 1000.0;
    let iter_us = compute_us + comm_us;
    TrainingEstimate {
        gpus,
        compute_us,
        comm_us,
        iter_us,
        throughput: global_batch as f64 / (iter_us / 1e6),
    }
}

/// Estimate one iteration at a given scale.
///
/// `compute_us_override > 0` substitutes a *measured* per-iteration
/// compute time (the e2e_train example feeds real PJRT timings here).
pub fn estimate_iteration(
    cluster: &Cluster,
    model: &DnnModel,
    backend: &BcastBackend,
    global_batch: usize,
    compute_us_override: f64,
) -> TrainingEstimate {
    estimate_iteration_with_model(
        cluster,
        model,
        backend,
        global_batch,
        compute_us_override,
        LinkModel::Fifo,
    )
}

/// [`estimate_iteration`] under an explicit link-contention model: the
/// broadcast schedule is simulated on an engine running `link_model`
/// (concurrent owner-broadcasts share fabric links fairly instead of
/// serializing). Pass a selector tuned under the same model for a
/// consistent story.
pub fn estimate_iteration_with_model(
    cluster: &Cluster,
    model: &DnnModel,
    backend: &BcastBackend,
    global_batch: usize,
    compute_us_override: f64,
    link_model: LinkModel,
) -> TrainingEstimate {
    let gpus = cluster.n_gpus();
    let compute_us = compute_us_for(model, gpus, global_batch, compute_us_override);
    let msgs = bcast_messages(model, gpus, MessageSchedule::Partitioned);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::with_model(cluster, link_model);
    let comm_ns = comm_time_ns(&mut comm, &mut engine, backend, &msgs);
    estimate_from(gpus, global_batch, compute_us, comm_ns)
}

/// Knobs for the full-exchange estimator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExchangeOptions<'f> {
    /// Overlap backprop with the gradient exchange: cost the iteration
    /// as the makespan of the layer-wise timeline DAG
    /// ([`super::timeline`]) instead of the `compute + comm` barrier
    /// model. Off reproduces the pre-timeline estimates bit-for-bit.
    pub overlap: bool,
    /// Gradient-fusion bucket size for the allreduce mode (the
    /// `--bucket-bytes` flush threshold; both the barrier and overlap
    /// paths bucket with it).
    pub bucket_bytes: u64,
    /// Link-contention model the exchange is simulated under (the
    /// `--link-model` knob). Matters most with `overlap`: the timeline
    /// runs many bucket collectives *concurrently* on the shared fabric,
    /// which FIFO serializes but fair sharing progressively fills.
    pub link_model: LinkModel,
    /// Fault schedule injected into the exchange's engine (the
    /// `--faults` knob; DESIGN.md §Fault model). `None` — and an empty
    /// schedule — leave the estimate bit-identical to the healthy path.
    pub faults: Option<&'f FaultSchedule>,
    /// Recovery policy + detection/replan knobs for multi-iteration jobs
    /// ([`super::recovery::run_training_job`]; the `--recovery` and
    /// `--detect-ns` flags). The single-iteration estimators ignore it;
    /// the default (`RecoveryPolicy::None`) aborts a job on its first
    /// failed iteration, matching the pre-recovery behavior.
    pub recovery: super::recovery::RecoveryConfig,
}

impl Default for ExchangeOptions<'_> {
    fn default() -> Self {
        ExchangeOptions {
            overlap: false,
            bucket_bytes: crate::models::DEFAULT_BUCKET_BYTES,
            link_model: LinkModel::Fifo,
            faults: None,
            recovery: super::recovery::RecoveryConfig::default(),
        }
    }
}

/// Estimate one iteration of the *full* gradient/parameter exchange
/// under a [`TrainingMode`], with the tuned MPI runtime carrying the
/// collectives — default options (no overlap, default buckets).
///
/// Unlike [`estimate_iteration`] (which reproduces the paper's Fig. 3
/// broadcast-only accounting), the partitioned mode here also pays the
/// gather-based gradient aggregation that precedes the owner broadcasts
/// — the honest apples-to-apples baseline for the allreduce mode, which
/// inherently does both halves of the exchange.
pub fn estimate_training_iteration(
    cluster: &Cluster,
    model: &DnnModel,
    sel: &Selector,
    mode: TrainingMode,
    global_batch: usize,
    compute_us_override: f64,
) -> TrainingEstimate {
    estimate_training_iteration_opts(
        cluster,
        model,
        sel,
        mode,
        global_batch,
        compute_us_override,
        ExchangeOptions::default(),
    )
}

/// [`estimate_training_iteration`] with explicit [`ExchangeOptions`].
///
/// With `overlap` off, the iteration is `compute + comm` (a global
/// barrier between backprop and the exchange). With `overlap` on, the
/// iteration is the makespan of the overlap timeline — per-layer
/// backprop delays feeding the bucketed exchange in one DAG — and
/// `comm_us` reports only the *exposed* (non-hidden) communication.
/// With zero per-layer compute the two paths agree exactly.
pub fn estimate_training_iteration_opts(
    cluster: &Cluster,
    model: &DnnModel,
    sel: &Selector,
    mode: TrainingMode,
    global_batch: usize,
    compute_us_override: f64,
    opts: ExchangeOptions<'_>,
) -> TrainingEstimate {
    let gpus = cluster.n_gpus();
    let compute_us = compute_us_for(model, gpus, global_batch, compute_us_override);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::with_model(cluster, opts.link_model);
    if let Some(f) = opts.faults {
        // both the overlap timeline and the barrier path below run every
        // collective on this engine, so one install covers the exchange
        engine.set_faults(Some(f.clone()));
    }
    if opts.overlap {
        let compute_ns = (compute_us * 1000.0).round() as u64;
        let makespan = super::timeline::overlap_iteration_ns(
            &mut comm,
            &mut engine,
            sel,
            mode,
            model,
            compute_ns,
            opts.bucket_bytes,
        );
        let iter_us = makespan as f64 / 1000.0;
        return TrainingEstimate {
            gpus,
            compute_us,
            comm_us: (iter_us - compute_us).max(0.0),
            iter_us,
            throughput: global_batch as f64 / (iter_us / 1e6),
        };
    }
    let comm_ns = match mode {
        TrainingMode::PartitionedBcast => {
            let msgs = bcast_messages(model, gpus, MessageSchedule::Partitioned);
            // modelled as a global barrier between the aggregation and
            // broadcast halves — conservative for the baseline (per-block
            // overlap would shave at most the smaller half), but the
            // allreduce-vs-bcast crossover is driven by the aggregation's
            // all-to-all IB traffic, which dwarfs both halves at scale
            aggregation_time_ns(&mut comm, &mut engine, &msgs)
                + comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(sel), &msgs)
        }
        TrainingMode::AllreduceGradients => {
            let buckets = allreduce_buckets(model, opts.bucket_bytes);
            allreduce_time_ns(&mut comm, &mut engine, sel, &buckets)
        }
    };
    estimate_from(gpus, global_batch, compute_us, comm_ns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::vgg16;
    use crate::nccl::NcclParams;
    use crate::topology::presets::kesch;
    use crate::tuning::Selector;

    #[test]
    fn mv2_opt_beats_or_matches_nccl_for_vgg() {
        // the paper's 7%-at-32-GPUs claim, shape-checked at one scale
        let cluster = kesch(2, 16).unwrap(); // 32 GPUs
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let nccl = NcclParams::default();
        let a = estimate_iteration(
            &cluster,
            &model,
            &BcastBackend::Mv2Opt(&sel),
            256,
            0.0,
        );
        let b = estimate_iteration(
            &cluster,
            &model,
            &BcastBackend::NcclMv2(&nccl),
            256,
            0.0,
        );
        assert!(a.iter_us <= b.iter_us, "{} vs {}", a.iter_us, b.iter_us);
        // improvement should be single-digit-to-low-teens percent, not 10x
        // (compute dominates; the paper reports 7%)
        let gain = (b.iter_us - a.iter_us) / b.iter_us;
        assert!(gain < 0.5, "gain {gain} suspiciously large");
    }

    #[test]
    fn compute_override_is_respected() {
        let cluster = kesch(1, 4).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let est = estimate_iteration(
            &cluster,
            &model,
            &BcastBackend::Mv2Opt(&sel),
            64,
            123_456.0,
        );
        assert_eq!(est.compute_us, 123_456.0);
        assert!(est.iter_us > est.compute_us);
    }

    #[test]
    fn allreduce_mode_beats_partitioned_bcast_at_32_gpus() {
        // the motivating claim of the refactor: once the partitioned
        // scheme pays its aggregation leg, bucketed ring allreduce wins
        // the full gradient exchange at multi-node scale
        let cluster = kesch(2, 16).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let batch = 16 * cluster.n_gpus();
        let bcast = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::PartitionedBcast,
            batch,
            0.0,
        );
        let ar = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            batch,
            0.0,
        );
        assert!(
            ar.comm_us < bcast.comm_us,
            "allreduce {} us vs partitioned {} us",
            ar.comm_us,
            bcast.comm_us
        );
        assert!(ar.iter_us < bcast.iter_us);
    }

    #[test]
    fn training_modes_share_compute_model() {
        let cluster = kesch(1, 4).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let a = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            64,
            0.0,
        );
        let b = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), 64, 0.0);
        assert_eq!(a.compute_us, b.compute_us);
        assert!(a.comm_us > 0.0);
    }

    #[test]
    fn overlap_no_worse_than_barrier_at_32_gpus() {
        // acceptance: VGG16 on the 32-GPU kesch preset — overlapping
        // backprop with the exchange never loses to the barrier model,
        // in either training mode
        let cluster = kesch(2, 16).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let batch = 16 * cluster.n_gpus();
        for mode in [TrainingMode::PartitionedBcast, TrainingMode::AllreduceGradients] {
            let off = estimate_training_iteration_opts(
                &cluster,
                &model,
                &sel,
                mode,
                batch,
                0.0,
                ExchangeOptions::default(),
            );
            let on = estimate_training_iteration_opts(
                &cluster,
                &model,
                &sel,
                mode,
                batch,
                0.0,
                ExchangeOptions {
                    overlap: true,
                    ..ExchangeOptions::default()
                },
            );
            assert!(
                on.iter_us <= off.iter_us,
                "{}: overlap {} us vs barrier {} us",
                mode.label(),
                on.iter_us,
                off.iter_us
            );
            // overlap can hide comm, never compute
            assert!(on.iter_us >= on.compute_us);
            assert_eq!(on.compute_us, off.compute_us);
        }
    }

    #[test]
    fn overlap_equals_barrier_at_zero_compute() {
        // acceptance: with zero per-layer compute the timeline's
        // exchange DAG replays the barrier model's exactly — iteration
        // times must agree to the bit, in both training modes
        let cluster = kesch(2, 16).unwrap();
        let model = vgg16().with_flops(0); // zero compute, real messages
        let sel = Selector::tuned(&cluster);
        let batch = 16 * cluster.n_gpus();
        for mode in [TrainingMode::PartitionedBcast, TrainingMode::AllreduceGradients] {
            let off = estimate_training_iteration_opts(
                &cluster,
                &model,
                &sel,
                mode,
                batch,
                0.0,
                ExchangeOptions::default(),
            );
            let on = estimate_training_iteration_opts(
                &cluster,
                &model,
                &sel,
                mode,
                batch,
                0.0,
                ExchangeOptions {
                    overlap: true,
                    ..ExchangeOptions::default()
                },
            );
            assert_eq!(off.compute_us, 0.0);
            assert_eq!(
                on.iter_us,
                off.iter_us,
                "{}: zero-compute overlap must be exact",
                mode.label()
            );
        }
    }

    #[test]
    fn no_overlap_path_matches_schedule_primitives_bit_for_bit() {
        // golden parity: the overlap-capable estimator with overlap OFF
        // must reproduce the pre-timeline composition of the schedule
        // primitives exactly
        let cluster = kesch(1, 8).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let gpus = cluster.n_gpus();
        let batch = 16 * gpus;
        // partitioned: aggregation + judged broadcast schedule
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let msgs = bcast_messages(&model, gpus, MessageSchedule::Partitioned);
        let want_part = aggregation_time_ns(&mut comm, &mut engine, &msgs)
            + comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(&sel), &msgs);
        let got_part = estimate_training_iteration_opts(
            &cluster,
            &model,
            &sel,
            TrainingMode::PartitionedBcast,
            batch,
            0.0,
            ExchangeOptions::default(),
        );
        assert_eq!(got_part.comm_us, want_part as f64 / 1000.0);
        // allreduce: merged default-size buckets
        let buckets = allreduce_buckets(&model, crate::models::DEFAULT_BUCKET_BYTES);
        let want_ar = allreduce_time_ns(&mut comm, &mut engine, &sel, &buckets);
        let got_ar = estimate_training_iteration_opts(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            batch,
            0.0,
            ExchangeOptions::default(),
        );
        assert_eq!(got_ar.comm_us, want_ar as f64 / 1000.0);
        // and the default-options wrapper is the same path
        let wrapped = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            batch,
            0.0,
        );
        assert_eq!(wrapped.iter_us, got_ar.iter_us);
    }

    #[test]
    fn fairshare_exchange_estimates_are_sane() {
        // the fair-share model must produce a well-formed estimate in
        // both training modes, with and without overlap: iteration
        // contains all the compute, communication is positive, and the
        // model flows through ExchangeOptions (closed-form correctness
        // is pinned by the engine's fair-share unit tests)
        let cluster = kesch(1, 4).unwrap();
        let model = vgg16();
        let sel = Selector::tuned_with_model(&cluster, None, crate::netsim::LinkModel::FairShare);
        for overlap in [false, true] {
            for mode in [TrainingMode::PartitionedBcast, TrainingMode::AllreduceGradients] {
                let e = estimate_training_iteration_opts(
                    &cluster,
                    &model,
                    &sel,
                    mode,
                    64,
                    0.0,
                    ExchangeOptions {
                        overlap,
                        link_model: crate::netsim::LinkModel::FairShare,
                        ..ExchangeOptions::default()
                    },
                );
                assert!(e.iter_us >= e.compute_us, "{mode:?} overlap={overlap}");
                assert!(e.iter_us > 0.0 && e.throughput > 0.0);
            }
        }
        // the fifo-model broadcast path is reachable through the
        // explicit-model wrapper too, and matches the default entry
        let a = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), 64, 0.0);
        let b = estimate_iteration_with_model(
            &cluster,
            &model,
            &BcastBackend::Mv2Opt(&sel),
            64,
            0.0,
            crate::netsim::LinkModel::Fifo,
        );
        assert_eq!(a.iter_us, b.iter_us);
    }

    #[test]
    fn bucket_bytes_knob_changes_allreduce_schedule() {
        let cluster = kesch(1, 4).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let coarse = estimate_training_iteration_opts(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            64,
            0.0,
            ExchangeOptions {
                overlap: false,
                bucket_bytes: model.total_bytes(), // one giant bucket
                ..ExchangeOptions::default()
            },
        );
        let fine = estimate_training_iteration_opts(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            64,
            0.0,
            ExchangeOptions {
                overlap: false,
                bucket_bytes: 8 << 20,
                ..ExchangeOptions::default()
            },
        );
        assert!(coarse.comm_us > 0.0 && fine.comm_us > 0.0);
        assert_ne!(
            coarse.comm_us, fine.comm_us,
            "bucket size must change the merged schedule"
        );
    }

    #[test]
    fn throughput_consistent() {
        let cluster = kesch(1, 2).unwrap();
        let model = vgg16();
        let sel = Selector::tuned(&cluster);
        let est =
            estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), 128, 0.0);
        let recomputed = 128.0 / (est.iter_us / 1e6);
        assert!((est.throughput - recomputed).abs() < 1e-6);
    }
}
