//! Leader side of the data-parallel engine.
//!
//! The leader owns the parameter vector. Each iteration it (conceptually)
//! broadcasts parameters to all workers — the traffic the paper's
//! `MPI_Bcast` designs carry — collects gradient shards, averages them
//! and applies SGD. Two execution modes:
//!
//! * [`run_threaded`] — workers on real threads behind channels (used
//!   when the backend is `Send`);
//! * [`run_serial`] — workers driven in-place (used for PJRT-backed
//!   workers; the `xla` handles are not `Send`). Identical arithmetic.
//!
//! The *timing* of the parameter exchange comes from the simulator via a
//! caller-provided costing closure, so training metrics combine real
//! compute/loss with simulated communication — see DESIGN.md §0.

use std::sync::mpsc;
use std::thread;

use super::metrics::{IterationMetrics, TrainingMetrics};
use super::worker::ComputeBackend;

/// SGD hyper-parameters.
#[derive(Debug, Clone)]
pub struct SgdConfig {
    pub lr: f32,
    pub iterations: usize,
}

fn apply_update(params: &mut [f32], grads: &[Vec<f32>], lr: f32) -> f32 {
    let k = grads.len() as f32;
    for (i, p) in params.iter_mut().enumerate() {
        let avg: f32 = grads.iter().map(|g| g[i]).sum::<f32>() / k;
        *p -= lr * avg;
    }
    k
}

/// Serial data-parallel SGD (for non-Send backends).
pub fn run_serial<B: ComputeBackend + ?Sized>(
    params: &mut Vec<f32>,
    workers: &mut [Box<B>],
    cfg: &SgdConfig,
    mut comm_cost_ns: impl FnMut(usize) -> u64,
) -> TrainingMetrics {
    assert!(!workers.is_empty());
    let mut metrics = TrainingMetrics::default();
    for iter in 0..cfg.iterations {
        let t0 = std::time::Instant::now();
        let mut grads = Vec::with_capacity(workers.len());
        let mut loss_sum = 0.0f32;
        for w in workers.iter_mut() {
            let (g, loss) = w.grad(params, iter as u64);
            assert_eq!(g.len(), params.len());
            grads.push(g);
            loss_sum += loss;
        }
        apply_update(params, &grads, cfg.lr);
        let compute_ns = t0.elapsed().as_nanos() as u64;
        metrics.push(IterationMetrics {
            iter,
            loss: loss_sum / workers.len() as f32,
            compute_ns,
            comm_ns: comm_cost_ns(iter),
        });
    }
    metrics
}

/// Threaded data-parallel SGD: one OS thread per worker, parameters fan
/// out and gradients fan in over channels each iteration.
pub fn run_threaded<B>(
    params: &mut Vec<f32>,
    workers: Vec<B>,
    cfg: &SgdConfig,
    mut comm_cost_ns: impl FnMut(usize) -> u64,
) -> TrainingMetrics
where
    B: ComputeBackend + Send + 'static,
{
    assert!(!workers.is_empty());
    let n = workers.len();
    let mut to_workers = Vec::with_capacity(n);
    let (grad_tx, grad_rx) = mpsc::channel::<(usize, Vec<f32>, f32)>();
    let mut handles = Vec::with_capacity(n);
    for (wid, mut backend) in workers.into_iter().enumerate() {
        let (ptx, prx) = mpsc::channel::<Option<Vec<f32>>>();
        to_workers.push(ptx);
        let gtx = grad_tx.clone();
        handles.push(thread::spawn(move || {
            let mut iter = 0u64;
            // the worker loop: receive params (None = shutdown), compute,
            // send gradient shard back
            while let Ok(Some(params)) = prx.recv() {
                let (g, loss) = backend.grad(&params, iter);
                iter += 1;
                if gtx.send((wid, g, loss)).is_err() {
                    break;
                }
            }
        }));
    }
    drop(grad_tx);

    let mut metrics = TrainingMetrics::default();
    for iter in 0..cfg.iterations {
        let t0 = std::time::Instant::now();
        // parameter broadcast (the MPI_Bcast the paper optimises)
        for tx in &to_workers {
            tx.send(Some(params.clone())).expect("worker alive");
        }
        let mut grads: Vec<Option<Vec<f32>>> = vec![None; n];
        let mut loss_sum = 0.0f32;
        for _ in 0..n {
            let (wid, g, loss) = grad_rx.recv().expect("worker alive");
            assert_eq!(g.len(), params.len());
            grads[wid] = Some(g);
            loss_sum += loss;
        }
        let grads: Vec<Vec<f32>> = grads.into_iter().map(|g| g.unwrap()).collect();
        apply_update(params, &grads, cfg.lr);
        let compute_ns = t0.elapsed().as_nanos() as u64;
        metrics.push(IterationMetrics {
            iter,
            loss: loss_sum / n as f32,
            compute_ns,
            comm_ns: comm_cost_ns(iter),
        });
    }
    for tx in &to_workers {
        let _ = tx.send(None);
    }
    for h in handles {
        let _ = h.join();
    }
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::QuadBackend;

    fn target() -> Vec<f32> {
        (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect()
    }

    #[test]
    fn serial_converges_to_target() {
        let t = target();
        let mut params = vec![0.0f32; t.len()];
        let mut workers: Vec<Box<QuadBackend>> = (0..4)
            .map(|_| Box::new(QuadBackend::new(t.clone())))
            .collect();
        let metrics = run_serial(
            &mut params,
            &mut workers,
            &SgdConfig {
                lr: 0.2,
                iterations: 60,
            },
            |_| 1000,
        );
        assert!(metrics.final_loss() < 1e-6, "loss {}", metrics.final_loss());
        assert!(metrics.loss_decreased());
        for (p, t) in params.iter().zip(&t) {
            assert!((p - t).abs() < 1e-3);
        }
    }

    #[test]
    fn threaded_matches_serial_arithmetic() {
        let t = target();
        let cfg = SgdConfig {
            lr: 0.1,
            iterations: 25,
        };
        let mut p_serial = vec![0.5f32; t.len()];
        let mut ws: Vec<Box<QuadBackend>> = (0..3)
            .map(|_| Box::new(QuadBackend::new(t.clone())))
            .collect();
        run_serial(&mut p_serial, &mut ws, &cfg, |_| 0);

        let mut p_thread = vec![0.5f32; t.len()];
        let workers: Vec<QuadBackend> =
            (0..3).map(|_| QuadBackend::new(t.clone())).collect();
        run_threaded(&mut p_thread, workers, &cfg, |_| 0);

        for (a, b) in p_serial.iter().zip(&p_thread) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn comm_cost_recorded() {
        let t = target();
        let mut params = vec![0.0f32; t.len()];
        let mut workers: Vec<Box<QuadBackend>> =
            vec![Box::new(QuadBackend::new(t.clone()))];
        let metrics = run_serial(
            &mut params,
            &mut workers,
            &SgdConfig {
                lr: 0.1,
                iterations: 5,
            },
            |i| (i as u64 + 1) * 100,
        );
        assert_eq!(metrics.iterations.len(), 5);
        assert_eq!(metrics.iterations[4].comm_ns, 500);
        assert_eq!(metrics.total_comm_ns(), 100 + 200 + 300 + 400 + 500);
    }
}
