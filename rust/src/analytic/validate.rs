//! Experiment E1: simulator vs closed forms.
//!
//! On the `flat` preset (the uniform fabric §III assumes) the simulator
//! must land within a small tolerance of the exact analytic forms for
//! every algorithm and across the full (n, M) grid. This is the
//! foundation that makes the F1/F2/F3 reproductions trustworthy.

use crate::collectives::{self, Algorithm, BcastSpec};
use crate::comm::{Comm, CommParams};
use crate::netsim::Engine;
use crate::topology::presets::flat;

use super::bcast;
use super::params::ModelParams;

/// One validation row.
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub algorithm: String,
    pub n: usize,
    pub bytes: u64,
    pub sim_ns: f64,
    pub model_ns: f64,
    /// |sim - model| / model.
    pub rel_err: f64,
}

/// Model prediction for an algorithm on the flat fabric (exact forms).
pub fn model_ns(algo: &Algorithm, cp: &CommParams, n: usize, bytes: u64) -> f64 {
    let eager = ModelParams::flat_eager(cp);
    let rndv = ModelParams::flat_rndv(cp);
    let pick = |b: u64| if b <= cp.eager_threshold { eager } else { rndv };
    let p = pick(bytes);
    match algo {
        Algorithm::Direct => bcast::direct(&p, n, bytes),
        Algorithm::Chain => bcast::chain(&p, n, bytes),
        Algorithm::PipelinedChain { chunk } => {
            let pc = pick((*chunk).min(bytes));
            bcast::pipelined_chain(&pc, n, bytes, *chunk)
        }
        Algorithm::Knomial { k } => bcast::knomial_serialized(&p, n, *k, bytes),
        Algorithm::ScatterRingAllgather => {
            // parts are M/n — eager/rndv depends on the part size
            let pp = pick(bytes / n as u64);
            bcast::scatter_allgather(&pp, n, bytes)
        }
        Algorithm::HostStagedKnomial { .. } => {
            // flat preset has one GPU per pseudo-node; the host hop model
            // differs structurally — validated elsewhere
            f64::NAN
        }
        Algorithm::RingReduceScatter
        | Algorithm::RingAllgather
        | Algorithm::RingAllreduce
        | Algorithm::TreeAllreduce { .. } => {
            // reduction collectives are checked by the dataflow property
            // tests and their builders' ring/tree cost tests, not the
            // broadcast closed forms
            f64::NAN
        }
    }
}

/// Run the (algorithm × n × M) validation grid.
pub fn run_grid(
    algorithms: &[Algorithm],
    ns: &[usize],
    sizes: &[u64],
) -> Vec<ValidationRow> {
    let cp = CommParams::default();
    let mut rows = Vec::new();
    for &n in ns {
        let cluster = flat(n).unwrap();
        let mut comm = Comm::with_params(&cluster, cp.clone());
        let mut engine = Engine::new(&cluster);
        for algo in algorithms {
            for &bytes in sizes {
                let spec = BcastSpec::new(0, n, bytes);
                let sim_ns =
                    collectives::latency_ns(algo, &mut comm, &mut engine, &spec) as f64;
                let model = model_ns(algo, &cp, n, bytes);
                if model.is_nan() {
                    continue;
                }
                let rel_err = if model > 0.0 {
                    (sim_ns - model).abs() / model
                } else {
                    0.0
                };
                rows.push(ValidationRow {
                    algorithm: algo.name(),
                    n,
                    bytes,
                    sim_ns,
                    model_ns: model,
                    rel_err,
                });
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_matches_models_tightly() {
        let algos = [
            Algorithm::Direct,
            Algorithm::Chain,
            Algorithm::PipelinedChain { chunk: 256 << 10 },
            Algorithm::Knomial { k: 2 },
            Algorithm::Knomial { k: 4 },
        ];
        let rows = run_grid(&algos, &[2, 4, 8, 16], &[4, 8 << 10, 1 << 20, 16 << 20]);
        assert!(!rows.is_empty());
        for row in &rows {
            assert!(
                row.rel_err < 0.02,
                "{} n={} M={}: sim {} vs model {} (err {:.3})",
                row.algorithm,
                row.n,
                row.bytes,
                row.sim_ns,
                row.model_ns,
                row.rel_err
            );
        }
    }

    #[test]
    fn scatter_allgather_within_tolerance() {
        // SAG's model ignores which phase a t_s lands in; allow a looser
        // bound but require the bandwidth term to dominate correctly
        let rows = run_grid(
            &[Algorithm::ScatterRingAllgather],
            &[4, 8, 16],
            &[1 << 20, 16 << 20, 64 << 20],
        );
        for row in &rows {
            assert!(
                row.rel_err < 0.35,
                "{} n={} M={}: err {:.3}",
                row.algorithm,
                row.n,
                row.bytes,
                row.rel_err
            );
        }
    }
}
