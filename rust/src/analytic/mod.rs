//! Analytic cost models (§III and §IV of the paper, Table I notation).
//!
//! * [`bcast`] — the closed forms: Eq. (1) direct, Eq. (2) chain,
//!   Eq. (3) k-nomial, Eq. (4) scatter-ring-allgather, Eq. (5) pipelined
//!   chain, Eq. (6) host-staged k-nomial.
//! * [`params`] — the (t_s, B, B_PCIe, n, M, C) parameter block of
//!   Table I.
//! * [`validate`] — checks the simulator against the closed forms on the
//!   idealised `flat` fabric they assume (experiment E1 in DESIGN.md).

pub mod bcast;
pub mod params;
pub mod validate;

pub use bcast::*;
pub use params::ModelParams;
