//! The closed-form broadcast cost models, Eqs. (1)–(6).
//!
//! Two variants are provided for the O(n) algorithms:
//!
//! * `*_paper` — exactly as printed in the paper (Eq. 1 charges `n`
//!   sends; a root sending to `n-1` peers is approximated as `n`);
//! * the default — the exact count the simulator realises (`n-1`).
//!
//! Validation (experiment E1) uses the exact forms; reports print both.

use super::params::ModelParams;

/// Eq. (1) as printed: `T = n × (t_s + M/B)`.
pub fn direct_paper(p: &ModelParams, n: usize, m: u64) -> f64 {
    n as f64 * p.hop_ns(m)
}

/// Exact direct cost: the root performs `n-1` serialized sends.
pub fn direct(p: &ModelParams, n: usize, m: u64) -> f64 {
    (n as f64 - 1.0) * p.hop_ns(m)
}

/// Eq. (2): `T = (n-1) × (t_s + M/B)`.
pub fn chain(p: &ModelParams, n: usize, m: u64) -> f64 {
    (n as f64 - 1.0) * p.hop_ns(m)
}

/// Eq. (3): `T = ⌈log_k n⌉ × (t_s + M/B)`.
///
/// The paper's idealisation assumes the k-1 sends of a round overlap
/// perfectly; [`knomial_serialized`] charges them serially (what a real
/// blocking-send implementation — and the simulator — does).
pub fn knomial_paper(p: &ModelParams, n: usize, k: usize, m: u64) -> f64 {
    ceil_log(n, k) as f64 * p.hop_ns(m)
}

/// K-nomial with serialized per-round child sends: the critical path of
/// the recursive-splitting tree realised by the simulator.
pub fn knomial_serialized(p: &ModelParams, n: usize, k: usize, m: u64) -> f64 {
    // critical path: at each level the head sends to (k-1) children
    // serially, and the *last* child's subtree starts after all of them.
    // Depth of the recursive ceil-split tree with serialized sends:
    serialized_depth(n, k) as f64 * p.hop_ns(m)
}

/// Longest issue-to-arrival path (in hops) of the recursive ceil-split
/// k-nomial tree with serialized sends, matching
/// `collectives::knomial::plan`.
pub fn serialized_depth(n: usize, k: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    let sub = n.div_ceil(k);
    let mut ranges = Vec::new();
    let mut cursor = 0;
    while cursor < n {
        let len = sub.min(n - cursor);
        ranges.push(len);
        cursor += len;
    }
    // the head's own deeper sends queue behind its (ranges-1) sends at
    // this level (shared egress link)
    let sends = ranges.len() - 1;
    let mut worst = sends + serialized_depth(ranges[0], k);
    for (i, &len) in ranges.iter().enumerate().skip(1) {
        // i-th child receives after i serialized sends
        worst = worst.max(i + serialized_depth(len, k));
    }
    worst
}

/// Eq. (4): `T = (⌈log₂ n⌉ + n − 1) × t_s + 2 (n−1)/n × M/B`.
pub fn scatter_allgather(p: &ModelParams, n: usize, m: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    (ceil_log(n, 2) as f64 + nf - 1.0) * p.t_s_ns + 2.0 * (nf - 1.0) / nf * p.tx_ns(m)
}

/// Eq. (5): `T = (M/C + n − 2) × (t_s + C/B)` — the pipelined chain.
pub fn pipelined_chain(p: &ModelParams, n: usize, m: u64, c: u64) -> f64 {
    let n_chunks = (m as f64 / c as f64).ceil().max(1.0);
    (n_chunks + n as f64 - 2.0) * p.hop_ns(c.min(m))
}

/// Eq. (6): `T = M/B_PCIe + ⌈log_k n⌉ × (t_s + M/B)` — host-staged
/// k-nomial.
pub fn host_staged_knomial(p: &ModelParams, n: usize, k: usize, m: u64) -> f64 {
    m as f64 / p.b_pcie * 1e9 + knomial_paper(p, n, k, m)
}

/// The optimal chunk size for Eq. (5): minimising
/// `(M/C + n-2)(t_s + C/B)` over C gives `C* = sqrt(M·t_s·B / (n-2))`.
pub fn optimal_chunk(p: &ModelParams, n: usize, m: u64) -> u64 {
    if n <= 2 {
        return m.max(1);
    }
    let c = ((m as f64) * (p.t_s_ns / 1e9) * p.b / (n as f64 - 2.0)).sqrt();
    (c.round() as u64).clamp(1, m.max(1))
}

/// ⌈log_k n⌉ for n ≥ 1.
pub fn ceil_log(n: usize, k: usize) -> usize {
    assert!(k >= 2);
    let mut rounds = 0;
    let mut reach = 1usize;
    while reach < n {
        reach = reach.saturating_mul(k);
        rounds += 1;
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> ModelParams {
        ModelParams {
            t_s_ns: 2_000.0,
            b: 10.0e9,
            b_pcie: 12.0e9,
        }
    }

    #[test]
    fn ceil_log_values() {
        assert_eq!(ceil_log(1, 2), 0);
        assert_eq!(ceil_log(2, 2), 1);
        assert_eq!(ceil_log(8, 2), 3);
        assert_eq!(ceil_log(9, 2), 4);
        assert_eq!(ceil_log(16, 4), 2);
        assert_eq!(ceil_log(17, 4), 3);
    }

    #[test]
    fn eq1_vs_exact() {
        let m = 1 << 20;
        assert!(direct_paper(&p(), 8, m) > direct(&p(), 8, m));
        assert_eq!(direct(&p(), 8, m), chain(&p(), 8, m));
    }

    #[test]
    fn eq5_beats_eq2_for_large_m() {
        let m = 64 << 20;
        let c = 2 << 20;
        assert!(pipelined_chain(&p(), 8, m, c) < chain(&p(), 8, m) / 3.0);
    }

    #[test]
    fn eq5_degenerates_to_chain_at_c_eq_m() {
        let m = 4 << 20;
        let diff =
            (pipelined_chain(&p(), 8, m, m) - chain(&p(), 8, m)).abs();
        assert!(diff < 1.0);
    }

    #[test]
    fn eq4_bandwidth_term_is_2m_over_b() {
        let m: u64 = 1 << 30;
        let n = 64;
        let t = scatter_allgather(&p(), n, m);
        let bw_term = 2.0 * (n as f64 - 1.0) / n as f64 * p().tx_ns(m);
        assert!((t - bw_term) / t < 0.01, "t_s terms negligible at 1 GB");
    }

    #[test]
    fn eq6_small_m_close_to_eq3() {
        let m = 4;
        let a = host_staged_knomial(&p(), 16, 2, m);
        let b = knomial_paper(&p(), 16, 2, m);
        assert!((a - b) < 10.0, "PCIe term vanishes for 4 bytes");
    }

    #[test]
    fn optimal_chunk_interior_minimum() {
        let params = p();
        let m: u64 = 64 << 20;
        let n = 16;
        let c_star = optimal_chunk(&params, n, m);
        let t_star = pipelined_chain(&params, n, m, c_star);
        for c in [c_star / 4, c_star / 2, c_star * 2, c_star * 4] {
            if c >= 1 && c <= m {
                assert!(
                    t_star <= pipelined_chain(&params, n, m, c) + 1.0,
                    "C*={c_star} must beat C={c}"
                );
            }
        }
    }

    #[test]
    fn serialized_depth_examples() {
        assert_eq!(serialized_depth(2, 2), 1);
        assert_eq!(serialized_depth(8, 2), 3);
        // k=4, n=16: root sends 3 serial sends; worst child (3rd) then
        // does its own 3 -> 6
        assert_eq!(serialized_depth(16, 4), 6);
    }
}
