//! Table I — notations for the analytical model.
//!
//! | name   | description                                            |
//! |--------|--------------------------------------------------------|
//! | M      | size of a message                                      |
//! | C      | size of a chunk                                        |
//! | B      | bandwidth of the link                                  |
//! | B_PCIe | PCIe bandwidth available for CPU↔GPU transfers         |
//! | n      | number of nodes (or GPUs)                              |
//! | t_s    | startup time for initiating a single transfer          |

/// The model parameter block. Times in ns, bandwidths in bytes/s.
#[derive(Debug, Clone, Copy)]
pub struct ModelParams {
    /// Startup time t_s per transfer, ns.
    pub t_s_ns: f64,
    /// Link bandwidth B, bytes/s.
    pub b: f64,
    /// CPU↔GPU PCIe bandwidth B_PCIe, bytes/s.
    pub b_pcie: f64,
}

impl ModelParams {
    /// Parameters matching the `flat` validation preset with the comm
    /// layer's eager path (small messages).
    pub fn flat_eager(params: &crate::comm::CommParams) -> ModelParams {
        ModelParams {
            t_s_ns: params.eager_overhead_ns as f64,
            b: crate::topology::LinkKind::Ideal.default_bandwidth(),
            b_pcie: crate::topology::LinkKind::PcieG3x16.default_bandwidth(),
        }
    }

    /// Parameters matching the `flat` preset with the rendezvous path
    /// (large messages).
    pub fn flat_rndv(params: &crate::comm::CommParams) -> ModelParams {
        ModelParams {
            t_s_ns: params.rndv_overhead_ns as f64,
            ..ModelParams::flat_eager(params)
        }
    }

    /// Transmission time M/B in ns.
    #[inline]
    pub fn tx_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.b * 1e9
    }

    /// One hop: t_s + M/B, ns.
    #[inline]
    pub fn hop_ns(&self, bytes: u64) -> f64 {
        self.t_s_ns + self.tx_ns(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommParams;

    #[test]
    fn hop_combines_terms() {
        let p = ModelParams {
            t_s_ns: 1000.0,
            b: 1.0e9,
            b_pcie: 12.0e9,
        };
        assert!((p.hop_ns(1_000_000) - 1_001_000.0).abs() < 1.0);
    }

    #[test]
    fn flat_presets_differ_in_ts_only() {
        let cp = CommParams::default();
        let e = ModelParams::flat_eager(&cp);
        let r = ModelParams::flat_rndv(&cp);
        assert!(r.t_s_ns > e.t_s_ns);
        assert_eq!(e.b, r.b);
    }
}
