//! The enhanced collective tuning framework (§IV of the paper).
//!
//! MVAPICH2-GDR's "MV2-GDR-Opt" is not one algorithm — it is a dispatch
//! table: for each (message-size bucket, GPU count, topology), the tuned
//! runtime picks the algorithm + chunk size that won an offline sweep.
//! This module is that framework:
//!
//! * [`space`] — the candidate grid (algorithms × chunk sizes);
//! * [`sweep`] — run the candidates on the simulator for a cluster;
//! * [`table`] — the message-size-bucketed dispatch table;
//! * [`selector`] — runtime lookup: `MV2-GDR-Opt` = tuned selection;
//! * [`persist`] — save/load tables as JSON artifacts.

pub mod persist;
pub mod selector;
pub mod space;
pub mod sweep;
pub mod table;

pub use selector::Selector;
pub use table::TuningTable;
