//! The enhanced collective tuning framework (§IV of the paper),
//! generalized per collective kind.
//!
//! MVAPICH2-GDR's "MV2-GDR-Opt" is not one algorithm — it is a dispatch
//! table: for each (collective, message-size bucket, GPU count,
//! topology), the tuned runtime picks the algorithm + chunk size that
//! won an offline sweep. This module is that framework, keyed on
//! `(CollectiveKind, bytes)` so the broadcast menu and the reduction
//! collectives (ring/tree allreduce, reduce-scatter, allgather) tune
//! side by side:
//!
//! * [`space`] — the candidate grid (per kind: algorithms × parameters);
//! * [`sweep`] — run the candidates on the simulator for a cluster;
//! * [`table`] — the (kind, size)-bucketed dispatch table;
//! * [`selector`] — runtime lookup: `MV2-GDR-Opt` = tuned selection;
//! * [`persist`] — save/load tables as JSON artifacts.

pub mod montecarlo;
pub mod persist;
pub mod selector;
pub mod space;
pub mod sweep;
pub mod table;

pub use selector::Selector;
pub use table::TuningTable;
