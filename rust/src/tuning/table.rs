//! The dispatch table: (collective kind, message-size bucket) → winning
//! algorithm.

use std::collections::BTreeMap;

use crate::collectives::{Algorithm, CollectiveKind};
use crate::netsim::LinkModel;

/// One tuned entry.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    /// Messages of size ≤ this (bytes) use this entry.
    pub max_bytes: u64,
    pub algorithm: Algorithm,
    /// The simulated latency that won the sweep (ns) at `max_bytes`.
    pub won_at_ns: u64,
}

/// A tuned dispatch table for one (cluster shape, rank count), keyed by
/// collective kind and message size.
#[derive(Debug, Clone, Default)]
pub struct TuningTable {
    /// Identifies the topology the table was tuned for.
    pub cluster: String,
    pub n_ranks: usize,
    /// The link-contention model the sweep simulated under: entries won
    /// against FIFO-serialized or max-min fair-shared links, and a
    /// selector should dispatch on an engine running the same model.
    pub link_model: LinkModel,
    /// Broadcast entries (the paper's original table), sorted by
    /// `max_bytes` ascending; the last entry also covers everything
    /// above it.
    pub entries: Vec<TableEntry>,
    /// Entries for the reduction collectives, same bucket layout.
    pub reductions: BTreeMap<CollectiveKind, Vec<TableEntry>>,
}

impl TuningTable {
    pub fn new(cluster: impl Into<String>, n_ranks: usize) -> TuningTable {
        TuningTable {
            cluster: cluster.into(),
            n_ranks,
            link_model: LinkModel::Fifo,
            entries: Vec::new(),
            reductions: BTreeMap::new(),
        }
    }

    /// Tag the table with the contention model that produced it.
    pub fn with_link_model(mut self, model: LinkModel) -> TuningTable {
        self.link_model = model;
        self
    }

    /// When a kind has no tuned entries, fall back to its sane default.
    fn fallback(kind: CollectiveKind) -> Algorithm {
        match kind {
            CollectiveKind::Broadcast => Algorithm::Knomial { k: 2 },
            CollectiveKind::ReduceScatter => Algorithm::RingReduceScatter,
            CollectiveKind::Allgather => Algorithm::RingAllgather,
            CollectiveKind::Allreduce => Algorithm::RingAllreduce,
        }
    }

    /// The entry list for a kind (empty slice when never tuned).
    pub fn entries_for(&self, kind: CollectiveKind) -> &[TableEntry] {
        match kind {
            CollectiveKind::Broadcast => &self.entries,
            _ => self
                .reductions
                .get(&kind)
                .map(Vec::as_slice)
                .unwrap_or(&[]),
        }
    }

    fn entries_mut(&mut self, kind: CollectiveKind) -> &mut Vec<TableEntry> {
        match kind {
            CollectiveKind::Broadcast => &mut self.entries,
            _ => self.reductions.entry(kind).or_default(),
        }
    }

    /// Look up the algorithm for a (collective kind, message size).
    pub fn select_for(&self, kind: CollectiveKind, bytes: u64) -> Algorithm {
        let entries = self.entries_for(kind);
        for e in entries {
            if bytes <= e.max_bytes {
                return e.algorithm;
            }
        }
        entries
            .last()
            .map(|e| e.algorithm)
            .unwrap_or_else(|| Self::fallback(kind))
    }

    /// Look up the broadcast algorithm for a message size (the original
    /// single-collective entry point).
    pub fn select(&self, bytes: u64) -> Algorithm {
        self.select_for(CollectiveKind::Broadcast, bytes)
    }

    /// Insert a broadcast entry keeping the size order.
    pub fn insert(&mut self, entry: TableEntry) {
        self.insert_for(CollectiveKind::Broadcast, entry);
    }

    /// Insert an entry for a kind keeping the size order.
    pub fn insert_for(&mut self, kind: CollectiveKind, entry: TableEntry) {
        let entries = self.entries_mut(kind);
        let pos = entries
            .binary_search_by_key(&entry.max_bytes, |e| e.max_bytes)
            .unwrap_or_else(|p| p);
        entries.insert(pos, entry);
    }

    /// Append a sweep bucket in ascending-size order, merging it into the
    /// previous bucket when the same algorithm won both.
    pub fn push_bucket(&mut self, kind: CollectiveKind, entry: TableEntry) {
        let entries = self.entries_mut(kind);
        if let Some(last) = entries.last_mut() {
            if last.algorithm == entry.algorithm {
                last.max_bytes = entry.max_bytes;
                last.won_at_ns = entry.won_at_ns;
                return;
            }
        }
        entries.push(entry);
    }

    fn render_kind(&self, kind: CollectiveKind) -> String {
        use crate::util::tablefmt::Table;
        let mut t = Table::new(&["<= size", "algorithm", "latency (us)"]).with_title(format!(
            "tuning table: {} ({} ranks, {}, {} link model)",
            self.cluster,
            self.n_ranks,
            kind.name(),
            self.link_model.name()
        ));
        for e in self.entries_for(kind) {
            let size = if e.max_bytes == u64::MAX {
                "max".to_string()
            } else {
                crate::util::bytes::format_size(e.max_bytes)
            };
            t.row(vec![
                size,
                e.algorithm.name(),
                crate::util::bytes::format_us(e.won_at_ns as f64),
            ]);
        }
        t.render()
    }

    /// Human-readable rendering (the paper's "tuned version" story),
    /// one section per tuned collective kind.
    pub fn render(&self) -> String {
        let mut out = self.render_kind(CollectiveKind::Broadcast);
        for (&kind, entries) in &self.reductions {
            if !entries.is_empty() {
                out.push('\n');
                out.push_str(&self.render_kind(kind));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> TuningTable {
        let mut t = TuningTable::new("test", 8);
        t.insert(TableEntry {
            max_bytes: 8 << 10,
            algorithm: Algorithm::HostStagedKnomial { k: 2 },
            won_at_ns: 3000,
        });
        t.insert(TableEntry {
            max_bytes: 1 << 20,
            algorithm: Algorithm::Knomial { k: 2 },
            won_at_ns: 90_000,
        });
        t.insert(TableEntry {
            max_bytes: u64::MAX,
            algorithm: Algorithm::PipelinedChain { chunk: 1 << 20 },
            won_at_ns: 10_000_000,
        });
        t
    }

    #[test]
    fn bucket_lookup() {
        let t = table();
        assert_eq!(t.select(4), Algorithm::HostStagedKnomial { k: 2 });
        assert_eq!(t.select(8 << 10), Algorithm::HostStagedKnomial { k: 2 });
        assert_eq!(t.select(64 << 10), Algorithm::Knomial { k: 2 });
        assert_eq!(
            t.select(128 << 20),
            Algorithm::PipelinedChain { chunk: 1 << 20 }
        );
    }

    #[test]
    fn empty_table_falls_back() {
        let t = TuningTable::default();
        assert_eq!(t.select(4), Algorithm::Knomial { k: 2 });
        assert_eq!(
            t.select_for(CollectiveKind::Allreduce, 4),
            Algorithm::RingAllreduce
        );
        assert_eq!(
            t.select_for(CollectiveKind::ReduceScatter, 4),
            Algorithm::RingReduceScatter
        );
        assert_eq!(
            t.select_for(CollectiveKind::Allgather, 4),
            Algorithm::RingAllgather
        );
    }

    #[test]
    fn render_lists_entries() {
        let s = table().render();
        assert!(s.contains("host-staged-knomial"));
        assert!(s.contains("pipelined-chain"));
        // the table advertises the contention model it was tuned under
        assert!(s.contains("fifo link model"));
    }

    #[test]
    fn link_model_tag_defaults_fifo_and_renders() {
        let t = table();
        assert_eq!(t.link_model, LinkModel::Fifo);
        let fair = table().with_link_model(LinkModel::FairShare);
        assert_eq!(fair.link_model, LinkModel::FairShare);
        assert!(fair.render().contains("fairshare link model"));
    }

    #[test]
    fn per_kind_entries_are_independent() {
        let mut t = table();
        t.insert_for(
            CollectiveKind::Allreduce,
            TableEntry {
                max_bytes: 64 << 10,
                algorithm: Algorithm::TreeAllreduce { k: 2 },
                won_at_ns: 9_000,
            },
        );
        t.insert_for(
            CollectiveKind::Allreduce,
            TableEntry {
                max_bytes: u64::MAX,
                algorithm: Algorithm::RingAllreduce,
                won_at_ns: 30_000_000,
            },
        );
        assert_eq!(
            t.select_for(CollectiveKind::Allreduce, 4),
            Algorithm::TreeAllreduce { k: 2 }
        );
        assert_eq!(
            t.select_for(CollectiveKind::Allreduce, 16 << 20),
            Algorithm::RingAllreduce
        );
        // broadcast lookups are untouched
        assert_eq!(t.select(4), Algorithm::HostStagedKnomial { k: 2 });
        let s = t.render();
        assert!(s.contains("allreduce"));
        assert!(s.contains("tree-allreduce"));
    }

    #[test]
    fn push_bucket_merges_adjacent_same_winner() {
        let mut t = TuningTable::new("x", 4);
        for (max_bytes, algo) in [
            (1 << 10, Algorithm::RingAllreduce),
            (1 << 20, Algorithm::RingAllreduce),
            (u64::MAX, Algorithm::TreeAllreduce { k: 2 }),
        ] {
            t.push_bucket(
                CollectiveKind::Allreduce,
                TableEntry {
                    max_bytes,
                    algorithm: algo,
                    won_at_ns: 1,
                },
            );
        }
        let entries = t.entries_for(CollectiveKind::Allreduce);
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].max_bytes, 1 << 20);
    }
}
