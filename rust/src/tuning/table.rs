//! The dispatch table: message-size buckets → winning algorithm.

use crate::collectives::Algorithm;

/// One tuned entry.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    /// Messages of size ≤ this (bytes) use this entry.
    pub max_bytes: u64,
    pub algorithm: Algorithm,
    /// The simulated latency that won the sweep (ns) at `max_bytes`.
    pub won_at_ns: u64,
}

/// A tuned dispatch table for one (cluster shape, rank count).
#[derive(Debug, Clone, Default)]
pub struct TuningTable {
    /// Identifies the topology the table was tuned for.
    pub cluster: String,
    pub n_ranks: usize,
    /// Entries sorted by `max_bytes` ascending; the last entry also
    /// covers everything above it.
    pub entries: Vec<TableEntry>,
}

impl TuningTable {
    /// Look up the algorithm for a message size.
    pub fn select(&self, bytes: u64) -> Algorithm {
        for e in &self.entries {
            if bytes <= e.max_bytes {
                return e.algorithm;
            }
        }
        self.entries
            .last()
            .map(|e| e.algorithm)
            .unwrap_or(Algorithm::Knomial { k: 2 })
    }

    /// Insert an entry keeping the size order.
    pub fn insert(&mut self, entry: TableEntry) {
        let pos = self
            .entries
            .binary_search_by_key(&entry.max_bytes, |e| e.max_bytes)
            .unwrap_or_else(|p| p);
        self.entries.insert(pos, entry);
    }

    /// Human-readable rendering (the paper's "tuned version" story).
    pub fn render(&self) -> String {
        use crate::util::tablefmt::Table;
        let mut t = Table::new(&["<= size", "algorithm", "latency (us)"])
            .with_title(format!(
                "tuning table: {} ({} ranks)",
                self.cluster, self.n_ranks
            ));
        for e in &self.entries {
            let size = if e.max_bytes == u64::MAX {
                "max".to_string()
            } else {
                crate::util::bytes::format_size(e.max_bytes)
            };
            t.row(vec![
                size,
                e.algorithm.name(),
                crate::util::bytes::format_us(e.won_at_ns as f64),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> TuningTable {
        let mut t = TuningTable {
            cluster: "test".into(),
            n_ranks: 8,
            entries: Vec::new(),
        };
        t.insert(TableEntry {
            max_bytes: 8 << 10,
            algorithm: Algorithm::HostStagedKnomial { k: 2 },
            won_at_ns: 3000,
        });
        t.insert(TableEntry {
            max_bytes: 1 << 20,
            algorithm: Algorithm::Knomial { k: 2 },
            won_at_ns: 90_000,
        });
        t.insert(TableEntry {
            max_bytes: u64::MAX,
            algorithm: Algorithm::PipelinedChain { chunk: 1 << 20 },
            won_at_ns: 10_000_000,
        });
        t
    }

    #[test]
    fn bucket_lookup() {
        let t = table();
        assert_eq!(t.select(4), Algorithm::HostStagedKnomial { k: 2 });
        assert_eq!(t.select(8 << 10), Algorithm::HostStagedKnomial { k: 2 });
        assert_eq!(t.select(64 << 10), Algorithm::Knomial { k: 2 });
        assert_eq!(
            t.select(128 << 20),
            Algorithm::PipelinedChain { chunk: 1 << 20 }
        );
    }

    #[test]
    fn empty_table_falls_back() {
        let t = TuningTable::default();
        assert_eq!(t.select(4), Algorithm::Knomial { k: 2 });
    }

    #[test]
    fn render_lists_entries() {
        let s = table().render();
        assert!(s.contains("host-staged-knomial"));
        assert!(s.contains("pipelined-chain"));
    }
}
