//! The candidate space the tuner sweeps, per collective kind.

use crate::collectives::{Algorithm, CollectiveKind};

/// Chunk sizes tried for the pipelined chain (powers of two, 64 KB–8 MB —
//  the range MVAPICH2's tuning infrastructure explores).
pub fn chunk_candidates() -> Vec<u64> {
    vec![
        64 << 10,
        128 << 10,
        256 << 10,
        512 << 10,
        1 << 20,
        2 << 20,
        4 << 20,
        8 << 20,
    ]
}

/// Host staging replicates the payload across every host→GPU fan-out
/// write; beyond this size the PCIe-volume cost outweighs the latency
/// win (Eq. 6's M/B_PCIe term, felt sharply under the concurrent-bcast
/// load of training schedules), so MV2 only stages small messages.
pub const STAGING_MAX_BYTES: u64 = 32 << 10;

/// All candidate broadcast algorithms for a given message size (pruning
/// obviously hopeless candidates keeps sweeps fast without changing
/// winners).
pub fn candidates(bytes: u64) -> Vec<Algorithm> {
    let mut out = vec![
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::Knomial { k: 8 },
    ];
    if bytes <= STAGING_MAX_BYTES {
        out.push(Algorithm::HostStagedKnomial { k: 2 });
        out.push(Algorithm::HostStagedKnomial { k: 4 });
    }
    if bytes >= 4 << 10 {
        out.push(Algorithm::ScatterRingAllgather);
        out.push(Algorithm::Chain);
        for chunk in chunk_candidates() {
            if chunk <= bytes {
                out.push(Algorithm::PipelinedChain { chunk });
            }
        }
    }
    out
}

/// All candidates for a (collective kind, message size).
pub fn candidates_for(kind: CollectiveKind, bytes: u64) -> Vec<Algorithm> {
    match kind {
        CollectiveKind::Broadcast => candidates(bytes),
        CollectiveKind::ReduceScatter => vec![Algorithm::RingReduceScatter],
        CollectiveKind::Allgather => vec![Algorithm::RingAllgather],
        CollectiveKind::Allreduce => vec![
            Algorithm::RingAllreduce,
            Algorithm::TreeAllreduce { k: 2 },
            Algorithm::TreeAllreduce { k: 4 },
            Algorithm::TreeAllreduce { k: 8 },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_skip_pipelining() {
        let c = candidates(4);
        assert!(c
            .iter()
            .all(|a| !matches!(a, Algorithm::PipelinedChain { .. })));
        assert!(c.iter().any(|a| matches!(a, Algorithm::HostStagedKnomial { .. })));
    }

    #[test]
    fn large_messages_include_pipelined_chain() {
        let c = candidates(64 << 20);
        let n_pipe = c
            .iter()
            .filter(|a| matches!(a, Algorithm::PipelinedChain { .. }))
            .count();
        assert_eq!(n_pipe, chunk_candidates().len());
    }

    #[test]
    fn chunk_candidates_sorted_pow2() {
        let cs = chunk_candidates();
        for w in cs.windows(2) {
            assert_eq!(w[1], w[0] * 2);
        }
    }

    #[test]
    fn per_kind_candidates_implement_their_kind() {
        for kind in CollectiveKind::ALL {
            for bytes in [4u64, 64 << 10, 64 << 20] {
                let cands = candidates_for(kind, bytes);
                assert!(!cands.is_empty());
                for algo in cands {
                    assert_eq!(algo.kind(), kind, "{}", algo.name());
                }
            }
        }
    }
}
