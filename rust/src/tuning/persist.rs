//! Save/load tuning tables as JSON artifacts.

use std::path::Path;

use crate::collectives::{Algorithm, CollectiveKind};
use crate::error::{Error, Result};
use crate::util::json::Json;

use super::table::{TableEntry, TuningTable};

fn algo_to_json(a: &Algorithm) -> Json {
    let mut j = Json::obj();
    j.set("family", a.family());
    match a {
        Algorithm::PipelinedChain { chunk } => {
            j.set("chunk", *chunk);
        }
        Algorithm::Knomial { k }
        | Algorithm::HostStagedKnomial { k }
        | Algorithm::TreeAllreduce { k } => {
            j.set("k", *k as u64);
        }
        _ => {}
    }
    j
}

fn algo_from_json(j: &Json) -> Result<Algorithm> {
    let family = j
        .get("family")
        .and_then(|v| v.as_str())
        .ok_or_else(|| Error::Config("algorithm missing family".into()))?;
    let k_of = |j: &Json| j.get("k").and_then(|v| v.as_u64()).unwrap_or(2) as usize;
    Ok(match family {
        "direct" => Algorithm::Direct,
        "chain" => Algorithm::Chain,
        "pipelined-chain" => Algorithm::PipelinedChain {
            chunk: j
                .get("chunk")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Config("pipelined-chain missing chunk".into()))?,
        },
        "knomial" => Algorithm::Knomial { k: k_of(j) },
        "scatter-ring-allgather" => Algorithm::ScatterRingAllgather,
        "host-staged-knomial" => Algorithm::HostStagedKnomial { k: k_of(j) },
        "ring-reduce-scatter" => Algorithm::RingReduceScatter,
        "ring-allgather" => Algorithm::RingAllgather,
        "ring-allreduce" => Algorithm::RingAllreduce,
        "tree-allreduce" => Algorithm::TreeAllreduce { k: k_of(j) },
        other => return Err(Error::Config(format!("unknown algorithm '{other}'"))),
    })
}

fn entry_to_json(e: &TableEntry) -> Json {
    let mut ej = Json::obj();
    ej.set("max_bytes", e.max_bytes).set("won_at_ns", e.won_at_ns);
    ej.set("algorithm", algo_to_json(&e.algorithm));
    ej
}

fn entry_from_json(ej: &Json) -> Result<TableEntry> {
    Ok(TableEntry {
        max_bytes: ej
            .get("max_bytes")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| Error::Config("entry missing max_bytes".into()))?,
        won_at_ns: ej.get("won_at_ns").and_then(|v| v.as_u64()).unwrap_or(0),
        algorithm: algo_from_json(
            ej.get("algorithm")
                .ok_or_else(|| Error::Config("entry missing algorithm".into()))?,
        )?,
    })
}

/// Serialise a table to JSON text. The broadcast entries keep the
/// original `entries` key (old artifacts stay loadable); reduction
/// collectives serialise under `reductions` keyed by kind name.
pub fn to_json(table: &TuningTable) -> String {
    let mut j = Json::obj();
    j.set("cluster", table.cluster.as_str());
    j.set("n_ranks", table.n_ranks);
    j.set("link_model", table.link_model.name());
    let entries: Vec<Json> = table.entries.iter().map(entry_to_json).collect();
    j.set("entries", Json::Arr(entries));
    let mut reductions = Json::obj();
    for (kind, entries) in &table.reductions {
        let arr: Vec<Json> = entries.iter().map(entry_to_json).collect();
        reductions.set(kind.name(), Json::Arr(arr));
    }
    j.set("reductions", reductions);
    j.to_string_pretty()
}

/// Parse a table from JSON text.
pub fn from_json(text: &str) -> Result<TuningTable> {
    let j = Json::parse(text)?;
    let cluster = j
        .get("cluster")
        .and_then(|v| v.as_str())
        .unwrap_or("")
        .to_string();
    let n_ranks = j.get("n_ranks").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
    let mut table = TuningTable::new(cluster, n_ranks);
    // absent in pre-fair-share artifacts: those were tuned under FIFO
    if let Some(name) = j.get("link_model").and_then(|v| v.as_str()) {
        table.link_model = crate::netsim::LinkModel::parse(name)
            .ok_or_else(|| Error::Config(format!("unknown link model '{name}'")))?;
    }
    for ej in j
        .get("entries")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Config("tuning table missing entries".into()))?
    {
        table.entries.push(entry_from_json(ej)?);
    }
    // reductions are optional: pre-refactor artifacts carry none
    if let Some(Json::Obj(map)) = j.get("reductions") {
        for (name, arr) in map {
            let kind = CollectiveKind::parse(name)
                .ok_or_else(|| Error::Config(format!("unknown collective '{name}'")))?;
            let arr = arr
                .as_arr()
                .ok_or_else(|| Error::Config(format!("'{name}' entries must be an array")))?;
            for ej in arr {
                table.insert_for(kind, entry_from_json(ej)?);
            }
        }
    }
    Ok(table)
}

/// Save to a file.
pub fn save(table: &TuningTable, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_json(table))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &Path) -> Result<TuningTable> {
    from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TuningTable {
        let mut t = TuningTable::new("kesch-1x16", 16);
        t.entries = vec![
            TableEntry {
                max_bytes: 8 << 10,
                algorithm: Algorithm::HostStagedKnomial { k: 4 },
                won_at_ns: 3_500,
            },
            TableEntry {
                max_bytes: u64::MAX,
                algorithm: Algorithm::PipelinedChain { chunk: 2 << 20 },
                won_at_ns: 14_000_000,
            },
        ];
        t.insert_for(
            CollectiveKind::Allreduce,
            TableEntry {
                max_bytes: 64 << 10,
                algorithm: Algorithm::TreeAllreduce { k: 2 },
                won_at_ns: 9_000,
            },
        );
        t.insert_for(
            CollectiveKind::Allreduce,
            TableEntry {
                max_bytes: u64::MAX,
                algorithm: Algorithm::RingAllreduce,
                won_at_ns: 28_000_000,
            },
        );
        t.insert_for(
            CollectiveKind::ReduceScatter,
            TableEntry {
                max_bytes: u64::MAX,
                algorithm: Algorithm::RingReduceScatter,
                won_at_ns: 11_000_000,
            },
        );
        t
    }

    #[test]
    fn json_roundtrip() {
        let t = sample();
        let back = from_json(&to_json(&t)).unwrap();
        assert_eq!(back.cluster, t.cluster);
        assert_eq!(back.n_ranks, t.n_ranks);
        assert_eq!(back.entries, t.entries);
        assert_eq!(back.reductions, t.reductions);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("gdrbcast-test-persist");
        let path = dir.join("table.json");
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.entries, t.entries);
        assert_eq!(back.reductions, t.reductions);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn max_bytes_u64max_survives() {
        // u64::MAX can't round-trip exactly through f64; the paper's
        // tables cap at 1 GB anyway — verify we keep ordering + coverage
        let t = sample();
        let back = from_json(&to_json(&t)).unwrap();
        assert!(back.entries[1].max_bytes > 1 << 62);
    }

    #[test]
    fn link_model_round_trips_and_defaults_fifo() {
        use crate::netsim::LinkModel;
        let t = sample().with_link_model(LinkModel::FairShare);
        let back = from_json(&to_json(&t)).unwrap();
        assert_eq!(back.link_model, LinkModel::FairShare);
        assert_eq!(back.entries, t.entries);
        // artifacts written before the contention-model split carry no
        // link_model key: they were tuned under FIFO
        let text = r#"{"cluster":"x","n_ranks":2,"entries":[
            {"max_bytes":4,"won_at_ns":1,"algorithm":{"family":"chain"}}]}"#;
        assert_eq!(from_json(text).unwrap().link_model, LinkModel::Fifo);
        // an unknown model name is a config error, not a silent default
        let bad = r#"{"cluster":"x","n_ranks":2,"link_model":"bogus","entries":[]}"#;
        assert!(from_json(bad).is_err());
    }

    #[test]
    fn rejects_bad_family() {
        let text = r#"{"cluster":"x","n_ranks":2,"entries":[
            {"max_bytes":4,"won_at_ns":1,"algorithm":{"family":"bogus"}}]}"#;
        assert!(from_json(text).is_err());
    }

    #[test]
    fn pre_refactor_artifact_without_reductions_loads() {
        let text = r#"{"cluster":"x","n_ranks":2,"entries":[
            {"max_bytes":4,"won_at_ns":1,"algorithm":{"family":"chain"}}]}"#;
        let t = from_json(text).unwrap();
        assert_eq!(t.entries.len(), 1);
        assert!(t.reductions.is_empty());
    }
}
