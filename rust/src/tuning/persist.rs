//! Save/load tuning tables as JSON artifacts.

use std::path::Path;

use crate::collectives::Algorithm;
use crate::error::{Error, Result};
use crate::util::json::Json;

use super::table::{TableEntry, TuningTable};

fn algo_to_json(a: &Algorithm) -> Json {
    let mut j = Json::obj();
    j.set("family", a.family());
    match a {
        Algorithm::PipelinedChain { chunk } => {
            j.set("chunk", *chunk);
        }
        Algorithm::Knomial { k } | Algorithm::HostStagedKnomial { k } => {
            j.set("k", *k as u64);
        }
        _ => {}
    }
    j
}

fn algo_from_json(j: &Json) -> Result<Algorithm> {
    let family = j
        .get("family")
        .and_then(|v| v.as_str())
        .ok_or_else(|| Error::Config("algorithm missing family".into()))?;
    Ok(match family {
        "direct" => Algorithm::Direct,
        "chain" => Algorithm::Chain,
        "pipelined-chain" => Algorithm::PipelinedChain {
            chunk: j
                .get("chunk")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Config("pipelined-chain missing chunk".into()))?,
        },
        "knomial" => Algorithm::Knomial {
            k: j.get("k").and_then(|v| v.as_u64()).unwrap_or(2) as usize,
        },
        "scatter-ring-allgather" => Algorithm::ScatterRingAllgather,
        "host-staged-knomial" => Algorithm::HostStagedKnomial {
            k: j.get("k").and_then(|v| v.as_u64()).unwrap_or(2) as usize,
        },
        other => return Err(Error::Config(format!("unknown algorithm '{other}'"))),
    })
}

/// Serialise a table to JSON text.
pub fn to_json(table: &TuningTable) -> String {
    let mut j = Json::obj();
    j.set("cluster", table.cluster.as_str());
    j.set("n_ranks", table.n_ranks);
    let entries: Vec<Json> = table
        .entries
        .iter()
        .map(|e| {
            let mut ej = Json::obj();
            ej.set("max_bytes", e.max_bytes).set("won_at_ns", e.won_at_ns);
            ej.set("algorithm", algo_to_json(&e.algorithm));
            ej
        })
        .collect();
    j.set("entries", Json::Arr(entries));
    j.to_string_pretty()
}

/// Parse a table from JSON text.
pub fn from_json(text: &str) -> Result<TuningTable> {
    let j = Json::parse(text)?;
    let cluster = j
        .get("cluster")
        .and_then(|v| v.as_str())
        .unwrap_or("")
        .to_string();
    let n_ranks = j.get("n_ranks").and_then(|v| v.as_u64()).unwrap_or(0) as usize;
    let mut entries = Vec::new();
    for ej in j
        .get("entries")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Config("tuning table missing entries".into()))?
    {
        entries.push(TableEntry {
            max_bytes: ej
                .get("max_bytes")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| Error::Config("entry missing max_bytes".into()))?,
            won_at_ns: ej.get("won_at_ns").and_then(|v| v.as_u64()).unwrap_or(0),
            algorithm: algo_from_json(
                ej.get("algorithm")
                    .ok_or_else(|| Error::Config("entry missing algorithm".into()))?,
            )?,
        });
    }
    Ok(TuningTable {
        cluster,
        n_ranks,
        entries,
    })
}

/// Save to a file.
pub fn save(table: &TuningTable, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_json(table))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &Path) -> Result<TuningTable> {
    from_json(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TuningTable {
        TuningTable {
            cluster: "kesch-1x16".into(),
            n_ranks: 16,
            entries: vec![
                TableEntry {
                    max_bytes: 8 << 10,
                    algorithm: Algorithm::HostStagedKnomial { k: 4 },
                    won_at_ns: 3_500,
                },
                TableEntry {
                    max_bytes: u64::MAX,
                    algorithm: Algorithm::PipelinedChain { chunk: 2 << 20 },
                    won_at_ns: 14_000_000,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = sample();
        let back = from_json(&to_json(&t)).unwrap();
        assert_eq!(back.cluster, t.cluster);
        assert_eq!(back.n_ranks, t.n_ranks);
        assert_eq!(back.entries, t.entries);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let dir = std::env::temp_dir().join("gdrbcast-test-persist");
        let path = dir.join("table.json");
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.entries, t.entries);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn max_bytes_u64max_survives() {
        // u64::MAX can't round-trip exactly through f64; the paper's
        // tables cap at 1 GB anyway — verify we keep ordering + coverage
        let t = sample();
        let back = from_json(&to_json(&t)).unwrap();
        assert!(back.entries[1].max_bytes > 1 << 62);
    }

    #[test]
    fn rejects_bad_family() {
        let text = r#"{"cluster":"x","n_ranks":2,"entries":[
            {"max_bytes":4,"won_at_ns":1,"algorithm":{"family":"bogus"}}]}"#;
        assert!(from_json(text).is_err());
    }
}
