//! Offline tuning sweeps: run every candidate on the simulator.

use crate::collectives::{self, Algorithm, BcastSpec};
use crate::comm::Comm;
use crate::netsim::Engine;
use crate::topology::Cluster;

use super::space;
use super::table::{TableEntry, TuningTable};

/// Result of sweeping one message size.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub bytes: u64,
    pub winner: Algorithm,
    pub winner_ns: u64,
    /// (algorithm, latency ns) for every candidate, sorted fastest first.
    pub all: Vec<(Algorithm, u64)>,
}

/// Sweep all candidates at one size.
pub fn sweep_size(cluster: &Cluster, bytes: u64, root: usize) -> SweepPoint {
    let n = cluster.n_gpus();
    let spec = BcastSpec::new(root, n, bytes);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::new(cluster);
    let mut all: Vec<(Algorithm, u64)> = space::candidates(bytes)
        .into_iter()
        .map(|algo| {
            let t = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            (algo, t)
        })
        .collect();
    all.sort_by_key(|&(_, t)| t);
    let (winner, winner_ns) = all[0];
    SweepPoint {
        bytes,
        winner,
        winner_ns,
        all,
    }
}

/// Build a tuned table by sweeping a size grid.
pub fn tune(cluster: &Cluster, sizes: &[u64]) -> TuningTable {
    let mut table = TuningTable {
        cluster: cluster.name.clone(),
        n_ranks: cluster.n_gpus(),
        entries: Vec::new(),
    };
    for (i, &bytes) in sizes.iter().enumerate() {
        let point = sweep_size(cluster, bytes, 0);
        let max_bytes = if i + 1 == sizes.len() {
            u64::MAX
        } else {
            bytes
        };
        // merge adjacent buckets won by the same algorithm
        if let Some(last) = table.entries.last_mut() {
            if last.algorithm == point.winner {
                last.max_bytes = max_bytes;
                last.won_at_ns = point.winner_ns;
                continue;
            }
        }
        table.entries.push(TableEntry {
            max_bytes,
            algorithm: point.winner,
            won_at_ns: point.winner_ns,
        });
    }
    table
}

/// The default tuning size grid (powers of two, 4 B – 128 MB).
pub fn default_sizes() -> Vec<u64> {
    crate::util::bytes::pow2_sweep(4, 128 << 20)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    #[test]
    fn tuner_picks_staged_small_and_pipelined_large() {
        let cluster = kesch(1, 16);
        let table = tune(&cluster, &[4, 8 << 10, 1 << 20, 32 << 20, 128 << 20]);
        let small = table.select(4);
        assert!(
            matches!(small, Algorithm::HostStagedKnomial { .. })
                || matches!(small, Algorithm::Knomial { .. }),
            "small-message winner: {}",
            small.name()
        );
        let large = table.select(128 << 20);
        assert!(
            matches!(large, Algorithm::PipelinedChain { .. })
                || matches!(large, Algorithm::ScatterRingAllgather),
            "large-message winner: {}",
            large.name()
        );
    }

    #[test]
    fn tuned_beats_or_ties_every_fixed_algorithm() {
        let cluster = kesch(1, 8);
        for bytes in [4u64, 64 << 10, 16 << 20] {
            let point = sweep_size(&cluster, bytes, 0);
            for &(_, t) in &point.all {
                assert!(point.winner_ns <= t);
            }
        }
    }

    #[test]
    fn adjacent_same_winner_buckets_merge() {
        let cluster = kesch(1, 4);
        let table = tune(&cluster, &default_sizes());
        for w in table.entries.windows(2) {
            assert_ne!(
                w[0].algorithm, w[1].algorithm,
                "adjacent entries must differ after merging"
            );
        }
        assert_eq!(table.entries.last().unwrap().max_bytes, u64::MAX);
    }
}
