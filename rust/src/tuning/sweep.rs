//! Offline tuning sweeps: run every candidate on the simulator, per
//! collective kind.
//!
//! [`tune`] fans the (collective kind, message size) grid across
//! `std::thread::scope` workers — each worker owns its *own* cluster
//! clone (the route-intern table is deliberately single-threaded, see
//! [`crate::topology::RouteTable`]) plus its own [`Comm`] / [`Engine`],
//! and results merge back in grid order, so the produced table is
//! byte-identical to a serial run ([`tune_serial`] keeps the reference
//! path alive for the determinism test and for perf comparisons).
//!
//! Each worker's `Comm` persists across its grid points: path-plan
//! selection is canonical per size class and plan templates rescale
//! byte-exactly, so every point stays a pure function of the cluster
//! while the template cache turns the size axis of the sweep into
//! rescales instead of rebuilds (DESIGN.md §Plan templates). The
//! [`tune_with_threads`] variant bounds the fan-out for constrained CI
//! runners (`--tune-threads`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::collectives::{self, Algorithm, CollectiveKind, CollectiveSpec};
use crate::comm::Comm;
use crate::netsim::{Engine, LinkModel};
use crate::topology::Cluster;

use super::space;
use super::table::{TableEntry, TuningTable};

/// Result of sweeping one (collective kind, message size).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub kind: CollectiveKind,
    pub bytes: u64,
    pub winner: Algorithm,
    pub winner_ns: u64,
    /// (algorithm, latency ns) for every candidate, sorted fastest first.
    pub all: Vec<(Algorithm, u64)>,
}

/// Sweep all candidates of one kind at one size with caller-owned
/// simulator state — the building block both the serial and the parallel
/// tuner share. The `Comm` (path cache + plan-template cache), the
/// `Engine` (stateless across runs) and the cluster's route-intern table
/// may all be reused across points: path plans resolve at each class's
/// canonical size and templates rescale byte-exactly, so a point's
/// result is a pure function of the cluster regardless of what warmed
/// the caches — the property the parallel-equals-serial guarantee and
/// the golden parity suite pin down.
pub fn sweep_size_with(
    comm: &mut Comm,
    engine: &mut Engine,
    kind: CollectiveKind,
    bytes: u64,
    root: usize,
) -> SweepPoint {
    let n = comm.cluster().n_gpus();
    let spec = CollectiveSpec::collective(kind, root, n, bytes);
    let mut all: Vec<(Algorithm, u64)> = space::candidates_for(kind, bytes)
        .into_iter()
        .map(|algo| {
            let t = collectives::latency_ns(&algo, comm, engine, &spec);
            (algo, t)
        })
        .collect();
    all.sort_by_key(|&(_, t)| t);
    let (winner, winner_ns) = all[0];
    SweepPoint {
        kind,
        bytes,
        winner,
        winner_ns,
        all,
    }
}

/// Sweep all candidates of one kind at one size (self-contained variant).
pub fn sweep_size_for(
    cluster: &Cluster,
    kind: CollectiveKind,
    bytes: u64,
    root: usize,
) -> SweepPoint {
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::new(cluster);
    sweep_size_with(&mut comm, &mut engine, kind, bytes, root)
}

/// Sweep all broadcast candidates at one size (the original entry point).
pub fn sweep_size(cluster: &Cluster, bytes: u64, root: usize) -> SweepPoint {
    sweep_size_for(cluster, CollectiveKind::Broadcast, bytes, root)
}

/// The flattened (kind, size) grid, in the deterministic merge order.
fn grid(sizes: &[u64]) -> Vec<(CollectiveKind, u64)> {
    CollectiveKind::ALL
        .iter()
        .flat_map(|&kind| sizes.iter().map(move |&bytes| (kind, bytes)))
        .collect()
}

/// Fold swept points (in [`grid`] order) into the bucketed table — shared
/// by the serial and parallel tuners so their output is identical. The
/// table records the contention model the points were simulated under.
fn table_from_points(
    cluster: &Cluster,
    sizes: &[u64],
    points: Vec<SweepPoint>,
    model: LinkModel,
) -> TuningTable {
    let mut table = TuningTable::new(cluster.name.clone(), cluster.n_gpus()).with_link_model(model);
    for (p, point) in points.into_iter().enumerate() {
        let i = p % sizes.len();
        let max_bytes = if i + 1 == sizes.len() {
            u64::MAX
        } else {
            point.bytes
        };
        table.push_bucket(
            point.kind,
            TableEntry {
                max_bytes,
                algorithm: point.winner,
                won_at_ns: point.winner_ns,
            },
        );
    }
    table
}

/// Build a tuned table for every collective kind by sweeping a size grid,
/// fanning the grid points across OS threads (available parallelism).
/// Deterministic: the merge runs in grid order and every point is a pure
/// function of the cluster, so the table is byte-identical to
/// [`tune_serial`]'s.
pub fn tune(cluster: &Cluster, sizes: &[u64]) -> TuningTable {
    tune_with_threads(cluster, sizes, None)
}

/// [`tune`] with an explicit bound on the worker fan-out. `None` uses
/// available parallelism; `Some(1)` runs the serial reference path —
/// constrained CI runners and laptops set this via `--tune-threads`.
pub fn tune_with_threads(
    cluster: &Cluster,
    sizes: &[u64],
    threads: Option<usize>,
) -> TuningTable {
    tune_with_model(cluster, sizes, threads, LinkModel::Fifo)
}

/// [`tune_with_threads`] under an explicit link-contention model: every
/// candidate is simulated on an engine running `model`, and the produced
/// table records it ([`TuningTable::link_model`]) so a selector can be
/// matched to the engine it will dispatch for. The winners *can* differ
/// between models — fair sharing changes what concurrent chunks of a
/// pipelined chain or ring cost on a shared link.
pub fn tune_with_model(
    cluster: &Cluster,
    sizes: &[u64],
    threads: Option<usize>,
    model: LinkModel,
) -> TuningTable {
    let points = grid(sizes);
    let n_workers = threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
        .min(points.len().max(1));
    if n_workers <= 1 {
        return tune_serial_with_model(cluster, sizes, model);
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<SweepPoint>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            // each worker owns a cluster clone: the route-intern table is
            // interior-mutable and intentionally not Sync (hot-path reads
            // carry no atomics); cloning a cluster is a few hundred
            // device/link records
            let local = cluster.clone();
            let next = &next;
            let slots = &slots;
            let points = &points;
            s.spawn(move || {
                let mut engine = Engine::with_model(&local, model);
                // one Comm per worker, persistent across its points: the
                // template cache rescales across the size axis, and
                // canonical path selection keeps every point a pure
                // function of the cluster (see sweep_size_with)
                let mut comm = Comm::new(&local);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let (kind, bytes) = points[i];
                    let point = sweep_size_with(&mut comm, &mut engine, kind, bytes, 0);
                    *slots[i].lock().expect("sweep slot poisoned") = Some(point);
                }
            });
        }
    });
    let results: Vec<SweepPoint> = slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep slot poisoned")
                .expect("sweep point missing")
        })
        .collect();
    table_from_points(cluster, sizes, results, model)
}

/// The single-threaded reference tuner: same grid, same merge, one
/// worker. Kept public so tests (and `sweep_perf`) can assert the
/// parallel path persists a byte-identical table.
pub fn tune_serial(cluster: &Cluster, sizes: &[u64]) -> TuningTable {
    tune_serial_with_model(cluster, sizes, LinkModel::Fifo)
}

/// [`tune_serial`] under an explicit link-contention model.
pub fn tune_serial_with_model(
    cluster: &Cluster,
    sizes: &[u64],
    model: LinkModel,
) -> TuningTable {
    let mut engine = Engine::with_model(cluster, model);
    let mut comm = Comm::new(cluster);
    let results: Vec<SweepPoint> = grid(sizes)
        .into_iter()
        .map(|(kind, bytes)| sweep_size_with(&mut comm, &mut engine, kind, bytes, 0))
        .collect();
    table_from_points(cluster, sizes, results, model)
}

/// The default tuning size grid (powers of two, 4 B – 128 MB).
pub fn default_sizes() -> Vec<u64> {
    crate::util::bytes::pow2_sweep(4, 128 << 20)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;
    use crate::tuning::persist;

    #[test]
    fn tuner_picks_staged_small_and_pipelined_large() {
        let cluster = kesch(1, 16).unwrap();
        let table = tune(&cluster, &[4, 8 << 10, 1 << 20, 32 << 20, 128 << 20]);
        let small = table.select(4);
        assert!(
            matches!(small, Algorithm::HostStagedKnomial { .. })
                || matches!(small, Algorithm::Knomial { .. }),
            "small-message winner: {}",
            small.name()
        );
        let large = table.select(128 << 20);
        assert!(
            matches!(large, Algorithm::PipelinedChain { .. })
                || matches!(large, Algorithm::ScatterRingAllgather),
            "large-message winner: {}",
            large.name()
        );
    }

    #[test]
    fn tuned_beats_or_ties_every_fixed_algorithm() {
        let cluster = kesch(1, 8).unwrap();
        for bytes in [4u64, 64 << 10, 16 << 20] {
            let point = sweep_size(&cluster, bytes, 0);
            for &(_, t) in &point.all {
                assert!(point.winner_ns <= t);
            }
        }
    }

    #[test]
    fn adjacent_same_winner_buckets_merge() {
        let cluster = kesch(1, 4).unwrap();
        let table = tune(&cluster, &default_sizes());
        for w in table.entries.windows(2) {
            assert_ne!(
                w[0].algorithm, w[1].algorithm,
                "adjacent entries must differ after merging"
            );
        }
        assert_eq!(table.entries.last().unwrap().max_bytes, u64::MAX);
    }

    #[test]
    fn allreduce_table_tree_small_ring_large() {
        let cluster = kesch(1, 16).unwrap();
        let table = tune(&cluster, &[4, 8 << 10, 1 << 20, 32 << 20, 128 << 20]);
        assert!(
            matches!(
                table.select_for(CollectiveKind::Allreduce, 4),
                Algorithm::TreeAllreduce { .. }
            ),
            "small allreduce winner: {}",
            table.select_for(CollectiveKind::Allreduce, 4).name()
        );
        assert_eq!(
            table.select_for(CollectiveKind::Allreduce, 128 << 20),
            Algorithm::RingAllreduce,
            "large allreduce winner: {}",
            table.select_for(CollectiveKind::Allreduce, 128 << 20).name()
        );
        // single-candidate kinds still get tuned entries
        assert_eq!(
            table.select_for(CollectiveKind::ReduceScatter, 1 << 20),
            Algorithm::RingReduceScatter
        );
        assert_eq!(
            table.select_for(CollectiveKind::Allgather, 1 << 20),
            Algorithm::RingAllgather
        );
    }

    #[test]
    fn bounded_thread_fanout_is_byte_identical() {
        // --tune-threads N must not change the table, for any N
        let cluster = kesch(1, 4).unwrap();
        let sizes = [4u64, 8 << 10, 1 << 20, 32 << 20];
        let reference = persist::to_json(&tune_serial(&cluster, &sizes));
        for threads in [Some(1), Some(2), Some(3), None] {
            let t = tune_with_threads(&cluster, &sizes, threads);
            assert_eq!(
                persist::to_json(&t),
                reference,
                "tune_with_threads({threads:?}) diverged from serial"
            );
        }
    }

    #[test]
    fn fairshare_tune_is_deterministic_and_tagged() {
        // the fair-share model is a pure function of the cluster too:
        // parallel and serial sweeps must produce byte-identical tables,
        // and the table must record which model produced it
        let cluster = kesch(1, 4).unwrap();
        let sizes = [4u64, 8 << 10, 1 << 20, 32 << 20];
        let ser = tune_serial_with_model(&cluster, &sizes, LinkModel::FairShare);
        assert_eq!(ser.link_model, LinkModel::FairShare);
        for threads in [Some(2), None] {
            let par = tune_with_model(&cluster, &sizes, threads, LinkModel::FairShare);
            assert_eq!(
                persist::to_json(&par),
                persist::to_json(&ser),
                "fair-share tune_with_model({threads:?}) diverged from serial"
            );
        }
        // and the default-model paths still tag FIFO
        assert_eq!(tune(&cluster, &sizes).link_model, LinkModel::Fifo);
    }

    #[test]
    fn parallel_matches_serial_winners() {
        let cluster = kesch(1, 8).unwrap();
        let sizes = [4u64, 8 << 10, 1 << 20, 32 << 20];
        let par = tune(&cluster, &sizes);
        let ser = tune_serial(&cluster, &sizes);
        assert_eq!(
            persist::to_json(&par),
            persist::to_json(&ser),
            "parallel tune must be byte-identical to serial"
        );
    }
}
