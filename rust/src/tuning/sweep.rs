//! Offline tuning sweeps: run every candidate on the simulator, per
//! collective kind.

use crate::collectives::{self, Algorithm, CollectiveKind, CollectiveSpec};
use crate::comm::Comm;
use crate::netsim::Engine;
use crate::topology::Cluster;

use super::space;
use super::table::{TableEntry, TuningTable};

/// Result of sweeping one (collective kind, message size).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub kind: CollectiveKind,
    pub bytes: u64,
    pub winner: Algorithm,
    pub winner_ns: u64,
    /// (algorithm, latency ns) for every candidate, sorted fastest first.
    pub all: Vec<(Algorithm, u64)>,
}

/// Sweep all candidates of one kind at one size.
pub fn sweep_size_for(
    cluster: &Cluster,
    kind: CollectiveKind,
    bytes: u64,
    root: usize,
) -> SweepPoint {
    let n = cluster.n_gpus();
    let spec = CollectiveSpec::collective(kind, root, n, bytes);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::new(cluster);
    let mut all: Vec<(Algorithm, u64)> = space::candidates_for(kind, bytes)
        .into_iter()
        .map(|algo| {
            let t = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            (algo, t)
        })
        .collect();
    all.sort_by_key(|&(_, t)| t);
    let (winner, winner_ns) = all[0];
    SweepPoint {
        kind,
        bytes,
        winner,
        winner_ns,
        all,
    }
}

/// Sweep all broadcast candidates at one size (the original entry point).
pub fn sweep_size(cluster: &Cluster, bytes: u64, root: usize) -> SweepPoint {
    sweep_size_for(cluster, CollectiveKind::Broadcast, bytes, root)
}

/// Build a tuned table for every collective kind by sweeping a size grid.
pub fn tune(cluster: &Cluster, sizes: &[u64]) -> TuningTable {
    let mut table = TuningTable::new(cluster.name.clone(), cluster.n_gpus());
    for kind in CollectiveKind::ALL {
        for (i, &bytes) in sizes.iter().enumerate() {
            let point = sweep_size_for(cluster, kind, bytes, 0);
            let max_bytes = if i + 1 == sizes.len() {
                u64::MAX
            } else {
                bytes
            };
            table.push_bucket(
                kind,
                TableEntry {
                    max_bytes,
                    algorithm: point.winner,
                    won_at_ns: point.winner_ns,
                },
            );
        }
    }
    table
}

/// The default tuning size grid (powers of two, 4 B – 128 MB).
pub fn default_sizes() -> Vec<u64> {
    crate::util::bytes::pow2_sweep(4, 128 << 20)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    #[test]
    fn tuner_picks_staged_small_and_pipelined_large() {
        let cluster = kesch(1, 16);
        let table = tune(&cluster, &[4, 8 << 10, 1 << 20, 32 << 20, 128 << 20]);
        let small = table.select(4);
        assert!(
            matches!(small, Algorithm::HostStagedKnomial { .. })
                || matches!(small, Algorithm::Knomial { .. }),
            "small-message winner: {}",
            small.name()
        );
        let large = table.select(128 << 20);
        assert!(
            matches!(large, Algorithm::PipelinedChain { .. })
                || matches!(large, Algorithm::ScatterRingAllgather),
            "large-message winner: {}",
            large.name()
        );
    }

    #[test]
    fn tuned_beats_or_ties_every_fixed_algorithm() {
        let cluster = kesch(1, 8);
        for bytes in [4u64, 64 << 10, 16 << 20] {
            let point = sweep_size(&cluster, bytes, 0);
            for &(_, t) in &point.all {
                assert!(point.winner_ns <= t);
            }
        }
    }

    #[test]
    fn adjacent_same_winner_buckets_merge() {
        let cluster = kesch(1, 4);
        let table = tune(&cluster, &default_sizes());
        for w in table.entries.windows(2) {
            assert_ne!(
                w[0].algorithm, w[1].algorithm,
                "adjacent entries must differ after merging"
            );
        }
        assert_eq!(table.entries.last().unwrap().max_bytes, u64::MAX);
    }

    #[test]
    fn allreduce_table_tree_small_ring_large() {
        let cluster = kesch(1, 16);
        let table = tune(&cluster, &[4, 8 << 10, 1 << 20, 32 << 20, 128 << 20]);
        assert!(
            matches!(
                table.select_for(CollectiveKind::Allreduce, 4),
                Algorithm::TreeAllreduce { .. }
            ),
            "small allreduce winner: {}",
            table.select_for(CollectiveKind::Allreduce, 4).name()
        );
        assert_eq!(
            table.select_for(CollectiveKind::Allreduce, 128 << 20),
            Algorithm::RingAllreduce,
            "large allreduce winner: {}",
            table.select_for(CollectiveKind::Allreduce, 128 << 20).name()
        );
        // single-candidate kinds still get tuned entries
        assert_eq!(
            table.select_for(CollectiveKind::ReduceScatter, 1 << 20),
            Algorithm::RingReduceScatter
        );
        assert_eq!(
            table.select_for(CollectiveKind::Allgather, 1 << 20),
            Algorithm::RingAllgather
        );
    }
}
