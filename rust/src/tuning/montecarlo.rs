//! Monte Carlo fault trials: p50/p99 makespan per `(algorithm, size,
//! fault profile)` over N seeded realizations.
//!
//! Each *trial* realizes the [`FaultProfile`] with its own derived seed
//! ([`trial_seed`] — a pure function of the base seed and the grid/trial
//! indices, never of worker assignment), installs the schedule on a
//! fresh-per-pair [`Engine`], executes the collective, and classifies
//! the outcome through [`crate::netsim::engine::ExecResult::degraded_outcome`]:
//! trials that delivered every rank contribute their makespan to the
//! sample; aborted trials are counted but excluded (their makespans sit
//! at the unreachable sentinel and would poison every percentile).
//!
//! The `(algorithm, size)` grid fans out across `std::thread::scope`
//! workers exactly like [`super::sweep::tune_with_model`]: each worker
//! owns a cluster clone and each grid pair builds its own `Comm` +
//! `Engine`, so a pair's row is a pure function of `(cluster, pair,
//! profile, config)` and the merged output is byte-identical for any
//! `--tune-threads` setting — the determinism the acceptance gate pins.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::collectives::{self, Algorithm, CollectiveSpec};
use crate::comm::Comm;
use crate::netsim::faults::FaultProfile;
use crate::netsim::{Engine, LinkModel};
use crate::topology::Cluster;
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;

/// Monte Carlo run parameters.
#[derive(Debug, Clone, Copy)]
pub struct McConfig {
    /// Seeded realizations per `(algorithm, size)` pair.
    pub trials: usize,
    /// Base seed; trial seeds derive from it via [`trial_seed`].
    pub seed: u64,
    pub link_model: LinkModel,
    /// Worker fan-out bound (`None` = available parallelism). Output is
    /// identical for every setting.
    pub threads: Option<usize>,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig {
            trials: 20,
            seed: 0x5eed,
            link_model: LinkModel::Fifo,
            threads: None,
        }
    }
}

/// Makespan statistics over the delivered trials of one pair.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialStats {
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Half-width of the normal-approximation 95% confidence interval
    /// on the mean (1.96·σ/√n; 0 for a single sample).
    pub ci95_ns: f64,
}

/// One `(algorithm, size)` row of a Monte Carlo run.
#[derive(Debug, Clone, PartialEq)]
pub struct McRow {
    pub algorithm: String,
    pub bytes: u64,
    pub trials: usize,
    /// Trials in which every rank received its payload.
    pub delivered: usize,
    /// `None` when every trial aborted (no delivered makespans).
    pub stats: Option<TrialStats>,
}

impl McRow {
    /// Fraction of trials that delivered every rank.
    pub fn delivered_frac(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            self.delivered as f64 / self.trials as f64
        }
    }
}

/// The seed a given trial realizes its schedule with: a pure function of
/// `(base, pair index, trial index)`, whitened through SplitMix64 so
/// neighbouring trials don't share fault draws.
pub fn trial_seed(base: u64, pair: u64, trial: u64) -> u64 {
    SplitMix64::new(base ^ pair.rotate_left(32) ^ trial).next_u64()
}

/// Run one `(algorithm, size)` pair: `cfg.trials` seeded realizations on
/// a pair-local `Comm`/`Engine`. Self-contained on purpose — purity per
/// pair is what makes the parallel fan-out byte-identical to serial.
fn run_pair(
    cluster: &Cluster,
    algo: &Algorithm,
    bytes: u64,
    profile: &FaultProfile,
    cfg: &McConfig,
    pair: usize,
) -> McRow {
    let n = cluster.n_gpus();
    let spec = CollectiveSpec::new(0, n, bytes);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::with_model(cluster, cfg.link_model);
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.trials);
    let mut delivered = 0usize;
    for trial in 0..cfg.trials {
        let sched = profile.realize(cluster, trial_seed(cfg.seed, pair as u64, trial as u64));
        engine.set_faults(Some(sched));
        let cp = collectives::cached_plan(algo, &mut comm, &spec);
        let res = engine.execute(&cp.plan);
        let outcome = res.degraded_outcome(&cp.plan, n);
        if outcome.is_complete() {
            delivered += 1;
            samples.push(outcome.makespan as f64);
        }
    }
    engine.set_faults(None);
    let stats = Summary::of(&samples).map(|s| TrialStats {
        mean_ns: s.mean,
        p50_ns: s.p50,
        p99_ns: s.p99,
        ci95_ns: if s.n > 1 {
            1.96 * s.std_dev / (s.n as f64).sqrt()
        } else {
            0.0
        },
    });
    McRow {
        algorithm: algo.name(),
        bytes,
        trials: cfg.trials,
        delivered,
        stats,
    }
}

/// Monte Carlo over the `algorithms × sizes` grid. Rows come back in
/// grid order (algorithm-major) regardless of the worker fan-out.
pub fn run(
    cluster: &Cluster,
    algorithms: &[Algorithm],
    sizes: &[u64],
    profile: &FaultProfile,
    cfg: &McConfig,
) -> Vec<McRow> {
    let grid: Vec<(&Algorithm, u64)> = algorithms
        .iter()
        .flat_map(|a| sizes.iter().map(move |&b| (a, b)))
        .collect();
    let n_workers = cfg
        .threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
        .min(grid.len().max(1));
    if n_workers <= 1 {
        return grid
            .iter()
            .enumerate()
            .map(|(p, &(algo, bytes))| run_pair(cluster, algo, bytes, profile, cfg, p))
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<McRow>>> = grid.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            // cluster clone per worker: the route-intern table is
            // interior-mutable and intentionally not Sync
            let local = cluster.clone();
            let next = &next;
            let slots = &slots;
            let grid = &grid;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= grid.len() {
                    break;
                }
                let (algo, bytes) = grid[i];
                let row = run_pair(&local, algo, bytes, profile, cfg, i);
                *slots[i].lock().expect("mc slot poisoned") = Some(row);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("mc slot poisoned")
                .expect("mc row missing")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    fn profile() -> FaultProfile {
        FaultProfile::parse("degrade=1:0.5@200us,straggle=1:2,jitter=0.05").unwrap()
    }

    #[test]
    fn rows_cover_grid_in_order() {
        let cluster = kesch(1, 4);
        let algos = [Algorithm::Direct, Algorithm::Chain];
        let sizes = [4u64, 64 << 10];
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let rows = run(&cluster, &algos, &sizes, &profile(), &cfg);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].algorithm, Algorithm::Direct.name());
        assert_eq!(rows[0].bytes, 4);
        assert_eq!(rows[3].algorithm, Algorithm::Chain.name());
        assert_eq!(rows[3].bytes, 64 << 10);
        for r in &rows {
            assert_eq!(r.trials, 3);
            assert!(r.delivered <= r.trials);
        }
    }

    #[test]
    fn thread_fanout_and_reruns_are_identical() {
        let cluster = kesch(1, 4);
        let algos = [Algorithm::Chain, Algorithm::Knomial { k: 2 }];
        let sizes = [64u64 << 10];
        let cfg = McConfig {
            trials: 4,
            threads: Some(1),
            ..McConfig::default()
        };
        let reference = run(&cluster, &algos, &sizes, &profile(), &cfg);
        for threads in [Some(1), Some(2), None] {
            let cfg_t = McConfig { threads, ..cfg };
            let rows = run(&cluster, &algos, &sizes, &profile(), &cfg_t);
            assert_eq!(rows, reference, "threads={threads:?} diverged");
        }
    }

    #[test]
    fn degraded_only_profile_delivers_everything() {
        // no kill clause ⇒ every trial completes; stats must be present
        let cluster = kesch(1, 4);
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let rows = run(&cluster, &[Algorithm::Direct], &[4], &profile(), &cfg);
        assert_eq!(rows[0].delivered, 3);
        let stats = rows[0].stats.as_ref().expect("delivered trials");
        assert!(stats.p50_ns <= stats.p99_ns);
        assert!((rows[0].delivered_frac() - 1.0).abs() < 1e-12);
    }
}
