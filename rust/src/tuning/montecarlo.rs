//! Monte Carlo fault trials: p50/p99 makespan per `(algorithm, size,
//! fault profile)` over N seeded realizations.
//!
//! Each *trial* realizes the [`FaultProfile`] with its own derived seed
//! ([`trial_seed`] — a pure function of the base seed and the grid/trial
//! indices, never of worker assignment), installs the schedule on a
//! fresh-per-pair [`Engine`], executes the collective, and classifies
//! the outcome through [`crate::netsim::engine::ExecResult::degraded_outcome`]:
//! trials that delivered every rank contribute their makespan to the
//! sample; aborted trials are counted but excluded (their makespans sit
//! at the unreachable sentinel and would poison every percentile).
//!
//! The `(algorithm, size)` grid fans out across `std::thread::scope`
//! workers exactly like [`super::sweep::tune_with_model`]: each worker
//! owns a cluster clone and each grid pair builds its own `Comm` +
//! `Engine`, so a pair's row is a pure function of `(cluster, pair,
//! profile, config)` and the merged output is byte-identical for any
//! `--tune-threads` setting — the determinism the acceptance gate pins.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::collectives::{self, Algorithm, CollectiveSpec};
use crate::comm::Comm;
use crate::coordinator::recovery::{run_collective_job, RecoveryConfig, RecoveryPolicy};
use crate::error::Result;
use crate::netsim::faults::FaultProfile;
use crate::netsim::{Engine, FaultSchedule, LinkEvent, LinkModel};
use crate::topology::Cluster;
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;

/// Monte Carlo run parameters.
#[derive(Debug, Clone, Copy)]
pub struct McConfig {
    /// Seeded realizations per `(algorithm, size)` pair.
    pub trials: usize,
    /// Base seed; trial seeds derive from it via [`trial_seed`].
    pub seed: u64,
    pub link_model: LinkModel,
    /// Worker fan-out bound (`None` = available parallelism). Output is
    /// identical for every setting.
    pub threads: Option<usize>,
}

impl Default for McConfig {
    fn default() -> Self {
        McConfig {
            trials: 20,
            seed: 0x5eed,
            link_model: LinkModel::Fifo,
            threads: None,
        }
    }
}

/// Makespan statistics over the delivered trials of one pair.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialStats {
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Half-width of the normal-approximation 95% confidence interval
    /// on the mean (1.96·σ/√n; 0 for a single sample).
    pub ci95_ns: f64,
}

/// One `(algorithm, size)` row of a Monte Carlo run.
#[derive(Debug, Clone, PartialEq)]
pub struct McRow {
    pub algorithm: String,
    pub bytes: u64,
    pub trials: usize,
    /// Trials in which every rank received its payload.
    pub delivered: usize,
    /// `None` when every trial aborted (no delivered makespans).
    pub stats: Option<TrialStats>,
}

impl McRow {
    /// Fraction of trials that delivered every rank.
    pub fn delivered_frac(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            self.delivered as f64 / self.trials as f64
        }
    }

    /// Fraction of trials that aborted (lost at least one rank) — the
    /// complement of [`Self::delivered_frac`], rendered as its own
    /// report column so lossy profiles are visible at a glance.
    pub fn aborted_frac(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            (self.trials - self.delivered) as f64 / self.trials as f64
        }
    }
}

/// The seed a given trial realizes its schedule with: a pure function of
/// `(base, pair index, trial index)`, whitened through SplitMix64 so
/// neighbouring trials don't share fault draws.
pub fn trial_seed(base: u64, pair: u64, trial: u64) -> u64 {
    SplitMix64::new(base ^ pair.rotate_left(32) ^ trial).next_u64()
}

/// Run one `(algorithm, size)` pair: `cfg.trials` seeded realizations on
/// a pair-local `Comm`/`Engine`. Self-contained on purpose — purity per
/// pair is what makes the parallel fan-out byte-identical to serial.
fn run_pair(
    cluster: &Cluster,
    algo: &Algorithm,
    bytes: u64,
    profile: &FaultProfile,
    cfg: &McConfig,
    pair: usize,
) -> McRow {
    let n = cluster.n_gpus();
    let spec = CollectiveSpec::new(0, n, bytes);
    let mut comm = Comm::new(cluster);
    let mut engine = Engine::with_model(cluster, cfg.link_model);
    let mut samples: Vec<f64> = Vec::with_capacity(cfg.trials);
    let mut delivered = 0usize;
    for trial in 0..cfg.trials {
        let sched = profile
            .realize(cluster, trial_seed(cfg.seed, pair as u64, trial as u64))
            .expect("profile validated against this cluster by run()");
        engine.set_faults(Some(sched));
        let cp = collectives::cached_plan(algo, &mut comm, &spec);
        let res = engine.execute(&cp.plan);
        let outcome = res.degraded_outcome(&cp.plan, n);
        if outcome.is_complete() {
            delivered += 1;
            samples.push(outcome.makespan as f64);
        }
    }
    engine.set_faults(None);
    let stats = Summary::of(&samples).map(|s| TrialStats {
        mean_ns: s.mean,
        p50_ns: s.p50,
        p99_ns: s.p99,
        ci95_ns: if s.n > 1 {
            1.96 * s.std_dev / (s.n as f64).sqrt()
        } else {
            0.0
        },
    });
    McRow {
        algorithm: algo.name(),
        bytes,
        trials: cfg.trials,
        delivered,
        stats,
    }
}

/// Monte Carlo over the `algorithms × sizes` grid. Rows come back in
/// grid order (algorithm-major) regardless of the worker fan-out.
/// Errors up front when the profile names a link/rank index the cluster
/// doesn't have (validity is seed-independent, so one probe realization
/// covers every trial).
pub fn run(
    cluster: &Cluster,
    algorithms: &[Algorithm],
    sizes: &[u64],
    profile: &FaultProfile,
    cfg: &McConfig,
) -> Result<Vec<McRow>> {
    profile.realize(cluster, cfg.seed)?;
    let grid: Vec<(&Algorithm, u64)> = algorithms
        .iter()
        .flat_map(|a| sizes.iter().map(move |&b| (a, b)))
        .collect();
    let n_workers = cfg
        .threads
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
        .min(grid.len().max(1));
    if n_workers <= 1 {
        return Ok(grid
            .iter()
            .enumerate()
            .map(|(p, &(algo, bytes))| run_pair(cluster, algo, bytes, profile, cfg, p))
            .collect());
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<McRow>>> = grid.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            // cluster clone per worker: the route-intern table is
            // interior-mutable and intentionally not Sync
            let local = cluster.clone();
            let next = &next;
            let slots = &slots;
            let grid = &grid;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= grid.len() {
                    break;
                }
                let (algo, bytes) = grid[i];
                let row = run_pair(&local, algo, bytes, profile, cfg, i);
                *slots[i].lock().expect("mc slot poisoned") = Some(row);
            });
        }
    });
    Ok(slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("mc slot poisoned")
                .expect("mc row missing")
        })
        .collect())
}

/// One recovery-policy row of a [`recovery_run`]: `trials` N-iteration
/// jobs driven through per-trial fault realizations under the policy.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryRow {
    /// [`RecoveryPolicy::name`] of the policy the row swept.
    pub policy: String,
    pub trials: usize,
    /// Jobs that completed all N iterations.
    pub completed: usize,
    /// Recovery attempts summed over all trials.
    pub recoveries: u64,
    /// Time-to-completion statistics over the *completed* jobs' total
    /// virtual time (`None` when every job aborted).
    pub stats: Option<TrialStats>,
}

impl RecoveryRow {
    /// Fraction of jobs that gave up before iteration N.
    pub fn aborted_frac(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            (self.trials - self.completed) as f64 / self.trials as f64
        }
    }
}

/// Sweep recovery policies over a repeated-collective job: for each
/// policy, `cfg.trials` seeded profile realizations each drive an
/// `iterations`-long job through [`run_collective_job`], yielding
/// p50/p99 time-to-completion and the aborted fraction per policy.
/// Trials reuse [`trial_seed`] with the *policy index* as the pair
/// index, so every policy faces an identical fault draw sequence — rows
/// differ only by how the policy copes. Serial and deterministic.
#[allow(clippy::too_many_arguments)]
pub fn recovery_run(
    cluster: &Cluster,
    algorithm: &Algorithm,
    bytes: u64,
    iterations: usize,
    policies: &[RecoveryConfig],
    profile: &FaultProfile,
    cfg: &McConfig,
) -> Result<Vec<RecoveryRow>> {
    profile.realize(cluster, cfg.seed)?;
    let mut rows = Vec::with_capacity(policies.len());
    for rc in policies {
        let mut samples: Vec<f64> = Vec::with_capacity(cfg.trials);
        let mut completed = 0usize;
        let mut recoveries = 0u64;
        for trial in 0..cfg.trials {
            // seed by trial only (not policy): identical draws per policy
            let sched = profile
                .realize(cluster, trial_seed(cfg.seed, 0, trial as u64))
                .expect("validated above");
            let job = run_collective_job(
                cluster,
                algorithm,
                bytes,
                iterations,
                &sched,
                cfg.link_model,
                rc,
            );
            recoveries += u64::from(job.recoveries);
            if !job.aborted {
                completed += 1;
                samples.push(job.total_ns as f64);
            }
        }
        rows.push(RecoveryRow {
            policy: rc.policy.name().to_string(),
            trials: cfg.trials,
            completed,
            recoveries,
            stats: summarize(&samples),
        });
    }
    Ok(rows)
}

/// One MTBF point of the shrink-vs-restart crossover table.
#[derive(Debug, Clone, PartialEq)]
pub struct MtbfRow {
    pub mtbf_ns: u64,
    /// p50 time-to-completion per compared policy, `None` when every
    /// trial under that policy aborted. Order matches the `policies`
    /// argument of [`mtbf_crossover`].
    pub p50_ns: Vec<Option<f64>>,
    /// `policy.name()` of the fastest completing policy at this MTBF
    /// (`"-"` when nothing completed).
    pub winner: String,
}

/// The crossover table: at each MTBF, links die with exponential
/// inter-arrival times (deterministic per `(cfg.seed, mtbf, trial)`)
/// and each policy runs the same N-iteration job through the identical
/// kill sequence; the row records each policy's p50 time-to-completion
/// and which one wins. Sweeping MTBF from harsh to benign locates where
/// checkpoint/restart stops paying for itself against elastic shrink.
pub fn mtbf_crossover(
    cluster: &Cluster,
    algorithm: &Algorithm,
    bytes: u64,
    iterations: usize,
    mtbfs_ns: &[u64],
    policies: &[RecoveryConfig],
    cfg: &McConfig,
) -> Vec<MtbfRow> {
    // horizon: generously past the healthy job so late kills can strike
    // replayed iterations too
    let healthy = run_collective_job(
        cluster,
        algorithm,
        bytes,
        1,
        &FaultSchedule::default(),
        cfg.link_model,
        &RecoveryConfig::default(),
    )
    .total_ns;
    let horizon = healthy.saturating_mul(iterations as u64).saturating_mul(4);
    let mut rows = Vec::with_capacity(mtbfs_ns.len());
    for (m, &mtbf_ns) in mtbfs_ns.iter().enumerate() {
        let mut per_policy: Vec<Vec<f64>> = vec![Vec::new(); policies.len()];
        for trial in 0..cfg.trials {
            let seed = trial_seed(cfg.seed, m as u64, trial as u64);
            let sched = exponential_kills(cluster, mtbf_ns, horizon, seed);
            for (p, rc) in policies.iter().enumerate() {
                let job = run_collective_job(
                    cluster,
                    algorithm,
                    bytes,
                    iterations,
                    &sched,
                    cfg.link_model,
                    rc,
                );
                if !job.aborted {
                    per_policy[p].push(job.total_ns as f64);
                }
            }
        }
        let p50_ns: Vec<Option<f64>> = per_policy
            .iter()
            .map(|s| summarize(s).map(|st| st.p50_ns))
            .collect();
        let winner = p50_ns
            .iter()
            .enumerate()
            .filter_map(|(p, v)| v.map(|ns| (p, ns)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(p, _)| policies[p].policy.name().to_string())
            .unwrap_or_else(|| "-".to_string());
        rows.push(MtbfRow {
            mtbf_ns,
            p50_ns,
            winner,
        });
    }
    rows
}

/// A kill-only fault schedule with exponential inter-arrival times of
/// mean `mtbf_ns`, each kill striking a random live fabric link. Pure in
/// `(cluster, mtbf_ns, horizon_ns, seed)`.
pub fn exponential_kills(
    cluster: &Cluster,
    mtbf_ns: u64,
    horizon_ns: u64,
    seed: u64,
) -> FaultSchedule {
    let live: Vec<_> = cluster
        .links()
        .iter()
        .filter(|l| l.bandwidth > 0.0)
        .map(|l| l.id)
        .collect();
    let mut sched = FaultSchedule::default();
    if live.is_empty() || mtbf_ns == 0 {
        return sched;
    }
    let mut rng = SplitMix64::new(seed);
    let mut t = 0u64;
    loop {
        // inverse-CDF exponential draw on a (0,1] uniform from the top
        // 53 bits, never exactly 0
        let u = ((rng.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64);
        let dt = (-u.ln() * mtbf_ns as f64).round() as u64;
        t = t.saturating_add(dt.max(1));
        if t > horizon_ns {
            break;
        }
        let link = live[(rng.next_u64() % live.len() as u64) as usize];
        sched.link_events.push(LinkEvent {
            at_ns: t,
            link,
            bw_factor: 0.0,
        });
    }
    sched.normalize();
    sched
}

fn summarize(samples: &[f64]) -> Option<TrialStats> {
    Summary::of(samples).map(|s| TrialStats {
        mean_ns: s.mean,
        p50_ns: s.p50,
        p99_ns: s.p99,
        ci95_ns: if s.n > 1 {
            1.96 * s.std_dev / (s.n as f64).sqrt()
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    fn profile() -> FaultProfile {
        FaultProfile::parse("degrade=1:0.5@200us,straggle=1:2,jitter=0.05").unwrap()
    }

    #[test]
    fn rows_cover_grid_in_order() {
        let cluster = kesch(1, 4).unwrap();
        let algos = [Algorithm::Direct, Algorithm::Chain];
        let sizes = [4u64, 64 << 10];
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let rows = run(&cluster, &algos, &sizes, &profile(), &cfg).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].algorithm, Algorithm::Direct.name());
        assert_eq!(rows[0].bytes, 4);
        assert_eq!(rows[3].algorithm, Algorithm::Chain.name());
        assert_eq!(rows[3].bytes, 64 << 10);
        for r in &rows {
            assert_eq!(r.trials, 3);
            assert!(r.delivered <= r.trials);
        }
    }

    #[test]
    fn thread_fanout_and_reruns_are_identical() {
        let cluster = kesch(1, 4).unwrap();
        let algos = [Algorithm::Chain, Algorithm::Knomial { k: 2 }];
        let sizes = [64u64 << 10];
        let cfg = McConfig {
            trials: 4,
            threads: Some(1),
            ..McConfig::default()
        };
        let reference = run(&cluster, &algos, &sizes, &profile(), &cfg).unwrap();
        for threads in [Some(1), Some(2), None] {
            let cfg_t = McConfig { threads, ..cfg };
            let rows = run(&cluster, &algos, &sizes, &profile(), &cfg_t).unwrap();
            assert_eq!(rows, reference, "threads={threads:?} diverged");
            // the aborted fraction is part of the deterministic contract
            // (and the two fractions partition the trials)
            for (r, rr) in rows.iter().zip(reference.iter()) {
                assert_eq!(r.aborted_frac(), rr.aborted_frac());
                assert!((r.aborted_frac() + r.delivered_frac() - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn degraded_only_profile_delivers_everything() {
        // no kill clause ⇒ every trial completes; stats must be present
        let cluster = kesch(1, 4).unwrap();
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let rows = run(&cluster, &[Algorithm::Direct], &[4], &profile(), &cfg).unwrap();
        assert_eq!(rows[0].delivered, 3);
        let stats = rows[0].stats.as_ref().expect("delivered trials");
        assert!(stats.p50_ns <= stats.p99_ns);
        assert!((rows[0].delivered_frac() - 1.0).abs() < 1e-12);
        assert_eq!(rows[0].aborted_frac(), 0.0);
    }

    #[test]
    fn out_of_range_profile_errors_up_front() {
        let cluster = kesch(1, 4).unwrap(); // 4 ranks — rank 9 doesn't exist
        let bad = FaultProfile::parse("straggle=9:2").unwrap();
        let cfg = McConfig {
            trials: 2,
            threads: Some(1),
            ..McConfig::default()
        };
        let err = run(&cluster, &[Algorithm::Direct], &[4], &bad, &cfg).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = recovery_run(
            &cluster,
            &Algorithm::Direct,
            4,
            2,
            &[RecoveryConfig::default()],
            &bad,
            &cfg,
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn recovery_rows_are_deterministic_and_zero_fault_policies_tie() {
        let cluster = kesch(1, 4).unwrap();
        let none = FaultProfile::parse("").unwrap();
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let policies = [
            RecoveryConfig::default(),
            RecoveryConfig::with_policy(RecoveryPolicy::Replan),
            RecoveryConfig::with_policy(RecoveryPolicy::Shrink),
            RecoveryConfig::with_policy(RecoveryPolicy::Restart {
                restore_ns: 1 << 20,
            }),
        ];
        let rows =
            recovery_run(&cluster, &Algorithm::Chain, 64 << 10, 4, &policies, &none, &cfg)
                .unwrap();
        let again =
            recovery_run(&cluster, &Algorithm::Chain, 64 << 10, 4, &policies, &none, &cfg)
                .unwrap();
        assert_eq!(rows, again, "recovery sweep must be deterministic");
        assert_eq!(rows.len(), 4);
        // nothing fails ⇒ every policy completes every trial in the same
        // virtual time and recovery never triggers
        let p50 = rows[0].stats.as_ref().unwrap().p50_ns;
        for r in &rows {
            assert_eq!(r.completed, 3, "{}", r.policy);
            assert_eq!(r.recoveries, 0, "{}", r.policy);
            assert_eq!(r.aborted_frac(), 0.0, "{}", r.policy);
            assert_eq!(r.stats.as_ref().unwrap().p50_ns, p50, "{}", r.policy);
        }
    }

    #[test]
    fn mtbf_crossover_rows_cover_grid_and_harsh_mtbf_aborts_more() {
        let cluster = kesch(1, 4).unwrap();
        let cfg = McConfig {
            trials: 3,
            threads: Some(1),
            ..McConfig::default()
        };
        let policies = [
            RecoveryConfig::with_policy(RecoveryPolicy::Shrink),
            RecoveryConfig::with_policy(RecoveryPolicy::Restart {
                restore_ns: 1 << 22,
            }),
        ];
        let mtbfs = [50_000u64, 1_000_000_000_000];
        let rows = mtbf_crossover(
            &cluster,
            &Algorithm::Chain,
            64 << 10,
            3,
            &mtbfs,
            &policies,
            &cfg,
        );
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.p50_ns.len(), 2);
        }
        // an MTBF far beyond the job horizon means no kills at all:
        // every policy completes and the winner is decided on clean time
        let benign = &rows[1];
        assert!(benign.p50_ns.iter().all(|v| v.is_some()));
        assert_ne!(benign.winner, "-");
    }

    #[test]
    fn exponential_kills_is_pure_and_scales_with_mtbf() {
        let cluster = kesch(1, 4).unwrap();
        let a = exponential_kills(&cluster, 10_000, 1_000_000, 42);
        let b = exponential_kills(&cluster, 10_000, 1_000_000, 42);
        assert_eq!(a.link_events, b.link_events);
        let sparse = exponential_kills(&cluster, 1_000_000, 1_000_000, 42);
        assert!(
            a.link_events.len() > sparse.link_events.len(),
            "shorter MTBF must draw more kills ({} vs {})",
            a.link_events.len(),
            sparse.link_events.len()
        );
        for e in &a.link_events {
            assert_eq!(e.bw_factor, 0.0);
            assert!(e.at_ns >= 1 && e.at_ns <= 1_000_000);
        }
    }
}
