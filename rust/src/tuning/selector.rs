//! Runtime selection: "MV2-GDR-Opt", generalized per collective.
//!
//! A [`Selector`] owns a tuned table (built offline by [`super::sweep`]
//! or loaded from an artifact) and answers "which algorithm for this
//! (collective, message)?" on the hot path — the role MVAPICH2-GDR's
//! enhanced tuning framework plays at `MPI_Bcast` call time, extended to
//! the reduction collectives modern training workloads issue.

use crate::collectives::{self, Algorithm, CollectiveKind, CollectivePlan, CollectiveSpec};
use crate::comm::Comm;
use crate::netsim::{Engine, LinkModel};
use crate::topology::Cluster;

use super::sweep;
use super::table::TuningTable;

/// The tuned collective dispatcher.
#[derive(Debug, Clone)]
pub struct Selector {
    table: TuningTable,
}

impl Selector {
    /// Tune for a cluster on the default size grid (all collective
    /// kinds).
    pub fn tuned(cluster: &Cluster) -> Selector {
        Selector {
            table: sweep::tune(cluster, &sweep::default_sizes()),
        }
    }

    /// [`Self::tuned`] with a bound on the sweep's worker fan-out
    /// (`None` = available parallelism) — the `--tune-threads` CLI knob.
    pub fn tuned_with_threads(cluster: &Cluster, threads: Option<usize>) -> Selector {
        Selector {
            table: sweep::tune_with_threads(cluster, &sweep::default_sizes(), threads),
        }
    }

    /// Tune under an explicit link-contention model: the sweep simulates
    /// every candidate on an engine running `model` and the selector's
    /// table records it ([`Self::link_model`]) — dispatch it against an
    /// engine running the same model.
    pub fn tuned_with_model(
        cluster: &Cluster,
        threads: Option<usize>,
        model: LinkModel,
    ) -> Selector {
        Selector {
            table: sweep::tune_with_model(cluster, &sweep::default_sizes(), threads, model),
        }
    }

    /// Wrap an existing (e.g. persisted) table.
    pub fn from_table(table: TuningTable) -> Selector {
        Selector { table }
    }

    pub fn table(&self) -> &TuningTable {
        &self.table
    }

    /// The link-contention model this selector's table was tuned under.
    pub fn link_model(&self) -> LinkModel {
        self.table.link_model
    }

    /// The broadcast algorithm MV2-GDR-Opt uses for this message size.
    pub fn algorithm(&self, bytes: u64) -> Algorithm {
        self.table.select(bytes)
    }

    /// The tuned algorithm for any (collective kind, message size).
    pub fn algorithm_for(&self, kind: CollectiveKind, bytes: u64) -> Algorithm {
        self.table.select_for(kind, bytes)
    }

    /// Build the tuned plan for the spec's collective kind.
    pub fn plan(&self, comm: &mut Comm, spec: &CollectiveSpec) -> CollectivePlan {
        collectives::plan(&self.algorithm_for(spec.kind, spec.bytes), comm, spec)
    }

    /// The tuned plan through the comm's template cache: across a
    /// schedule's message sizes the picked algorithm's DAG is built once
    /// and rescaled (DESIGN.md §Plan templates).
    pub fn cached_plan<'a, 'c>(
        &self,
        comm: &'a mut Comm<'c>,
        spec: &CollectiveSpec,
    ) -> &'a CollectivePlan {
        collectives::cached_plan(&self.algorithm_for(spec.kind, spec.bytes), comm, spec)
    }

    /// Simulated tuned-collective latency, ns.
    pub fn latency_ns(&self, comm: &mut Comm, engine: &mut Engine, spec: &CollectiveSpec) -> u64 {
        collectives::latency_ns(
            &self.algorithm_for(spec.kind, spec.bytes),
            comm,
            engine,
            spec,
        )
    }

    /// Re-tune for a *mutated* topology (the recovery layer's re-plan
    /// path), re-sweeping only the affected size classes: each bucket's
    /// recorded winner is re-measured at the bucket boundary on the new
    /// topology, and buckets whose winning latency is bit-unchanged keep
    /// their entry verbatim — a size class a dead link never touched
    /// costs one probe, not a full candidate sweep. Buckets whose winner
    /// slowed down (re-routed transfers) or whose rank count changed
    /// re-run the full candidate selection at the boundary size.
    pub fn retuned_for(&self, cluster: &Cluster) -> Selector {
        // the open-ended top bucket's `won_at_ns` was recorded at the
        // sweep grid's largest size; probe it there
        let top_probe = sweep::default_sizes().last().copied().unwrap_or(128 << 20);
        let n = cluster.n_gpus();
        let mut comm = Comm::new(cluster);
        let mut engine = Engine::with_model(cluster, self.table.link_model);
        let mut out = TuningTable::new(self.table.cluster.clone(), n)
            .with_link_model(self.table.link_model);
        for kind in CollectiveKind::ALL {
            for e in self.table.entries_for(kind) {
                let probe = if e.max_bytes == u64::MAX {
                    top_probe
                } else {
                    e.max_bytes
                };
                let spec = CollectiveSpec::collective(kind, 0, n, probe);
                let now_ns =
                    collectives::latency_ns(&e.algorithm, &mut comm, &mut engine, &spec);
                if n == self.table.n_ranks && now_ns == e.won_at_ns {
                    out.push_bucket(kind, e.clone());
                    continue;
                }
                let point = sweep::sweep_size_with(&mut comm, &mut engine, kind, probe, 0);
                out.push_bucket(
                    kind,
                    super::table::TableEntry {
                        max_bytes: e.max_bytes,
                        algorithm: point.winner,
                        won_at_ns: point.winner_ns,
                    },
                );
            }
        }
        Selector { table: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::BcastSpec;
    use crate::topology::presets::kesch;

    #[test]
    fn tuned_selector_is_consistent_with_table() {
        let cluster = kesch(1, 4).unwrap();
        let sel = Selector::tuned(&cluster);
        for bytes in [4u64, 8 << 10, 2 << 20, 128 << 20] {
            assert_eq!(sel.algorithm(bytes), sel.table().select(bytes));
        }
    }

    #[test]
    fn fairshare_tuned_selector_never_loses_on_a_fairshare_engine() {
        // the tuned pick must win (or tie) against any fixed candidate
        // *under the model it was tuned for*
        let cluster = kesch(1, 8).unwrap();
        let sel = Selector::tuned_with_model(&cluster, None, LinkModel::FairShare);
        assert_eq!(sel.link_model(), LinkModel::FairShare);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::with_model(&cluster, LinkModel::FairShare);
        for bytes in [4u64, 64 << 10, 8 << 20] {
            let spec = BcastSpec::new(0, 8, bytes);
            let tuned = sel.latency_ns(&mut comm, &mut engine, &spec);
            let binomial = collectives::latency_ns(
                &Algorithm::Knomial { k: 2 },
                &mut comm,
                &mut engine,
                &spec,
            );
            assert!(
                tuned <= binomial,
                "fair-share tuned {tuned} vs binomial {binomial} at {bytes}B"
            );
        }
    }

    #[test]
    fn tuned_never_loses_to_binomial() {
        let cluster = kesch(1, 8).unwrap();
        let sel = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        for bytes in [4u64, 64 << 10, 8 << 20, 64 << 20] {
            let spec = BcastSpec::new(0, 8, bytes);
            let tuned = sel.latency_ns(&mut comm, &mut engine, &spec);
            let binomial = collectives::latency_ns(
                &Algorithm::Knomial { k: 2 },
                &mut comm,
                &mut engine,
                &spec,
            );
            assert!(
                tuned <= binomial,
                "tuned {tuned} vs binomial {binomial} at {bytes}B"
            );
        }
    }

    #[test]
    fn tuned_allreduce_never_loses_to_fixed_candidates() {
        let cluster = kesch(1, 8).unwrap();
        let sel = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        for bytes in [4u64, 64 << 10, 8 << 20, 64 << 20] {
            let spec = CollectiveSpec::allreduce(8, bytes);
            let tuned = sel.latency_ns(&mut comm, &mut engine, &spec);
            for algo in [
                Algorithm::RingAllreduce,
                Algorithm::TreeAllreduce { k: 2 },
                Algorithm::TreeAllreduce { k: 4 },
            ] {
                let fixed = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
                assert!(
                    tuned <= fixed,
                    "tuned {tuned} lost to {} {fixed} at {bytes}B",
                    algo.name()
                );
            }
        }
    }
}
