//! Benchmarking substrates.
//!
//! * [`harness`] — a criterion-style statistical runner (criterion is not
//!   in the offline crate universe): warmup, adaptive iteration counts,
//!   mean/σ/percentiles, throughput, and plain-text + JSON reports. All
//!   `cargo bench` targets in `rust/benches/` use it with
//!   `harness = false`.
//! * [`osu`] — the osu_bcast-equivalent micro-benchmark driving the
//!   simulator with the same loop structure the paper's Figs. 1–2 use.
//! * [`report`] — figure/series renderers and the headline-ratio
//!   extractor (the 14×/16.6×/7 % numbers).

pub mod harness;
pub mod osu;
pub mod report;

pub use harness::{Bencher, BenchResult};
pub use osu::{osu_bcast, OsuResult};
