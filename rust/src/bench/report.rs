//! Figure renderers and headline-ratio extraction.
//!
//! Each paper figure is a set of series over a size axis; these helpers
//! print the same rows the paper plots and compute the "up to N×"
//! improvement numbers the abstract quotes.

use crate::util::bytes::{format_size, format_us};
use crate::util::tablefmt::Table;

/// One plotted series: (label, per-size latencies µs).
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub latencies_us: Vec<f64>,
}

/// A rendered figure: shared size axis + series.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub sizes: Vec<u64>,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: impl Into<String>, sizes: Vec<u64>) -> Figure {
        Figure {
            title: title.into(),
            sizes,
            series: Vec::new(),
        }
    }

    pub fn push_series(&mut self, label: impl Into<String>, latencies_us: Vec<f64>) {
        assert_eq!(latencies_us.len(), self.sizes.len(), "axis mismatch");
        self.series.push(Series {
            label: label.into(),
            latencies_us,
        });
    }

    /// Render as a table (size column + one column per series + ratio of
    /// first/last series when there are exactly two).
    pub fn render(&self) -> String {
        let mut header: Vec<String> = vec!["size".into()];
        for s in &self.series {
            header.push(format!("{} (us)", s.label));
        }
        let two = self.series.len() == 2;
        if two {
            header.push("ratio".into());
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&header_refs).with_title(self.title.clone());
        for (i, &size) in self.sizes.iter().enumerate() {
            let mut row = vec![format_size(size)];
            for s in &self.series {
                row.push(format_us(s.latencies_us[i] * 1000.0));
            }
            if two {
                let a = self.series[0].latencies_us[i];
                let b = self.series[1].latencies_us[i];
                row.push(if b > 0.0 {
                    format!("{:.1}x", a / b)
                } else {
                    "-".into()
                });
            }
            t.row(row);
        }
        t.render()
    }

    /// Max ratio series[0]/series[1] over sizes ≤ `limit` — the paper's
    /// "up to N× improvement for small/medium messages" extraction.
    pub fn max_ratio_below(&self, limit: u64) -> Option<(u64, f64)> {
        if self.series.len() != 2 {
            return None;
        }
        let mut best: Option<(u64, f64)> = None;
        for (i, &size) in self.sizes.iter().enumerate() {
            if size > limit {
                continue;
            }
            let a = self.series[0].latencies_us[i];
            let b = self.series[1].latencies_us[i];
            if b <= 0.0 {
                continue;
            }
            let r = a / b;
            if best.map(|(_, br)| r > br).unwrap_or(true) {
                best = Some((size, r));
            }
        }
        best
    }

    /// Ratio at the largest size — the "comparable at large messages"
    /// check.
    pub fn ratio_at_max(&self) -> Option<f64> {
        if self.series.len() != 2 {
            return None;
        }
        let i = self.sizes.len() - 1;
        let b = self.series[1].latencies_us[i];
        (b > 0.0).then(|| self.series[0].latencies_us[i] / b)
    }

    /// Serialise for target/reports/.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("title", self.title.as_str());
        j.set("sizes", self.sizes.clone());
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|s| {
                let mut sj = Json::obj();
                sj.set("label", s.label.as_str());
                sj.set("latencies_us", s.latencies_us.clone());
                sj
            })
            .collect();
        j.set("series", Json::Arr(series));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Figure {
        let mut f = Figure::new("test", vec![4, 8192, 1 << 20]);
        f.push_series("NCCL", vec![28.0, 30.0, 150.0]);
        f.push_series("MV2-GDR-Opt", vec![2.0, 3.0, 140.0]);
        f
    }

    #[test]
    fn ratio_extraction() {
        let f = fig();
        let (size, ratio) = f.max_ratio_below(8192).unwrap();
        assert_eq!(size, 4);
        assert!((ratio - 14.0).abs() < 0.01);
        assert!((f.ratio_at_max().unwrap() - 150.0 / 140.0).abs() < 1e-9);
    }

    #[test]
    fn render_includes_ratio_column() {
        let s = fig().render();
        assert!(s.contains("ratio"));
        assert!(s.contains("14.0x"));
    }

    #[test]
    #[should_panic(expected = "axis mismatch")]
    fn series_length_checked() {
        let mut f = Figure::new("x", vec![4, 8]);
        f.push_series("bad", vec![1.0]);
    }

    #[test]
    fn json_has_series() {
        let j = fig().to_json();
        assert!(j.get("series").is_some());
    }
}
