//! A criterion-style wall-clock benchmark harness.
//!
//! Measures closures by adaptively choosing an iteration count to hit a
//! target measurement time, then reports summary statistics across
//! samples. Used by every `[[bench]]` target (with `harness = false`).

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// One benchmark's outcome.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time statistics, ns.
    pub per_iter: Summary,
    /// Iterations per sample used.
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<48} {:>12.2} ns/iter (±{:>8.2}, p99 {:>12.2}, {} samples × {} iters)",
            self.name,
            self.per_iter.mean,
            self.per_iter.std_dev,
            self.per_iter.p99,
            self.samples,
            self.iters_per_sample
        )
    }
}

/// A single-measurement row in the standard report shape (`iters` and
/// `samples` of 1) — for one-shot wall times and derived estimates that
/// ride along harness rows via [`Bencher::write_report_with`]. Keeping
/// the schema in one place means report-consuming gates (CI) track a
/// single definition.
pub fn one_shot_row(name: &str, ns: f64) -> Json {
    let mut j = Json::obj();
    j.set("name", name)
        .set("mean_ns", ns)
        .set("std_dev_ns", 0.0)
        .set("p50_ns", ns)
        .set("p99_ns", ns)
        .set("iters", 1u64)
        .set("samples", 1u64);
    j
}

/// Which link-contention models a bench should report. `LINK_MODEL=fifo`
/// or `LINK_MODEL=fairshare` restricts a local run to one; unset (or
/// `both`) reports the two models side by side — the default, so the CI
/// report gates can fail when either model's rows are missing.
pub fn link_models_from_env() -> Vec<crate::netsim::LinkModel> {
    use crate::netsim::LinkModel;
    match std::env::var("LINK_MODEL").ok().as_deref() {
        None | Some("both") | Some("") => LinkModel::ALL.to_vec(),
        Some(s) => match LinkModel::parse(s) {
            Some(m) => vec![m],
            None => panic!("unknown LINK_MODEL '{s}' (expected fifo|fairshare|both)"),
        },
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub target_sample_time: Duration,
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            target_sample_time: Duration::from_millis(60),
            samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher::default()
    }

    /// Quick mode for CI / tests.
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(10),
            target_sample_time: Duration::from_millis(5),
            samples: 4,
            results: Vec::new(),
        }
    }

    /// Benchmark a closure. The closure's return value is black-boxed to
    /// keep the optimiser honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warmup + calibration
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter_est = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters = ((self.target_sample_time.as_nanos() as f64 / per_iter_est).ceil()
            as u64)
            .max(1);

        let mut samples: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            per_iter: Summary::of(&samples)
                .expect("bench samples are non-empty by construction"),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump all results as JSON into `target/reports/<name>.json`.
    pub fn write_report(&self, report_name: &str) -> std::io::Result<std::path::PathBuf> {
        self.write_report_with(report_name, Vec::new())
    }

    /// Like [`Self::write_report`], appending caller-built rows in the
    /// same shape (e.g. `sweep_perf`'s one-shot wall-time measurements).
    pub fn write_report_with(
        &self,
        report_name: &str,
        extra_rows: Vec<Json>,
    ) -> std::io::Result<std::path::PathBuf> {
        let mut arr = Vec::new();
        for r in &self.results {
            let mut j = Json::obj();
            j.set("name", r.name.as_str())
                .set("mean_ns", r.per_iter.mean)
                .set("std_dev_ns", r.per_iter.std_dev)
                .set("p50_ns", r.per_iter.p50)
                .set("p99_ns", r.per_iter.p99)
                .set("iters", r.iters_per_sample)
                .set("samples", r.samples);
            arr.push(j);
        }
        arr.extend(extra_rows);
        let dir = std::path::Path::new("target/reports");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{report_name}.json"));
        std::fs::write(&path, Json::Arr(arr).to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut x = 0u64;
            for i in 0..100 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(r.per_iter.mean > 0.0);
        assert_eq!(r.samples, 4);
    }

    #[test]
    fn results_accumulate() {
        let mut b = Bencher::quick();
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        assert_eq!(b.results().len(), 2);
        assert!(b.results()[0].line().contains("ns/iter"));
    }
}
