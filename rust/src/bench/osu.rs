//! The osu_bcast-equivalent micro-benchmark.
//!
//! Mirrors the OSU micro-benchmark methodology the paper uses for
//! Figs. 1–2: for each message size, run warmup + timed iterations of the
//! broadcast and report the latency as the *maximum across ranks*
//! (averaged over iterations). Our clock is the simulator's virtual
//! clock; the simulator is deterministic, so "iterations" matter only
//! when the caller injects variation (e.g. rotating roots).

use crate::collectives::BcastSpec;
use crate::netsim::Engine;

/// Per-size result.
#[derive(Debug, Clone)]
pub struct OsuResult {
    pub bytes: u64,
    /// Mean over iterations of the max-across-ranks latency, µs.
    pub latency_us: f64,
    /// Min/max over iterations, µs.
    pub min_us: f64,
    pub max_us: f64,
}

/// Run the osu_bcast loop for one size with a caller-supplied plan
/// builder (called once per iteration — roots may rotate).
pub fn osu_bcast(
    engine: &mut Engine,
    sizes: &[u64],
    iterations: usize,
    warmup: usize,
    mut build: impl FnMut(u64, usize) -> crate::collectives::BcastPlan,
) -> Vec<OsuResult> {
    let mut out = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        for i in 0..warmup {
            let bp = build(bytes, i);
            let _ = engine.execute(&bp.plan);
        }
        let mut lat_sum = 0.0f64;
        let mut lat_min = f64::INFINITY;
        let mut lat_max = 0.0f64;
        for i in 0..iterations {
            let bp = build(bytes, warmup + i);
            let result = engine.execute(&bp.plan);
            let us = result.makespan as f64 / 1000.0;
            lat_sum += us;
            lat_min = lat_min.min(us);
            lat_max = lat_max.max(us);
        }
        out.push(OsuResult {
            bytes,
            latency_us: lat_sum / iterations as f64,
            min_us: lat_min,
            max_us: lat_max,
        });
    }
    out
}

/// Convenience: default root-0 spec builder.
pub fn spec_for(n_ranks: usize, bytes: u64) -> BcastSpec {
    BcastSpec::new(0, n_ranks, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{self, Algorithm};
    use crate::comm::Comm;
    use crate::topology::presets::kesch;

    #[test]
    fn sweep_produces_monotone_latencies() {
        let c = kesch(1, 4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let sizes = [4u64, 4 << 10, 4 << 20];
        let results = osu_bcast(&mut engine, &sizes, 3, 1, |bytes, _| {
            collectives::plan(
                &Algorithm::Knomial { k: 2 },
                &mut comm,
                &spec_for(4, bytes),
            )
        });
        assert_eq!(results.len(), 3);
        assert!(results[0].latency_us < results[2].latency_us);
        // deterministic: min == max == mean
        for r in &results {
            assert_eq!(r.min_us, r.max_us);
        }
    }
}
