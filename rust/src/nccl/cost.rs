//! NCCL cost constants.
//!
//! Calibrated against published NCCL 1.x microbenchmarks (see DESIGN.md
//! §4): small-message `ncclBcast` latency on 2–8 GPU PCIe boxes sits in
//! the 25–50 µs range regardless of size (kernel launch + ring setup),
//! while large-message bandwidth approaches the PCIe copy ceiling.

/// Behavioural constants for the NCCL model.
#[derive(Debug, Clone)]
pub struct NcclParams {
    /// CUDA kernel launch + argument setup per collective call, per GPU
    /// (they launch in parallel streams), ns.
    pub launch_ns: u64,
    /// Per-hop per-slice synchronisation/copy initiation inside the
    /// persistent kernel (flag spin + warp copy start), ns.
    pub hop_ns: u64,
    /// Ring slice granularity, bytes (NCCL_BUFFSIZE-style slicing).
    pub slice_bytes: u64,
    /// Effective CUDA-kernel copy bandwidth through the PCIe fabric
    /// (peer-access path), bytes/s.
    pub copy_bw: f64,
    /// Stream-synchronisation cost the host pays to observe completion —
    /// charged by the MPI integration (§II-D), not by pure-NCCL callers
    /// who keep work on-stream.
    pub sync_ns: u64,
}

impl Default for NcclParams {
    fn default() -> Self {
        NcclParams {
            launch_ns: 27_000,
            hop_ns: 1_300,
            slice_bytes: 256 << 10,
            copy_bw: 9.5e9,
            sync_ns: 24_000,
        }
    }
}

impl NcclParams {
    /// Slice count for a message (at least 1).
    pub fn n_slices(&self, bytes: u64) -> usize {
        crate::comm::chunk_sizes(bytes, self.slice_bytes).len()
    }

    /// Stable fingerprint for plan-template cache keys: the NCCL
    /// parameters shape a plan the way an [`Algorithm`] variant shapes
    /// an MPI one, but are not part of that enum.
    ///
    /// [`Algorithm`]: crate::collectives::Algorithm
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
        for v in [
            self.launch_ns,
            self.hop_ns,
            self.slice_bytes,
            self.copy_bw.to_bits(),
            self.sync_ns,
        ] {
            h ^= v;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_tracks_parameters() {
        let a = NcclParams::default();
        let mut b = NcclParams::default();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.slice_bytes = 128 << 10;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn defaults_sane() {
        let p = NcclParams::default();
        assert!(p.launch_ns > 10_000, "NCCL launch cost is tens of µs");
        assert!(p.copy_bw < 12.0e9, "CUDA copy can't beat PCIe");
        assert_eq!(p.n_slices(4), 1);
        assert_eq!(p.n_slices(1 << 20), 4);
    }
}
