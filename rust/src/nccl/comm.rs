//! NCCL communicator lifecycle (§II-D).
//!
//! Integrating NCCL into an MPI runtime means managing NCCL communicators
//! and CUDA streams *in addition to* MPI communicators. On systems where
//! some GPU pairs lack peer access, a single communicator clique may not
//! be optimal and multiple communicators must be created and stitched —
//! the design complexity the paper cites as a reason to avoid NCCL
//! integration altogether.

use crate::topology::Cluster;

/// One NCCL communicator: a clique of ranks that can ring amongst
/// themselves with peer access (plus at most the unavoidable boundary
/// crossings).
#[derive(Debug, Clone)]
pub struct NcclComm {
    /// Global ranks in the communicator, ring order.
    pub ranks: Vec<usize>,
    /// One-time creation cost (ncclCommInitAll + stream setup), ns. Paid
    /// at communicator creation, not per collective — but it is why
    /// communicator churn is expensive.
    pub setup_ns: u64,
}

/// Communicator plan for one node: either a single ring communicator or
/// one per peer-access clique.
#[derive(Debug, Clone)]
pub struct CommPlan {
    pub comms: Vec<NcclComm>,
    /// True when the node needed more than one clique (no peer access
    /// across some boundary).
    pub fragmented: bool,
}

/// ncclCommInitAll is of order tens of ms; we charge a per-rank cost.
pub const SETUP_PER_RANK_NS: u64 = 9_000_000;

/// Build the communicator plan for the node-local ranks `ranks`.
pub fn plan_comms(cluster: &Cluster, ranks: &[usize]) -> CommPlan {
    assert!(!ranks.is_empty());
    // greedy clique split: walk ranks in topology order, cut where peer
    // access breaks
    let mut cliques: Vec<Vec<usize>> = vec![vec![ranks[0]]];
    for w in ranks.windows(2) {
        let (a, b) = (w[0], w[1]);
        let peer = cluster.peer_access(cluster.rank_device(a), cluster.rank_device(b));
        if peer {
            cliques.last_mut().unwrap().push(b);
        } else {
            cliques.push(vec![b]);
        }
    }
    let fragmented = cliques.len() > 1;
    let comms = cliques
        .into_iter()
        .map(|ranks| {
            let setup_ns = SETUP_PER_RANK_NS * ranks.len() as u64;
            NcclComm { ranks, setup_ns }
        })
        .collect();
    CommPlan { comms, fragmented }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::{dgx1, kesch};

    #[test]
    fn kesch_16_fragments_at_socket() {
        let c = kesch(1, 16).unwrap();
        let ranks: Vec<usize> = (0..16).collect();
        let plan = plan_comms(&c, &ranks);
        assert!(plan.fragmented);
        assert_eq!(plan.comms.len(), 2);
        assert_eq!(plan.comms[0].ranks.len(), 8);
    }

    #[test]
    fn kesch_4_single_comm() {
        let c = kesch(1, 4).unwrap();
        let ranks: Vec<usize> = (0..4).collect();
        let plan = plan_comms(&c, &ranks);
        assert!(!plan.fragmented);
        assert_eq!(plan.comms.len(), 1);
    }

    #[test]
    fn dgx1_nvlink_keeps_one_comm() {
        let c = dgx1(1, 8, true).unwrap();
        let ranks: Vec<usize> = (0..8).collect();
        let plan = plan_comms(&c, &ranks);
        assert!(!plan.fragmented, "NVLink mesh gives full peer access");
    }

    #[test]
    fn setup_cost_scales_with_ranks() {
        let c = kesch(1, 8).unwrap();
        let ranks: Vec<usize> = (0..8).collect();
        let plan = plan_comms(&c, &ranks);
        let total: u64 = plan.comms.iter().map(|c| c.setup_ns).sum();
        assert_eq!(total, 8 * SETUP_PER_RANK_NS);
    }
}
