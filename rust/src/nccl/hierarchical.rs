//! NCCL-integrated `MPI_Bcast` (the authors' earlier design, ref. [4];
//! §II-D): tuned MPI internode broadcast among node leaders + `ncclBcast`
//! within each node, pipelined in large chunks.
//!
//! The integration costs that motivate the paper's pure-MPI design are
//! modelled explicitly:
//!
//! * every call pays the NCCL kernel launch on each GPU *and* a
//!   stream-synchronisation on each rank before MPI may consider the
//!   collective complete (`sync_ns`);
//! * intranode movement inherits NCCL's ring/copy cost profile.

use crate::collectives::template::{
    n_chunk_slots, AlgoKey, CollectiveTemplate, RoleRecorder, TemplateKey,
};
use crate::collectives::{BcastPlan, BcastSpec, CollectiveKind, CollectivePlan, FlowEdge};
use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps, OpId, Plan, SimOp, NO_CLASS};

use super::bcast::plan_ring;
use super::cost::NcclParams;

/// Pipeline chunk size for the internode phase (the [4] design moves
/// large messages in multi-MB chunks between leaders).
pub const DEFAULT_CHUNK: u64 = 4 << 20;

/// Build the NCCL-MV2-GDR broadcast plan across the whole cluster.
pub fn plan(
    comm: &mut Comm,
    params: &NcclParams,
    spec: &BcastSpec,
    chunk: u64,
) -> BcastPlan {
    template(comm, params, spec, chunk).cp
}

/// Structural shape of the hierarchical pipeline at a message size:
/// chunk count in the high 32 bits, total slice count in the low. All
/// non-final chunks are full, so two sizes share a DAG iff both match.
fn shape(params: &NcclParams, bytes: u64, chunk: u64) -> u64 {
    let chunks = n_chunk_slots(bytes, chunk);
    let mut slices = 0u64;
    for c in 0..chunks {
        let cbytes = ByteRole::ChunkSlot {
            index: c as u32,
            chunk,
        }
        .bytes(bytes);
        slices += n_chunk_slots(cbytes, params.slice_bytes);
    }
    (chunks << 32) | slices
}

/// Acquire the hierarchical plan through the comm's template cache:
/// across a training schedule's message sizes the op DAG is built once
/// per (root, chunk shape) and rescaled, exactly like the MPI menu.
pub fn cached<'a, 'c>(
    comm: &'a mut Comm<'c>,
    params: &NcclParams,
    spec: &BcastSpec,
    chunk: u64,
) -> &'a CollectivePlan {
    let key = TemplateKey {
        kind: CollectiveKind::Broadcast,
        algo: AlgoKey::NcclHier {
            chunk,
            params_fp: params.fingerprint(),
        },
        root: spec.root,
        n_ranks: spec.n_ranks,
        shape: shape(params, spec.bytes, chunk),
        generation: comm.cluster().generation(),
        topology: comm.cluster().topology_kind(),
    };
    let comm_params = comm.params().clone();
    let hit = comm.template_cache_mut().try_rescale(&key, spec.bytes, |b| {
        crate::comm::protocol::size_class(&comm_params, b)
    });
    if !hit {
        let tpl = template(comm, params, spec, chunk);
        comm.template_cache_mut().insert(key, tpl);
    }
    comm.template_cache().plan_for(&key)
}

/// [`plan`] with byte roles recorded for the template cache.
pub fn template(
    comm: &mut Comm,
    params: &NcclParams,
    spec: &BcastSpec,
    chunk: u64,
) -> CollectiveTemplate {
    let cluster = comm.cluster();
    assert_eq!(
        spec.n_ranks,
        cluster.n_gpus(),
        "hierarchical bcast runs over all cluster ranks"
    );
    let mut plan = Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges: Vec<FlowEdge> = Vec::new();

    // rank blocks for the two stages, from the topology's natural
    // hierarchy: leaf blocks on fat-tree, group blocks on dragonfly,
    // node blocks everywhere else (identical to the historical
    // node-major grouping on kesch/dgx1/flat). Blocks are contiguous in
    // rank order.
    let ranks_of_node = cluster.rank_groups();
    let mut group_of = vec![0usize; spec.n_ranks];
    for (g, ranks) in ranks_of_node.iter().enumerate() {
        for &r in ranks {
            group_of[r] = g;
        }
    }
    debug_assert_eq!(
        ranks_of_node.iter().map(|g| g.len()).sum::<usize>(),
        spec.n_ranks
    );

    let root_node = group_of[spec.root];
    // leaders: the root in its block, the first rank of each other block
    let leaders: Vec<usize> = ranks_of_node
        .iter()
        .enumerate()
        .map(|(i, ranks)| if i == root_node { spec.root } else { ranks[0] })
        .collect();

    // kernel launch per rank (NCCL phase requirement), in parallel
    let mut launch: Vec<Option<OpId>> = vec![None; spec.n_ranks];
    for r in 0..spec.n_ranks {
        if ranks_of_node[group_of[r]].len() > 1 {
            let mark = plan.len();
            launch[r] = Some(plan.push(
                SimOp::Delay {
                    dev: cluster.rank_device(r),
                    dur_ns: params.launch_ns,
                },
                Deps::none(),
                None,
            ));
            rec.tag(&plan, mark, ByteRole::Fixed(0), NO_CLASS);
        }
    }

    let chunks = crate::comm::chunk_sizes(spec.bytes, chunk);
    // internode pipelined chain over leaders, chunk by chunk, feeding the
    // per-node NCCL ring for each chunk
    let n_leaders = leaders.len();
    let mut leader_recv: Vec<Vec<Option<OpId>>> =
        vec![vec![None; chunks.len()]; n_leaders];
    // leader order: root's node first, then the others in node order
    let mut order: Vec<usize> = Vec::with_capacity(n_leaders);
    order.push(root_node);
    for i in 0..n_leaders {
        if i != root_node {
            order.push(i);
        }
    }

    // per-rank last delivery op (for the final sync)
    let mut last_delivery: Vec<Option<OpId>> = vec![None; spec.n_ranks];

    for (c, &cbytes) in chunks.iter().enumerate() {
        // the remainder chunk may sit in a different mechanism class
        let class = comm.size_class_of(cbytes);
        let role = ByteRole::ChunkSlot {
            index: c as u32,
            chunk,
        };
        // chain the chunk through the leaders
        for w in order.windows(2) {
            let (src_node, dst_node) = (w[0], w[1]);
            let src = leaders[src_node];
            let dst = leaders[dst_node];
            // root leader owns the data (no dependency)
            let deps = Deps::from_opt(leader_recv[src_node][c]);
            let mark = plan.len();
            let op = comm.send(&mut plan, src, dst, cbytes, deps, Some((dst, c)));
            rec.tag(&plan, mark, role, class);
            edges.push(FlowEdge::copy(src, dst, c, op));
            leader_recv[dst_node][c] = Some(op);
            last_delivery[dst] = Some(op);
        }
        // NCCL ring inside each node for this chunk
        for (node, ranks) in ranks_of_node.iter().enumerate() {
            if ranks.len() <= 1 {
                continue;
            }
            let leader = leaders[node];
            let root_ready = leader_recv[node][c];
            let out = plan_ring(
                cluster,
                params,
                ranks,
                leader,
                cbytes,
                c * ((params.n_slices(chunk)).max(1)),
                Some((c as u32, chunk)),
                &mut plan,
                &mut rec,
                &mut edges,
                &launch,
                root_ready,
            );
            for &r in ranks {
                if let Some(op) = out[r] {
                    last_delivery[r] = Some(op);
                }
            }
        }
    }

    // stream synchronisation per rank (the MPI-integration cost, §II-D);
    // ranks on single-GPU nodes never enter the NCCL phase and skip it
    for r in 0..spec.n_ranks {
        if launch[r].is_none() {
            continue;
        }
        if last_delivery[r].is_none() && r == spec.root {
            continue;
        }
        let mark = plan.len();
        plan.push(
            SimOp::Delay {
                dev: cluster.rank_device(r),
                dur_ns: params.sync_ns,
            },
            Deps::from_opt(last_delivery[r]),
            None,
        );
        rec.tag(&plan, mark, ByteRole::Fixed(0), NO_CLASS);
    }

    let slices_per_chunk = params.n_slices(chunk).max(1);
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: chunks.len() * slices_per_chunk,
            spec: spec.clone(),
            algorithm: "nccl-mv2-gdr".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::kesch;

    #[test]
    fn covers_all_ranks() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 1 << 20);
        let bp = plan(&mut comm, &params, &spec, DEFAULT_CHUNK);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        for r in 1..16 {
            // every rank got slice 0 of chunk 0
            assert!(
                result.delivery_time(&bp.plan, r, 0).is_some(),
                "rank {r} missing data"
            );
        }
    }

    #[test]
    fn small_message_pays_launch_and_sync() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 4);
        let bp = plan(&mut comm, &params, &spec, DEFAULT_CHUNK);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        assert!(
            t >= params.launch_ns + params.sync_ns,
            "integration overheads must show: {t}"
        );
    }

    #[test]
    fn large_message_pipeline_is_bandwidth_bound() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let params = NcclParams::default();
        let m: u64 = 128 << 20;
        let spec = BcastSpec::new(0, 16, m);
        let bp = plan(&mut comm, &params, &spec, DEFAULT_CHUNK);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        // must be within ~3x of the IB serial bound (pipelined phases)
        let ib_ns = (m as f64 / 6.8e9 * 1e9) as u64;
        assert!(t > ib_ns);
        assert!(t < 3 * ib_ns, "{t} vs {ib_ns}");
    }

    #[test]
    fn cached_template_matches_fresh_build() {
        let c = kesch(2, 8).unwrap();
        let params = NcclParams::default();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        // 1 MB twice (exact revisit), a shape-mate of 1 MB, then shapes
        // that force rebuilds — every acquisition must match a fresh
        // single-use build
        for bytes in [1u64 << 20, 1 << 20, (1 << 20) - 4096, 4, 9 << 20, 64 << 20] {
            let spec = BcastSpec::new(0, 16, bytes);
            let cached_ns =
                engine.makespan_ns(&cached(&mut comm, &params, &spec, DEFAULT_CHUNK).plan);
            let mut fresh_comm = Comm::new(&c);
            let fresh = plan(&mut fresh_comm, &params, &spec, DEFAULT_CHUNK);
            assert_eq!(
                cached_ns,
                engine.makespan_ns(&fresh.plan),
                "hierarchical template diverged at {bytes}B"
            );
        }
        let (hits, _) = comm.template_cache().stats();
        assert!(hits >= 2, "revisits and shape-mates must hit the cache");
    }

    #[test]
    fn fat_tree_blocks_map_leaves_to_stages() {
        // on a structured fabric the two stages follow rank_groups():
        // the internode chain runs over leaf leaders and the NCCL ring
        // runs inside each leaf block
        let c = crate::topology::presets::fat_tree(2, 2, 2, 2, 2).unwrap();
        assert!(
            c.rank_groups().iter().all(|g| g.len() == 2),
            "fat-tree blocks should be leaf-sized"
        );
        let mut comm = Comm::new(&c);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 8, 1 << 20);
        let bp = plan(&mut comm, &params, &spec, DEFAULT_CHUNK);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        for r in 1..8 {
            assert!(
                result.delivery_time(&bp.plan, r, 0).is_some(),
                "rank {r} missing data"
            );
        }
    }

    #[test]
    fn single_gpu_nodes_skip_nccl_phase() {
        let c = kesch(2, 1).unwrap();
        let mut comm = Comm::new(&c);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 2, 4096);
        let bp = plan(&mut comm, &params, &spec, DEFAULT_CHUNK);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        // no launches, no syncs: just the internode send
        assert!(t < params.launch_ns);
    }
}
