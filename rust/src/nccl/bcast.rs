//! `ncclBcast` model: persistent-kernel ring pipeline.

use crate::collectives::{BcastPlan, BcastSpec, FlowEdge};
use crate::netsim::{Deps, OpId, Plan, SimOp};
use crate::topology::Cluster;

use super::cost::NcclParams;
use super::ring::ring_from;

/// Build the intranode `ncclBcast` plan over ranks `ranks` (global rank
/// ids on ONE node) rooted at `root`, moving `bytes`.
///
/// Structure: one kernel-launch `Delay` per GPU, then the message moves
/// around the topology ring in `slice_bytes` slices; each hop of each
/// slice costs `hop_ns` (flag sync + copy start) and rides the PCIe
/// fabric at `copy_bw`. Pairs without peer access bounce through the
/// source's host (pinned staging), as NCCL 1.x's via-host transport does.
pub fn plan_ring(
    cluster: &Cluster,
    params: &NcclParams,
    ranks: &[usize],
    root: usize,
    bytes: u64,
    // chunk labels get offset by this (hierarchical pipelining reuses us
    // per chunk)
    chunk_base: usize,
    plan: &mut Plan,
    edges: &mut Vec<FlowEdge>,
    launch: &[Option<OpId>],
    // per-rank op that must precede the root's first send (e.g. the
    // internode delivery of this chunk in hierarchical mode)
    root_ready: Option<OpId>,
) -> Vec<Option<OpId>> {
    let ring = ring_from(ranks, root);
    let slices = crate::comm::chunk_sizes(bytes, params.slice_bytes);
    // last delivery op per ring position
    let mut last_recv: Vec<Option<OpId>> = vec![None; ring.len()];
    // recv op of each slice at the previous ring position
    let mut prev_recv: Vec<Option<OpId>> = vec![None; slices.len()];
    for (pos, pair) in ring.windows(2).enumerate() {
        let (src, dst) = (pair[0], pair[1]);
        let src_dev = cluster.rank_device(src);
        let dst_dev = cluster.rank_device(dst);
        let peer = cluster.peer_access(src_dev, dst_dev);
        for (s, &sbytes) in slices.iter().enumerate() {
            let mut deps = Deps::none();
            if let Some(op) = prev_recv[s] {
                deps.push(op); // slice must have arrived at src
            } else if let Some(op) = root_ready {
                deps.push(op); // root's data availability (hierarchical)
            }
            if let Some(op) = launch[src] {
                deps.push(op);
            }
            if let Some(op) = launch[dst] {
                deps.push(op);
            }
            let label = Some((dst, chunk_base + s));
            let op = if peer {
                let route = cluster.route(src_dev, dst_dev).expect("ring route");
                plan.push(
                    SimOp::Transfer {
                        route,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    deps,
                    label,
                )
            } else {
                // via-host transport: bounce through the source's socket
                // host (pinned buffer), two capped copies
                let host = cluster.staging_host(src_dev).expect("host");
                let first = cluster.route(src_dev, host).expect("d2h");
                let second = cluster.route(host, dst_dev).expect("h2d");
                let mid = plan.push(
                    SimOp::Transfer {
                        route: first,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    deps,
                    None,
                );
                plan.push(
                    SimOp::Transfer {
                        route: second,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    Deps::one(mid),
                    label,
                )
            };
            edges.push(FlowEdge::copy(src, dst, chunk_base + s, op));
            prev_recv[s] = Some(op);
            last_recv[pos + 1] = Some(op);
        }
    }
    // map back to per-global-rank last recv
    let mut out: Vec<Option<OpId>> = vec![None; cluster.n_gpus()];
    for (pos, &r) in ring.iter().enumerate() {
        out[r] = last_recv[pos];
    }
    out
}

/// The standalone `ncclBcast` over one node's ranks.
pub fn plan_intranode(
    cluster: &Cluster,
    params: &NcclParams,
    spec: &BcastSpec,
) -> BcastPlan {
    assert!(
        spec.n_ranks <= cluster.n_gpus(),
        "more ranks than cluster GPUs"
    );
    let ranks: Vec<usize> = (0..spec.n_ranks).collect();
    // all participating GPUs must be on one node (NCCL 1.x limitation)
    let n0 = cluster.device(cluster.rank_device(0)).node;
    assert!(
        ranks
            .iter()
            .all(|&r| cluster.device(cluster.rank_device(r)).node == n0),
        "NCCL 1.x is single-node only (§II-B)"
    );
    let mut plan = Plan::new();
    let mut edges = Vec::new();
    // parallel kernel launches
    let mut launch: Vec<Option<OpId>> = vec![None; cluster.n_gpus()];
    for &r in &ranks {
        let dev = cluster.rank_device(r);
        launch[r] = Some(plan.push(
            SimOp::Delay {
                dev,
                dur_ns: params.launch_ns,
            },
            Deps::none(),
            None,
        ));
    }
    plan_ring(
        cluster,
        params,
        &ranks,
        spec.root,
        spec.bytes,
        0,
        &mut plan,
        &mut edges,
        &launch,
        None,
    );
    let n_chunks = params.n_slices(spec.bytes);
    BcastPlan {
        plan,
        edges,
        n_chunks,
        spec: spec.clone(),
        algorithm: "nccl-bcast".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::kesch;

    #[test]
    fn small_message_dominated_by_launch() {
        let c = kesch(1, 2);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 2, 4);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        assert!(t >= params.launch_ns);
        assert!(t < params.launch_ns + 10_000);
    }

    #[test]
    fn large_message_approaches_copy_bw() {
        let c = kesch(1, 4);
        let params = NcclParams::default();
        let m = 128 << 20;
        let spec = BcastSpec::new(0, 4, m);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        let ideal_ns = (m as f64 / params.copy_bw * 1e9) as u64;
        assert!(t > ideal_ns, "can't beat the copy ceiling");
        assert!(
            t < 2 * ideal_ns,
            "ring pipeline should be near bandwidth-optimal: {t} vs {ideal_ns}"
        );
    }

    #[test]
    fn validates_as_broadcast() {
        let c = kesch(1, 8);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 8, 3 << 20);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        crate::collectives::validate::validate(&bp, &result).unwrap();
    }

    #[test]
    fn sixteen_gpu_ring_bounces_once() {
        let c = kesch(1, 16);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 4);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        crate::collectives::validate::validate(&bp, &result).unwrap();
        // 15 forwarding hops, one staged (2 ops) + 16 launches
        assert_eq!(bp.plan.len(), 16 + 15 + 1);
    }

    #[test]
    #[should_panic(expected = "single-node")]
    fn multinode_rejected() {
        let c = kesch(2, 8);
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 4);
        let _ = plan_intranode(&c, &params, &spec);
    }
}
