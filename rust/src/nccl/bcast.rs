//! `ncclBcast` model: persistent-kernel ring pipeline.

use crate::collectives::template::{AlgoKey, CollectiveTemplate, RoleRecorder, TemplateKey};
use crate::collectives::{BcastPlan, BcastSpec, CollectiveKind, CollectivePlan, FlowEdge};
use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps, OpId, Plan, SimOp, NO_CLASS};
use crate::topology::Cluster;

use super::cost::NcclParams;
use super::ring::ring_from;

/// Build the intranode `ncclBcast` plan over ranks `ranks` (global rank
/// ids on ONE node) rooted at `root`, moving `bytes`.
///
/// Structure: one kernel-launch `Delay` per GPU, then the message moves
/// around the topology ring in `slice_bytes` slices; each hop of each
/// slice costs `hop_ns` (flag sync + copy start) and rides the PCIe
/// fabric at `copy_bw`. Pairs without peer access bounce through the
/// source's host (pinned staging), as NCCL 1.x's via-host transport does.
///
/// Every emitted op is tagged with its byte role in `rec` (all
/// `NO_CLASS`: hop costs are fixed parameters, structure depends only on
/// topology and slice count). `outer` nests the roles under a
/// hierarchical chunk: `Some((chunk index, chunk granularity))` when the
/// ring moves one pipeline chunk rather than the whole message.
#[allow(clippy::too_many_arguments)]
pub fn plan_ring(
    cluster: &Cluster,
    params: &NcclParams,
    ranks: &[usize],
    root: usize,
    bytes: u64,
    // chunk labels get offset by this (hierarchical pipelining reuses us
    // per chunk)
    chunk_base: usize,
    outer: Option<(u32, u64)>,
    plan: &mut Plan,
    rec: &mut RoleRecorder,
    edges: &mut Vec<FlowEdge>,
    launch: &[Option<OpId>],
    // per-rank op that must precede the root's first send (e.g. the
    // internode delivery of this chunk in hierarchical mode)
    root_ready: Option<OpId>,
) -> Vec<Option<OpId>> {
    let ring = ring_from(ranks, root);
    let slices = crate::comm::chunk_sizes(bytes, params.slice_bytes);
    // last delivery op per ring position
    let mut last_recv: Vec<Option<OpId>> = vec![None; ring.len()];
    // recv op of each slice at the previous ring position
    let mut prev_recv: Vec<Option<OpId>> = vec![None; slices.len()];
    for (pos, pair) in ring.windows(2).enumerate() {
        let (src, dst) = (pair[0], pair[1]);
        let src_dev = cluster.rank_device(src);
        let dst_dev = cluster.rank_device(dst);
        let peer = cluster.peer_access(src_dev, dst_dev);
        for (s, &sbytes) in slices.iter().enumerate() {
            let role = match outer {
                Some((oc, ochunk)) => ByteRole::SliceOfChunk {
                    outer: oc,
                    chunk: ochunk,
                    index: s as u32,
                    slice: params.slice_bytes,
                },
                None => ByteRole::ChunkSlot {
                    index: s as u32,
                    chunk: params.slice_bytes,
                },
            };
            let mut deps = Deps::none();
            if let Some(op) = prev_recv[s] {
                deps.push(op); // slice must have arrived at src
            } else if let Some(op) = root_ready {
                deps.push(op); // root's data availability (hierarchical)
            }
            if let Some(op) = launch[src] {
                deps.push(op);
            }
            if let Some(op) = launch[dst] {
                deps.push(op);
            }
            let label = Some((dst, chunk_base + s));
            let mark = plan.len();
            let op = if peer {
                let route = cluster.route(src_dev, dst_dev).expect("ring route");
                plan.push(
                    SimOp::Transfer {
                        route,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    deps,
                    label,
                )
            } else {
                // via-host transport: bounce through the source's socket
                // host (pinned buffer), two capped copies
                let host = cluster.staging_host(src_dev).expect("host");
                let first = cluster.route(src_dev, host).expect("d2h");
                let second = cluster.route(host, dst_dev).expect("h2d");
                let mid = plan.push(
                    SimOp::Transfer {
                        route: first,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    deps,
                    None,
                );
                plan.push(
                    SimOp::Transfer {
                        route: second,
                        bytes: sbytes,
                        overhead_ns: params.hop_ns,
                        issue_ns: params.hop_ns,
                        bw_cap: Some(params.copy_bw),
                    },
                    Deps::one(mid),
                    label,
                )
            };
            rec.tag(plan, mark, role, NO_CLASS);
            edges.push(FlowEdge::copy(src, dst, chunk_base + s, op));
            prev_recv[s] = Some(op);
            last_recv[pos + 1] = Some(op);
        }
    }
    // map back to per-global-rank last recv
    let mut out: Vec<Option<OpId>> = vec![None; cluster.n_gpus()];
    for (pos, &r) in ring.iter().enumerate() {
        out[r] = last_recv[pos];
    }
    out
}

/// The standalone `ncclBcast` over one node's ranks.
pub fn plan_intranode(
    cluster: &Cluster,
    params: &NcclParams,
    spec: &BcastSpec,
) -> BcastPlan {
    template_intranode(cluster, params, spec).cp
}

/// Acquire the intranode plan through the comm's template cache
/// (`AlgoKey::NcclRing`): message sizes sharing a slice count rescale
/// the same ring DAG instead of rebuilding it.
pub fn cached_intranode<'a, 'c>(
    comm: &'a mut Comm<'c>,
    params: &NcclParams,
    spec: &BcastSpec,
) -> &'a CollectivePlan {
    let key = TemplateKey {
        kind: CollectiveKind::Broadcast,
        algo: AlgoKey::NcclRing {
            params_fp: params.fingerprint(),
        },
        root: spec.root,
        n_ranks: spec.n_ranks,
        shape: params.n_slices(spec.bytes) as u64,
        generation: comm.cluster().generation(),
        topology: comm.cluster().topology_kind(),
    };
    let comm_params = comm.params().clone();
    let hit = comm.template_cache_mut().try_rescale(&key, spec.bytes, |b| {
        crate::comm::protocol::size_class(&comm_params, b)
    });
    if !hit {
        let tpl = template_intranode(comm.cluster(), params, spec);
        comm.template_cache_mut().insert(key, tpl);
    }
    comm.template_cache().plan_for(&key)
}

/// [`plan_intranode`] with the byte roles recorded, so the plan can be
/// rescaled across message sizes of equal slice count.
pub fn template_intranode(
    cluster: &Cluster,
    params: &NcclParams,
    spec: &BcastSpec,
) -> CollectiveTemplate {
    assert!(
        spec.n_ranks <= cluster.n_gpus(),
        "more ranks than cluster GPUs"
    );
    let ranks: Vec<usize> = (0..spec.n_ranks).collect();
    // all participating GPUs must be on one node (NCCL 1.x limitation)
    let n0 = cluster.device(cluster.rank_device(0)).node;
    assert!(
        ranks
            .iter()
            .all(|&r| cluster.device(cluster.rank_device(r)).node == n0),
        "NCCL 1.x is single-node only (§II-B)"
    );
    let mut plan = Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    // parallel kernel launches
    let mut launch: Vec<Option<OpId>> = vec![None; cluster.n_gpus()];
    for &r in &ranks {
        let dev = cluster.rank_device(r);
        let mark = plan.len();
        launch[r] = Some(plan.push(
            SimOp::Delay {
                dev,
                dur_ns: params.launch_ns,
            },
            Deps::none(),
            None,
        ));
        rec.tag(&plan, mark, ByteRole::Fixed(0), NO_CLASS);
    }
    plan_ring(
        cluster,
        params,
        &ranks,
        spec.root,
        spec.bytes,
        0,
        None,
        &mut plan,
        &mut rec,
        &mut edges,
        &launch,
        None,
    );
    let n_chunks = params.n_slices(spec.bytes);
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks,
            spec: spec.clone(),
            algorithm: "nccl-bcast".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::kesch;

    #[test]
    fn small_message_dominated_by_launch() {
        let c = kesch(1, 2).unwrap();
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 2, 4);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        assert!(t >= params.launch_ns);
        assert!(t < params.launch_ns + 10_000);
    }

    #[test]
    fn large_message_approaches_copy_bw() {
        let c = kesch(1, 4).unwrap();
        let params = NcclParams::default();
        let m = 128 << 20;
        let spec = BcastSpec::new(0, 4, m);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let t = e.execute(&bp.plan).makespan;
        let ideal_ns = (m as f64 / params.copy_bw * 1e9) as u64;
        assert!(t > ideal_ns, "can't beat the copy ceiling");
        assert!(
            t < 2 * ideal_ns,
            "ring pipeline should be near bandwidth-optimal: {t} vs {ideal_ns}"
        );
    }

    #[test]
    fn validates_as_broadcast() {
        let c = kesch(1, 8).unwrap();
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 8, 3 << 20);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        crate::collectives::validate::validate(&bp, &result).unwrap();
    }

    #[test]
    fn sixteen_gpu_ring_bounces_once() {
        let c = kesch(1, 16).unwrap();
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 4);
        let bp = plan_intranode(&c, &params, &spec);
        let mut e = Engine::new(&c);
        let result = e.execute(&bp.plan);
        crate::collectives::validate::validate(&bp, &result).unwrap();
        // 15 forwarding hops, one staged (2 ops) + 16 launches
        assert_eq!(bp.plan.len(), 16 + 15 + 1);
    }

    #[test]
    fn cached_intranode_matches_fresh_build() {
        let c = kesch(1, 8).unwrap();
        let params = NcclParams::default();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        // exact revisit, slice-count mate, then new shapes
        for bytes in [1u64 << 20, 1 << 20, (1 << 20) - 4096, 4, 8 << 20] {
            let spec = BcastSpec::new(0, 8, bytes);
            let cached_ns =
                engine.makespan_ns(&cached_intranode(&mut comm, &params, &spec).plan);
            let fresh = plan_intranode(&c, &params, &spec);
            assert_eq!(
                cached_ns,
                engine.makespan_ns(&fresh.plan),
                "intranode template diverged at {bytes}B"
            );
        }
        assert!(comm.template_cache().stats().0 >= 2);
    }

    #[test]
    fn template_rescales_within_slice_count() {
        // same slice count (4): rescaling the template must reproduce a
        // fresh build bit-for-bit
        let c = kesch(1, 8).unwrap();
        let params = NcclParams::default();
        let m1: u64 = 1 << 20;
        let m2: u64 = (1 << 20) - 4096; // 3 full slices + remainder = 4
        let mut tpl = template_intranode(&c, &params, &BcastSpec::new(0, 8, m1));
        assert_eq!(tpl.roles.len(), tpl.cp.plan.len());
        assert!(tpl.rescale(m2, |_| 0), "all-NO_CLASS plan must rescale");
        let mut e = Engine::new(&c);
        let rescaled = e.execute(&tpl.cp.plan).makespan;
        let fresh = plan_intranode(&c, &params, &BcastSpec::new(0, 8, m2));
        assert_eq!(rescaled, e.execute(&fresh.plan).makespan);
        assert_eq!(tpl.cp.plan.total_bytes(), fresh.plan.total_bytes());
    }

    #[test]
    #[should_panic(expected = "single-node")]
    fn multinode_rejected() {
        let c = kesch(2, 8).unwrap();
        let params = NcclParams::default();
        let spec = BcastSpec::new(0, 16, 4);
        let _ = plan_intranode(&c, &params, &spec);
    }
}
