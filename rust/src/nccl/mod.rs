//! NVIDIA NCCL 1.3 behavioural model (§II-B of the paper) and the
//! NCCL-integrated `MPI_Bcast` hybrid of the authors' earlier work [4]
//! (§II-D).
//!
//! NCCL 1.x is a single-node, ring-based collective library: every
//! collective is one persistent CUDA kernel per GPU that moves data
//! around a topology-ordered ring in fine-grained slices, synchronising
//! hop-by-hop with flags. That design has two consequences the paper
//! exploits:
//!
//! * **great large-message bandwidth** — the ring pipeline saturates the
//!   PCIe fabric;
//! * **poor small/medium-message latency** — every call pays CUDA kernel
//!   launch + ring traversal costs (tens of µs) that a CPU-driven MPI
//!   runtime simply does not have.
//!
//! [`bcast::plan_intranode`] models `ncclBcast`; [`hierarchical`] models
//! the NCCL-integrated `MPI_Bcast` (NCCL ring inside each node + tuned
//! MPI internode), including the stream-synchronisation cost the MPI
//! integration must pay on every call (§II-D).

pub mod bcast;
pub mod comm;
pub mod cost;
pub mod hierarchical;
pub mod ring;

pub use cost::NcclParams;
