//! Ring construction.
//!
//! NCCL orders the ring by PCIe topology so adjacent ring positions are
//! cheap hops (same PLX where possible) and the expensive boundary (QPI)
//! is crossed exactly once. Our cluster presets enumerate GPUs in
//! exactly that order, so the ring is rank order rotated to the root.

use crate::topology::Cluster;

/// The ring (as rank indices) for a broadcast rooted at `root` over the
/// node-local ranks `ranks` (global rank numbers, topology-ordered).
/// The root leads; the ring follows topology order from it, wrapping.
pub fn ring_from(ranks: &[usize], root: usize) -> Vec<usize> {
    let pos = ranks
        .iter()
        .position(|&r| r == root)
        .expect("root must be a member of the ring");
    let mut out = Vec::with_capacity(ranks.len());
    for i in 0..ranks.len() {
        out.push(ranks[(pos + i) % ranks.len()]);
    }
    out
}

/// Count how many adjacent ring pairs lack peer access (each such pair
/// forces a host bounce — and, per §II-D, potentially a separate NCCL
/// communicator clique on older systems).
pub fn bounce_count(cluster: &Cluster, ring: &[usize]) -> usize {
    ring.windows(2)
        .filter(|w| {
            !cluster.peer_access(cluster.rank_device(w[0]), cluster.rank_device(w[1]))
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    #[test]
    fn ring_rotation() {
        let ranks = vec![0, 1, 2, 3];
        assert_eq!(ring_from(&ranks, 2), vec![2, 3, 0, 1]);
        assert_eq!(ring_from(&ranks, 0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn kesch_ring_crosses_qpi_once_for_16() {
        let c = kesch(1, 16).unwrap();
        let ranks: Vec<usize> = (0..16).collect();
        let ring = ring_from(&ranks, 0);
        // rank 7 -> 8 crosses sockets; everything else stays on PCIe
        assert_eq!(bounce_count(&c, &ring), 1);
    }

    #[test]
    fn kesch_ring_4_has_no_bounce() {
        let c = kesch(1, 4).unwrap();
        let ranks: Vec<usize> = (0..4).collect();
        assert_eq!(bounce_count(&c, &ring_from(&ranks, 0)), 0);
    }

    #[test]
    #[should_panic(expected = "member")]
    fn root_must_be_member() {
        ring_from(&[1, 2, 3], 0);
    }
}
