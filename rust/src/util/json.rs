//! A small JSON value model + writer.
//!
//! Benches and the tuning framework persist machine-readable reports
//! (`target/reports/*.json`, `artifacts/tuning_*.json`); `serde` is not
//! available offline, so this provides the tiny subset we need: building a
//! tree of values and serialising it (and parsing it back for tuning-table
//! persistence).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(map) = self {
            map.insert(key.to_string(), value.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialise with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Supports the full value grammar minus
    /// exotic number forms; good enough to read back our own output.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Config(format!(
                "trailing garbage at byte {} in JSON",
                p.pos
            )));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Config(format!(
                "expected '{}' at byte {} in JSON",
                b as char, self.pos
            )))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::Config(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => {
                            return Err(Error::Config(format!(
                                "bad array at byte {}",
                                self.pos
                            )))
                        }
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    map.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => {
                            return Err(Error::Config(format!(
                                "bad object at byte {}",
                                self.pos
                            )))
                        }
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::Config(format!("bad value at byte {}", self.pos))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| Error::Config("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Config("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Config("bad \\u escape".into()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(Error::Config("bad escape".into())),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::Config("invalid utf8 in JSON".into()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err(Error::Config("unterminated string".into())),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Config(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "pipelined_chain")
            .set("latency_us", 12.5f64)
            .set("gpus", 16u64)
            .set("ok", true)
            .set("series", vec![1u64, 2, 4, 8]);
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn roundtrip_escapes() {
        let j = Json::Str("a\"b\\c\nd\tḟ".to_string());
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": null}, -2.5e1], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].as_f64(), Some(-25.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(1.5).to_string_compact(), "1.5");
    }
}
