//! A tiny argument parser (flags, `--key value` options, subcommands,
//! positional arguments) — the offline crate universe has no `clap`.
//!
//! Usage pattern:
//!
//! ```
//! use gdrbcast::util::cli::Args;
//! let argv = vec!["bcast".to_string(), "--gpus".to_string(), "16".to_string()];
//! let mut args = Args::new(argv);
//! let gpus: usize = args.opt_parse("--gpus").unwrap().unwrap_or(8);
//! assert_eq!(gpus, 16);
//! ```

use std::collections::HashMap;
use std::str::FromStr;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand word, `--key value` options, `--flag`
/// booleans and positionals, in that grammar. Values may also be attached
/// with `--key=value`.
#[derive(Debug, Clone)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
    consumed: Vec<String>,
    /// Option keys that appeared more than once — silently keeping the
    /// last occurrence hid typos like `--faults a --faults b`; reported
    /// as a usage error by [`Args::finish`].
    dups: Vec<String>,
}

impl Args {
    /// Parse from an argv-style vector (program name NOT included).
    pub fn new(argv: Vec<String>) -> Args {
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut positionals = Vec::new();
        let mut dups = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    let key = format!("--{}", &rest[..eq]);
                    if opts.insert(key.clone(), rest[eq + 1..].to_string()).is_some() {
                        dups.push(key);
                    }
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = it.next().unwrap();
                    let key = format!("--{rest}");
                    if opts.insert(key.clone(), val).is_some() {
                        dups.push(key);
                    }
                } else {
                    flags.push(format!("--{rest}"));
                }
            } else {
                positionals.push(arg);
            }
        }
        Args {
            opts,
            flags,
            positionals,
            consumed: Vec::new(),
            dups,
        }
    }

    /// From the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::new(std::env::args().skip(1).collect())
    }

    /// Take the next positional (typically the subcommand).
    pub fn positional(&mut self) -> Option<String> {
        if self.positionals.is_empty() {
            None
        } else {
            Some(self.positionals.remove(0))
        }
    }

    /// Whether a boolean flag is present.
    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string option.
    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.opts.get(name).cloned()
    }

    /// Parse an option into any `FromStr` type.
    pub fn opt_parse<T: FromStr>(&mut self, name: &str) -> Result<Option<T>> {
        match self.opt(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|_| {
                Error::Usage(format!("cannot parse {name} value '{raw}'"))
            }),
        }
    }

    /// Parse an option with a default.
    pub fn opt_or<T: FromStr>(&mut self, name: &str, default: T) -> Result<T> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Parse an option constrained to a fixed set of choices, with a
    /// default when absent (e.g. `--exchange bcast|allreduce|compare`).
    pub fn opt_choice(&mut self, name: &str, choices: &[&str], default: &str) -> Result<String> {
        debug_assert!(choices.contains(&default));
        let raw = self.opt(name).unwrap_or_else(|| default.to_string());
        if choices.iter().any(|c| *c == raw) {
            Ok(raw)
        } else {
            Err(Error::Usage(format!(
                "{name} must be one of {}, got '{raw}'",
                choices.join("|")
            )))
        }
    }

    /// Comma-separated list option, e.g. `--gpus 2,4,8,16`.
    pub fn opt_list<T: FromStr>(&mut self, name: &str) -> Result<Option<Vec<T>>> {
        match self.opt(name) {
            None => Ok(None),
            Some(raw) => raw
                .split(',')
                .map(|s| {
                    s.trim().parse::<T>().map_err(|_| {
                        Error::Usage(format!("cannot parse {name} element '{s}'"))
                    })
                })
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Error if any option was given twice, or any `--options` remain
    /// that were never consumed.
    pub fn finish(self) -> Result<()> {
        if let Some(d) = self.dups.first() {
            return Err(Error::Usage(format!("option {d} given more than once")));
        }
        for k in self.opts.keys() {
            if !self.consumed.contains(k) {
                return Err(Error::Usage(format!("unknown option {k}")));
            }
        }
        for f in &self.flags {
            if !self.consumed.contains(f) {
                return Err(Error::Usage(format!("unknown flag {f}")));
            }
        }
        if !self.positionals.is_empty() {
            return Err(Error::Usage(format!(
                "unexpected argument '{}'",
                self.positionals[0]
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        // NB a bare flag followed by a non-flag token would consume it as
        // a value (grammar ambiguity) — flags go last or before options
        let mut a = Args::new(argv("bcast pos2 --gpus 16 --algo chain --verbose"));
        assert_eq!(a.positional().as_deref(), Some("bcast"));
        assert_eq!(a.opt_parse::<usize>("--gpus").unwrap(), Some(16));
        assert_eq!(a.opt("--algo").as_deref(), Some("chain"));
        assert!(a.flag("--verbose"));
        assert_eq!(a.positional().as_deref(), Some("pos2"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let mut a = Args::new(argv("--size=8K"));
        assert_eq!(a.opt("--size").as_deref(), Some("8K"));
    }

    #[test]
    fn default_values() {
        let mut a = Args::new(argv(""));
        assert_eq!(a.opt_or("--iters", 100usize).unwrap(), 100);
    }

    #[test]
    fn list_option() {
        let mut a = Args::new(argv("--gpus 2,4,8,16"));
        assert_eq!(
            a.opt_list::<usize>("--gpus").unwrap().unwrap(),
            vec![2, 4, 8, 16]
        );
    }

    #[test]
    fn choice_option() {
        let mut a = Args::new(argv("--exchange allreduce"));
        assert_eq!(
            a.opt_choice("--exchange", &["bcast", "allreduce"], "bcast")
                .unwrap(),
            "allreduce"
        );
        let mut b = Args::new(argv(""));
        assert_eq!(
            b.opt_choice("--exchange", &["bcast", "allreduce"], "bcast")
                .unwrap(),
            "bcast"
        );
        let mut c = Args::new(argv("--exchange bogus"));
        assert!(c.opt_choice("--exchange", &["bcast", "allreduce"], "bcast").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = Args::new(argv("--bogus 3"));
        let _ = a.opt("--real");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let mut a = Args::new(argv("--gpus banana"));
        assert!(a.opt_parse::<usize>("--gpus").is_err());
    }

    #[test]
    fn duplicate_option_rejected() {
        // last-one-wins used to swallow the first value silently
        let mut a = Args::new(argv("--gpus 4 --gpus 8"));
        assert_eq!(a.opt_parse::<usize>("--gpus").unwrap(), Some(8));
        let err = a.finish().unwrap_err();
        assert!(err.to_string().contains("more than once"), "{err}");
        // mixed `--k v` and `--k=v` forms count as the same option
        let mut b = Args::new(argv("--size=8K --size 16K"));
        let _ = b.opt("--size");
        assert!(b.finish().is_err());
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let mut a = Args::new(argv("--verbose --gpus 4"));
        assert!(a.flag("--verbose"));
        assert_eq!(a.opt_parse::<usize>("--gpus").unwrap(), Some(4));
    }
}
