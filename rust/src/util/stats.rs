//! Summary statistics used by the benchmark harness and reports.

/// Summary of a sample of observations (e.g. latencies in ns).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. NaN observations are dropped first (they carry
    /// no ordering information, and one of them used to poison every
    /// percentile through the sort); returns `None` when nothing remains
    /// — e.g. a Monte Carlo trial vector where every trial aborted.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| !x.is_nan()).collect();
        if sorted.is_empty() {
            return None;
        }
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        sorted.sort_by(f64::total_cmp);
        Some(Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0).expect("non-empty"),
            p90: percentile_sorted(&sorted, 90.0).expect("non-empty"),
            p99: percentile_sorted(&sorted, 99.0).expect("non-empty"),
        })
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample; `None`
/// on an empty one. The percentile itself must be in `[0, 100]` — that
/// is a caller bug, not a data condition, and still asserts.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> Option<f64> {
    assert!((0.0..=100.0).contains(&pct));
    if sorted.is_empty() {
        return None;
    }
    if sorted.len() == 1 {
        return Some(sorted[0]);
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    })
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]).unwrap();
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn summary_drops_nans() {
        // all-NaN collapses to the empty sample
        assert!(Summary::of(&[f64::NAN, f64::NAN]).is_none());
        // a NaN among real observations is ignored, not propagated
        let s = Summary::of(&[2.0, f64::NAN, 4.0]).unwrap();
        assert_eq!(s.n, 2);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 4.0);
        assert!(!s.p99.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0).unwrap() - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), Some(0.0));
        assert_eq!(percentile_sorted(&sorted, 100.0), Some(10.0));
    }

    #[test]
    fn percentile_empty_is_none() {
        assert_eq!(percentile_sorted(&[], 50.0), None);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let s = Summary::of(&xs).unwrap();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std_dev() - s.std_dev).abs() < 1e-9);
    }
}
