//! Zero-dependency substrates.
//!
//! The build environment is fully offline and its crate universe does not
//! include `rand`, `serde`, `clap`, `criterion` or `proptest`, so this
//! module provides the minimal from-scratch equivalents the rest of the
//! crate needs:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256** PRNGs,
//! * [`stats`] — summary statistics (mean/σ/percentiles) for benches,
//! * [`bytes`] — human size parsing/formatting (`"8K"`, `"128M"`),
//! * [`json`] — a small JSON writer for machine-readable reports,
//! * [`tablefmt`] — aligned plain-text tables for figure/table output,
//! * [`cli`] — a tiny argument parser (flags, options, subcommands),
//! * [`prop`] — a property-testing harness with shrinking.

pub mod bytes;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tablefmt;
