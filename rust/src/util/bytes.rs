//! Human-readable byte-size parsing and formatting.
//!
//! Micro-benchmark sweeps and the CLI use sizes like `4`, `8K`, `2M`,
//! `128M`; figures label axes the same way the paper does (powers of two,
//! IEC units).

use crate::error::{Error, Result};

/// Parse `"8K"`, `"2M"`, `"1G"`, `"512"` into bytes. Accepts an optional
/// `B`/`iB` suffix and lower/upper case.
pub fn parse_size(s: &str) -> Result<u64> {
    let t = s.trim();
    if t.is_empty() {
        return Err(Error::Usage("empty size".into()));
    }
    let up = t.to_ascii_uppercase();
    let up = up
        .strip_suffix("IB")
        .or_else(|| up.strip_suffix('B'))
        .unwrap_or(&up);
    let (num, mult) = match up.chars().last() {
        Some('K') => (&up[..up.len() - 1], 1u64 << 10),
        Some('M') => (&up[..up.len() - 1], 1u64 << 20),
        Some('G') => (&up[..up.len() - 1], 1u64 << 30),
        Some('T') => (&up[..up.len() - 1], 1u64 << 40),
        _ => (&up[..], 1u64),
    };
    let num = num.trim();
    let value: f64 = num
        .parse()
        .map_err(|_| Error::Usage(format!("cannot parse size '{s}'")))?;
    if value < 0.0 {
        return Err(Error::Usage(format!("negative size '{s}'")));
    }
    Ok((value * mult as f64).round() as u64)
}

/// Format bytes the way the paper's figures label them: `4`, `8K`, `2M`…
pub fn format_size(bytes: u64) -> String {
    const UNITS: [(u64, &str); 4] = [
        (1 << 40, "T"),
        (1 << 30, "G"),
        (1 << 20, "M"),
        (1 << 10, "K"),
    ];
    for (scale, suffix) in UNITS {
        if bytes >= scale && bytes % scale == 0 {
            return format!("{}{}", bytes / scale, suffix);
        }
    }
    for (scale, suffix) in UNITS {
        if bytes >= scale {
            return format!("{:.1}{}", bytes as f64 / scale as f64, suffix);
        }
    }
    format!("{bytes}")
}

/// The classic osu-benchmark sweep: powers of two from `lo` to `hi`
/// inclusive.
pub fn pow2_sweep(lo: u64, hi: u64) -> Vec<u64> {
    assert!(lo > 0 && lo <= hi);
    let mut out = Vec::new();
    let mut m = lo;
    while m <= hi {
        out.push(m);
        if m > hi / 2 {
            break;
        }
        m *= 2;
    }
    out
}

/// Format a nanosecond quantity as the paper reports latencies (µs).
pub fn format_us(ns: f64) -> String {
    let us = ns / 1000.0;
    if us >= 100_000.0 {
        format!("{:.0}", us)
    } else if us >= 100.0 {
        format!("{:.1}", us)
    } else {
        format!("{:.2}", us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain() {
        assert_eq!(parse_size("512").unwrap(), 512);
        assert_eq!(parse_size(" 4 ").unwrap(), 4);
    }

    #[test]
    fn parse_units() {
        assert_eq!(parse_size("8K").unwrap(), 8192);
        assert_eq!(parse_size("8k").unwrap(), 8192);
        assert_eq!(parse_size("2M").unwrap(), 2 << 20);
        assert_eq!(parse_size("1G").unwrap(), 1 << 30);
        assert_eq!(parse_size("8KB").unwrap(), 8192);
        assert_eq!(parse_size("8KiB").unwrap(), 8192);
        assert_eq!(parse_size("1.5K").unwrap(), 1536);
    }

    #[test]
    fn parse_errors() {
        assert!(parse_size("").is_err());
        assert!(parse_size("abc").is_err());
        assert!(parse_size("-4K").is_err());
    }

    #[test]
    fn format_roundtrip() {
        for s in ["4", "64", "8K", "256K", "2M", "128M", "1G"] {
            assert_eq!(format_size(parse_size(s).unwrap()), s);
        }
    }

    #[test]
    fn sweep_covers_range() {
        let s = pow2_sweep(4, 128 << 20);
        assert_eq!(s[0], 4);
        assert_eq!(*s.last().unwrap(), 128 << 20);
        assert_eq!(s.len(), 26);
        for w in s.windows(2) {
            assert_eq!(w[1], w[0] * 2);
        }
    }
}
