//! Aligned plain-text tables.
//!
//! Figure/table output from the benchmark harness is printed in the same
//! row/series structure as the paper's figures; this keeps the rendering
//! code out of the benches.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Table {
        self.title = Some(title.into());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with right-aligned numeric-looking cells.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                if looks_numeric(cell) {
                    line.extend(std::iter::repeat(' ').take(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.extend(std::iter::repeat(' ').take(pad));
                }
            }
            while line.ends_with(' ') {
                line.pop();
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.extend(std::iter::repeat('-').take(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

fn looks_numeric(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'x' | 'K' | 'M' | 'G' | '%' | 'X'))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["size", "NCCL (us)", "MV2-GDR-Opt (us)"]);
        t.row(vec!["4".into(), "28.10".into(), "2.01".into()]);
        t.row(vec!["128M".into(), "41820.55".into(), "40190.01".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width or less (trailing trim)
        assert!(lines[2].len() <= lines[1].len());
        assert!(s.contains("128M"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
