//! Deterministic pseudo-random number generators.
//!
//! Everything in the simulator and the property-testing harness must be
//! reproducible from a seed, so we implement two small, well-known PRNGs:
//! SplitMix64 (for seeding / cheap streams) and xoshiro256** (the general
//! purpose generator).

/// SplitMix64 — used to expand a single `u64` seed into streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free-ish multiply-shift; bias is
        // negligible for our bounds (<< 2^32) but we reject to be exact.
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, bound);
            if lo >= bound.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi]` (inclusive).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform `u64` in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64();
        }
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.range_usize(0, xs.len() - 1)]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::from(u32::MAX)] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            match r.range_usize(3, 5) {
                3 => saw_lo = true,
                5 => saw_hi = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
