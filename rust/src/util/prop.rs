//! A small property-testing harness with shrinking.
//!
//! `proptest` is not in the offline crate universe; this provides the
//! subset we use for simulator/collective invariants: seeded random case
//! generation, a fixed case budget, and greedy shrinking of failing cases
//! through a user-provided shrink function.
//!
//! ```
//! use gdrbcast::util::prop::{Config, check};
//! use gdrbcast::util::rng::Rng;
//! check(Config::default().cases(64), "sum-commutes",
//!     |rng: &mut Rng| (rng.range_u64(0, 100), rng.range_u64(0, 100)),
//!     |&(a, b)| if a + b == b + a { Ok(()) } else { Err("!".into()) },
//!     |_case| Vec::new());
//! ```

use super::rng::Rng;

/// Property-check configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            // override with GDRBCAST_PROP_SEED for exploration
            seed: std::env::var("GDRBCAST_PROP_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0xB0CA57),
            max_shrink_steps: 400,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run a property: generate `cases` random inputs, check each, and on
/// failure greedily shrink via `shrink` (which returns candidate smaller
/// cases) before panicking with the minimal counterexample.
pub fn check<T, G, P, S>(config: Config, name: &str, mut gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(config.seed);
    for case_no in 0..config.cases {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            // shrink
            let mut best = case.clone();
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: loop {
                if steps >= config.max_shrink_steps {
                    break;
                }
                for candidate in shrink(&best) {
                    steps += 1;
                    if steps >= config.max_shrink_steps {
                        break 'outer;
                    }
                    if let Err(m) = prop(&candidate) {
                        best = candidate;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case #{case_no}, seed {seed}):\n  \
                 counterexample: {best:?}\n  error: {best_msg}",
                seed = config.seed,
            );
        }
    }
}

/// Common shrink helper: all "halve it" and "decrement it" candidates for
/// an integer, largest reduction first.
pub fn shrink_u64(x: u64, lo: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if x > lo {
        out.push(lo);
        if x / 2 > lo {
            out.push(x / 2);
        }
        out.push(x - 1);
    }
    out
}

/// Shrink helper for usize.
pub fn shrink_usize(x: usize, lo: usize) -> Vec<usize> {
    shrink_u64(x as u64, lo as u64)
        .into_iter()
        .map(|v| v as usize)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config::default().cases(50),
            "add-commutes",
            |rng| (rng.range_u64(0, 1000), rng.range_u64(0, 1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
            |_| Vec::new(),
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            check(
                Config::default().cases(200).seed(3),
                "all-below-50",
                |rng| rng.range_u64(0, 1000),
                |&x| {
                    if x < 50 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 50"))
                    }
                },
                |&x| shrink_u64(x, 0),
            );
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // minimal counterexample is 50 exactly
        assert!(msg.contains("counterexample: 50"), "msg: {msg}");
    }

    #[test]
    fn shrink_helpers_reduce() {
        assert!(shrink_u64(100, 0).iter().all(|&v| v < 100));
        assert!(shrink_u64(0, 0).is_empty());
        assert_eq!(shrink_usize(1, 0), vec![0, 0]);
    }
}
