//! DNN model descriptors and CNTK-style broadcast message schedules.
//!
//! The paper motivates its designs with the parameter-exchange traffic of
//! real networks — LeNet, AlexNet, GoogLeNet, ResNet-50 and (for the
//! application study, Fig. 3) VGG-16. What the broadcast layer sees is
//! the *layer-size distribution*: VGG's 500+ MB of mostly-FC parameters
//! force large messages, GoogLeNet's 7 M parameters mean small/medium
//! traffic (§V-D). These descriptors carry exact layer shapes so the
//! benchmark harness replays realistic message mixes.

pub mod layer;
pub mod messages;
pub mod zoo;

pub use layer::{DnnModel, Layer};
pub use messages::{allreduce_buckets, bcast_messages, MessageSchedule, DEFAULT_BUCKET_BYTES};
pub use zoo::{alexnet, by_name, googlenet, lenet5, resnet50, vgg16, vgg_mini};
