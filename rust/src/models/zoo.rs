//! The model zoo: exact layer parameter inventories.
//!
//! Counts follow the original architecture papers. AlexNet is encoded
//! ungrouped (the two-GPU grouping of the 2012 paper halves some conv
//! params; broadcast traffic shape is unaffected). GoogLeNet's inception
//! modules are encoded per-branch.

use super::layer::DnnModel;

/// LeNet-5 (61,706 params / ~241 KB) — the small end of the spectrum.
pub fn lenet5() -> DnnModel {
    DnnModel::new("lenet5")
        .conv("conv1", 5, 5, 1, 6)
        .conv("conv2", 5, 5, 6, 16)
        .fc("fc1", 400, 120)
        .fc("fc2", 120, 84)
        .fc("fc3", 84, 10)
        .with_flops(4_200_000) // ~4.2 MFLOP fwd
}

/// AlexNet (~62.4 M params / ~250 MB), ungrouped.
pub fn alexnet() -> DnnModel {
    DnnModel::new("alexnet")
        .conv("conv1", 11, 11, 3, 96)
        .conv("conv2", 5, 5, 96, 256)
        .conv("conv3", 3, 3, 256, 384)
        .conv("conv4", 3, 3, 384, 384)
        .conv("conv5", 3, 3, 384, 256)
        .fc("fc6", 9216, 4096)
        .fc("fc7", 4096, 4096)
        .fc("fc8", 4096, 1000)
        .with_flops(720_000_000) // ~0.72 GFLOP fwd (227x227)
}

/// VGG-16 (~138.4 M params / ~553 MB) — the Fig. 3 workload. Its three
/// FC layers carry ~124 M of the parameters: mostly-large messages.
pub fn vgg16() -> DnnModel {
    DnnModel::new("vgg16")
        .conv("conv1_1", 3, 3, 3, 64)
        .conv("conv1_2", 3, 3, 64, 64)
        .conv("conv2_1", 3, 3, 64, 128)
        .conv("conv2_2", 3, 3, 128, 128)
        .conv("conv3_1", 3, 3, 128, 256)
        .conv("conv3_2", 3, 3, 256, 256)
        .conv("conv3_3", 3, 3, 256, 256)
        .conv("conv4_1", 3, 3, 256, 512)
        .conv("conv4_2", 3, 3, 512, 512)
        .conv("conv4_3", 3, 3, 512, 512)
        .conv("conv5_1", 3, 3, 512, 512)
        .conv("conv5_2", 3, 3, 512, 512)
        .conv("conv5_3", 3, 3, 512, 512)
        .fc("fc6", 25088, 4096)
        .fc("fc7", 4096, 4096)
        .fc("fc8", 4096, 1000)
        .with_flops(15_500_000_000) // ~15.5 GFLOP fwd (224x224)
}

/// GoogLeNet (~7.0 M params / ~28 MB) — "lesser number of parameters and
/// thus a small/medium message communication requirement" (§V-D).
pub fn googlenet() -> DnnModel {
    let mut m = DnnModel::new("googlenet")
        .conv("conv1", 7, 7, 3, 64)
        .conv("conv2_reduce", 1, 1, 64, 64)
        .conv("conv2", 3, 3, 64, 192);
    // (name, cin, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
    let inceptions: [(&str, u64, u64, u64, u64, u64, u64, u64); 9] = [
        ("3a", 192, 64, 96, 128, 16, 32, 32),
        ("3b", 256, 128, 128, 192, 32, 96, 64),
        ("4a", 480, 192, 96, 208, 16, 48, 64),
        ("4b", 512, 160, 112, 224, 24, 64, 64),
        ("4c", 512, 128, 128, 256, 24, 64, 64),
        ("4d", 512, 112, 144, 288, 32, 64, 64),
        ("4e", 528, 256, 160, 320, 32, 128, 128),
        ("5a", 832, 256, 160, 320, 32, 128, 128),
        ("5b", 832, 384, 192, 384, 48, 128, 128),
    ];
    for (name, cin, c1, c3r, c3, c5r, c5, pp) in inceptions {
        m = m
            .conv(&format!("i{name}.1x1"), 1, 1, cin, c1)
            .conv(&format!("i{name}.3x3r"), 1, 1, cin, c3r)
            .conv(&format!("i{name}.3x3"), 3, 3, c3r, c3)
            .conv(&format!("i{name}.5x5r"), 1, 1, cin, c5r)
            .conv(&format!("i{name}.5x5"), 5, 5, c5r, c5)
            .conv(&format!("i{name}.pool"), 1, 1, cin, pp);
    }
    m.fc("fc", 1024, 1000).with_flops(1_600_000_000) // ~1.6 GFLOP fwd
}

/// ResNet-50 (~25.6 M params / ~102 MB), encoded per bottleneck block.
pub fn resnet50() -> DnnModel {
    let mut m = DnnModel::new("resnet50").conv("conv1", 7, 7, 3, 64);
    // (stage, blocks, cin_first, mid, cout)
    let stages: [(&str, u64, u64, u64, u64); 4] = [
        ("conv2", 3, 64, 64, 256),
        ("conv3", 4, 256, 128, 512),
        ("conv4", 6, 512, 256, 1024),
        ("conv5", 3, 1024, 512, 2048),
    ];
    for (stage, blocks, cin_first, mid, cout) in stages {
        for b in 0..blocks {
            let cin = if b == 0 { cin_first } else { cout };
            m = m
                .conv(&format!("{stage}_{b}.a"), 1, 1, cin, mid)
                .conv(&format!("{stage}_{b}.b"), 3, 3, mid, mid)
                .conv(&format!("{stage}_{b}.c"), 1, 1, mid, cout);
            if b == 0 {
                m = m.conv(&format!("{stage}_{b}.down"), 1, 1, cin, cout);
            }
        }
    }
    m.fc("fc", 2048, 1000).with_flops(3_900_000_000) // ~3.9 GFLOP fwd
}

/// VGG-mini: the E2E training workload (the AOT-compiled JAX model in
/// `python/compile/model.py`). A VGG-spirit MLP over 32×32×3 inputs —
/// small enough to train on CPU PJRT in the e2e_train example, with the
/// same "few huge FC layers + small biases" message-size signature.
pub fn vgg_mini() -> DnnModel {
    DnnModel::new("vgg-mini")
        .fc("fc1", 3072, 512)
        .fc("fc2", 512, 256)
        .fc("fc3", 256, 10)
        .with_flops(3_500_000) // ~2 x 1.74M params
}

/// Look up a model by CLI name.
pub fn by_name(name: &str) -> Option<DnnModel> {
    match name.to_ascii_lowercase().as_str() {
        "lenet" | "lenet5" => Some(lenet5()),
        "alexnet" => Some(alexnet()),
        "vgg" | "vgg16" => Some(vgg16()),
        "googlenet" => Some(googlenet()),
        "resnet" | "resnet50" => Some(resnet50()),
        "vgg-mini" | "vggmini" => Some(vgg_mini()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_exact() {
        assert_eq!(lenet5().total_params(), 61_706);
    }

    #[test]
    fn vgg16_close_to_138m() {
        let p = vgg16().total_params();
        assert!((p as i64 - 138_357_544).abs() < 10, "got {p}");
    }

    #[test]
    fn alexnet_around_62m() {
        let p = alexnet().total_params();
        assert!((60_000_000..66_000_000).contains(&p), "got {p}");
    }

    #[test]
    fn googlenet_around_7m() {
        let p = googlenet().total_params();
        assert!((5_500_000..7_500_000).contains(&p), "got {p}");
    }

    #[test]
    fn resnet50_around_25m() {
        let p = resnet50().total_params();
        assert!((23_000_000..27_000_000).contains(&p), "got {p}");
    }

    #[test]
    fn vgg_has_mostly_large_bytes() {
        let m = vgg16();
        let h = m.size_class_histogram();
        // FC weights are "very large"; biases are small — the §V-D mix
        assert!(h[3] >= 3);
        assert!(h[0] >= 10);
    }

    #[test]
    fn zoo_lookup() {
        for name in ["lenet", "alexnet", "vgg16", "googlenet", "resnet50", "vgg-mini"] {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("skynet").is_none());
    }
}
