//! CNTK-style broadcast message schedules (§V-D).
//!
//! CA-CNTK exchanges training parameters with `MPI_Bcast` every
//! iteration. The paper notes that "CNTK divides the communication based
//! on the process count so the message-sizes can vary considerably":
//! the flattened parameter vector is partitioned across ranks, each rank
//! broadcasting its block after aggregation. We model both that
//! partitioned schedule and the simpler per-layer one.

use super::layer::DnnModel;

/// How parameters map onto broadcast calls.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MessageSchedule {
    /// One `MPI_Bcast` per parameter tensor, rooted at rank 0 (parameter-
    /// server style). Message sizes = layer sizes.
    PerLayer,
    /// The flattened parameter vector is split into `n_ranks` near-equal
    /// blocks; block `i` is broadcast from rank `i` (CNTK data-parallel
    /// aggregation). Message sizes ≈ total/n — they shrink as the job
    /// scales, which is exactly the §V-D observation.
    Partitioned,
}

/// A broadcast call in the per-iteration schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BcastMsg {
    pub root: usize,
    pub bytes: u64,
}

/// The per-iteration broadcast calls for a model at a given scale.
pub fn bcast_messages(model: &DnnModel, n_ranks: usize, schedule: MessageSchedule) -> Vec<BcastMsg> {
    assert!(n_ranks >= 1);
    match schedule {
        MessageSchedule::PerLayer => model
            .layers
            .iter()
            .map(|l| BcastMsg {
                root: 0,
                bytes: l.bytes(),
            })
            .collect(),
        MessageSchedule::Partitioned => {
            let total = model.total_bytes();
            crate::comm::chunk::equal_parts(total, n_ranks)
                .into_iter()
                .enumerate()
                .map(|(i, bytes)| BcastMsg { root: i, bytes })
                .collect()
        }
    }
}

/// Total bytes a schedule moves per iteration (must equal the model size).
pub fn schedule_bytes(msgs: &[BcastMsg]) -> u64 {
    msgs.iter().map(|m| m.bytes).sum()
}

/// Default gradient-fusion bucket for the allreduce schedule (the
/// Horovod/DDP-style fusion size; large enough to amortise per-call
/// startup, small enough to overlap buckets on the fabric).
pub const DEFAULT_BUCKET_BYTES: u64 = 32 << 20;

/// The per-iteration allreduce calls for gradient-averaging training:
/// the flattened gradient vector (same length as the parameters) fused
/// into buckets of at most `bucket_bytes`. Returns the bucket sizes —
/// allreduce has no root, so unlike [`BcastMsg`] there is nothing else
/// to carry.
pub fn allreduce_buckets(model: &DnnModel, bucket_bytes: u64) -> Vec<u64> {
    crate::comm::chunk::chunk_sizes(model.total_bytes(), bucket_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{googlenet, vgg16};

    #[test]
    fn per_layer_matches_layer_sizes() {
        let m = vgg16();
        let msgs = bcast_messages(&m, 32, MessageSchedule::PerLayer);
        assert_eq!(msgs.len(), m.layers.len());
        assert_eq!(schedule_bytes(&msgs), m.total_bytes());
        assert!(msgs.iter().all(|msg| msg.root == 0));
    }

    #[test]
    fn partitioned_shrinks_with_scale() {
        let m = vgg16();
        let at8 = bcast_messages(&m, 8, MessageSchedule::Partitioned);
        let at128 = bcast_messages(&m, 128, MessageSchedule::Partitioned);
        assert_eq!(at8.len(), 8);
        assert_eq!(at128.len(), 128);
        assert!(at8[0].bytes > at128[0].bytes * 10);
        assert_eq!(schedule_bytes(&at8), m.total_bytes());
        assert_eq!(schedule_bytes(&at128), m.total_bytes());
    }

    #[test]
    fn partitioned_roots_rotate() {
        let m = googlenet();
        let msgs = bcast_messages(&m, 4, MessageSchedule::Partitioned);
        let roots: Vec<usize> = msgs.iter().map(|m| m.root).collect();
        assert_eq!(roots, vec![0, 1, 2, 3]);
    }

    #[test]
    fn allreduce_buckets_cover_model() {
        let m = vgg16();
        let buckets = allreduce_buckets(&m, DEFAULT_BUCKET_BYTES);
        assert_eq!(buckets.iter().sum::<u64>(), m.total_bytes());
        assert!(buckets.len() > 1, "VGG must span multiple buckets");
        assert!(buckets.iter().all(|&b| b <= DEFAULT_BUCKET_BYTES));
    }

    #[test]
    fn googlenet_partitioned_is_small_medium_at_scale() {
        // §V-D: GoogLeNet at 128 ranks -> ~220 KB messages (medium)
        let m = googlenet();
        let msgs = bcast_messages(&m, 128, MessageSchedule::Partitioned);
        assert!(msgs[0].bytes < 512 << 10);
        assert!(msgs[0].bytes > 8 << 10);
    }
}
