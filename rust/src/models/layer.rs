//! Model/layer descriptors.

/// One parameter tensor (weights of a conv/FC layer, or its bias).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    /// Number of scalar parameters.
    pub params: u64,
}

impl Layer {
    pub fn new(name: impl Into<String>, params: u64) -> Layer {
        Layer {
            name: name.into(),
            params,
        }
    }

    /// Bytes at fp32.
    pub fn bytes(&self) -> u64 {
        self.params * 4
    }
}

/// A DNN as its broadcastable parameter inventory.
#[derive(Debug, Clone)]
pub struct DnnModel {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Forward-pass FLOPs per sample (for the compute-time model in
    /// `coordinator::train`; backward ≈ 2× forward).
    pub fwd_flops: u64,
}

impl DnnModel {
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes()).sum()
    }

    /// Layer-size histogram against the paper's message classes:
    /// small (≤8 KB), medium (≤512 KB), large (≤8 MB), very large (>8 MB).
    pub fn size_class_histogram(&self) -> [usize; 4] {
        let mut h = [0usize; 4];
        for l in &self.layers {
            let b = l.bytes();
            let idx = if b <= 8 << 10 {
                0
            } else if b <= 512 << 10 {
                1
            } else if b <= 8 << 20 {
                2
            } else {
                3
            };
            h[idx] += 1;
        }
        h
    }

    /// Helper: add a conv layer (kh × kw × cin × cout weights + bias).
    pub fn conv(
        mut self,
        name: &str,
        kh: u64,
        kw: u64,
        cin: u64,
        cout: u64,
    ) -> DnnModel {
        self.layers
            .push(Layer::new(format!("{name}.w"), kh * kw * cin * cout));
        self.layers.push(Layer::new(format!("{name}.b"), cout));
        self
    }

    /// Helper: add a fully-connected layer (in × out weights + bias).
    pub fn fc(mut self, name: &str, cin: u64, cout: u64) -> DnnModel {
        self.layers.push(Layer::new(format!("{name}.w"), cin * cout));
        self.layers.push(Layer::new(format!("{name}.b"), cout));
        self
    }

    pub fn new(name: impl Into<String>) -> DnnModel {
        DnnModel {
            name: name.into(),
            layers: Vec::new(),
            fwd_flops: 0,
        }
    }

    /// Set the forward FLOPs-per-sample estimate.
    pub fn with_flops(mut self, fwd_flops: u64) -> DnnModel {
        self.fwd_flops = fwd_flops;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_counts() {
        let m = DnnModel::new("toy").conv("c1", 3, 3, 3, 64).fc("f1", 100, 10);
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.total_params(), 3 * 3 * 3 * 64 + 64 + 1000 + 10);
        assert_eq!(m.total_bytes(), m.total_params() * 4);
    }

    #[test]
    fn histogram_buckets() {
        let mut m = DnnModel::new("h");
        m.layers.push(Layer::new("tiny", 10)); // 40 B -> small
        m.layers.push(Layer::new("mid", 20_000)); // 80 KB -> medium
        m.layers.push(Layer::new("big", 1 << 20)); // 4 MB -> large
        m.layers.push(Layer::new("huge", 30 << 20)); // 120 MB -> very large
        assert_eq!(m.size_class_histogram(), [1, 1, 1, 1]);
    }
}
