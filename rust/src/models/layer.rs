//! Model/layer descriptors.

/// One parameter tensor (weights of a conv/FC layer, or its bias).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    /// Number of scalar parameters.
    pub params: u64,
}

impl Layer {
    pub fn new(name: impl Into<String>, params: u64) -> Layer {
        Layer {
            name: name.into(),
            params,
        }
    }

    /// Bytes at fp32.
    pub fn bytes(&self) -> u64 {
        self.params * 4
    }
}

/// A DNN as its broadcastable parameter inventory.
#[derive(Debug, Clone)]
pub struct DnnModel {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Forward-pass FLOPs per sample (for the compute-time model in
    /// `coordinator::train`; backward ≈ 2× forward).
    pub fwd_flops: u64,
}

impl DnnModel {
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes()).sum()
    }

    /// Layer-size histogram against the paper's message classes:
    /// small (≤8 KB), medium (≤512 KB), large (≤8 MB), very large (>8 MB).
    pub fn size_class_histogram(&self) -> [usize; 4] {
        let mut h = [0usize; 4];
        for l in &self.layers {
            let b = l.bytes();
            let idx = if b <= 8 << 10 {
                0
            } else if b <= 512 << 10 {
                1
            } else if b <= 8 << 20 {
                2
            } else {
                3
            };
            h[idx] += 1;
        }
        h
    }

    /// Helper: add a conv layer (kh × kw × cin × cout weights + bias).
    pub fn conv(
        mut self,
        name: &str,
        kh: u64,
        kw: u64,
        cin: u64,
        cout: u64,
    ) -> DnnModel {
        self.layers
            .push(Layer::new(format!("{name}.w"), kh * kw * cin * cout));
        self.layers.push(Layer::new(format!("{name}.b"), cout));
        self
    }

    /// Helper: add a fully-connected layer (in × out weights + bias).
    pub fn fc(mut self, name: &str, cin: u64, cout: u64) -> DnnModel {
        self.layers.push(Layer::new(format!("{name}.w"), cin * cout));
        self.layers.push(Layer::new(format!("{name}.b"), cout));
        self
    }

    pub fn new(name: impl Into<String>) -> DnnModel {
        DnnModel {
            name: name.into(),
            layers: Vec::new(),
            fwd_flops: 0,
        }
    }

    /// Set the forward FLOPs-per-sample estimate.
    pub fn with_flops(mut self, fwd_flops: u64) -> DnnModel {
        self.fwd_flops = fwd_flops;
        self
    }

    /// Apportion a per-iteration backprop compute budget (ns) across the
    /// layers, proportional to each layer's parameter count — the
    /// per-layer `Delay` durations the overlap timeline emits in reverse
    /// layer order ([`crate::coordinator::timeline`]). The split is
    /// exact: the pieces always sum to `total_ns` (cumulative rounding,
    /// so no layer is off by more than one ns from proportional).
    /// Parameter-free models split the budget equally.
    pub fn layer_compute_split(&self, total_ns: u64) -> Vec<u64> {
        let n = self.layers.len();
        if n == 0 {
            return Vec::new();
        }
        let params = self.total_params();
        // weight by params; all-zero models fall back to uniform weights
        let uniform = params == 0;
        let total_weight = if uniform { n as u64 } else { params };
        let mut out = Vec::with_capacity(n);
        let mut acc = 0u64;
        let mut prev = 0u64;
        for layer in &self.layers {
            acc += if uniform { 1 } else { layer.params };
            // u128: total_ns × params overflows u64 for real models
            let upto = (total_ns as u128 * acc as u128 / total_weight as u128) as u64;
            out.push(upto - prev);
            prev = upto;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_counts() {
        let m = DnnModel::new("toy").conv("c1", 3, 3, 3, 64).fc("f1", 100, 10);
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.total_params(), 3 * 3 * 3 * 64 + 64 + 1000 + 10);
        assert_eq!(m.total_bytes(), m.total_params() * 4);
    }

    #[test]
    fn layer_compute_split_is_exact_and_proportional() {
        let m = DnnModel::new("toy").conv("c1", 3, 3, 3, 64).fc("f1", 100, 10);
        let total: u64 = 1_000_000;
        let split = m.layer_compute_split(total);
        assert_eq!(split.len(), m.layers.len());
        assert_eq!(split.iter().sum::<u64>(), total, "split must be exact");
        // the dominant layer gets the dominant share
        let (imax, _) = m
            .layers
            .iter()
            .enumerate()
            .max_by_key(|(_, l)| l.params)
            .unwrap();
        assert_eq!(
            split.iter().enumerate().max_by_key(|&(_, &ns)| ns).unwrap().0,
            imax
        );
        // zero budget -> all-zero pieces; zero-param model -> uniform
        assert!(m.layer_compute_split(0).iter().all(|&ns| ns == 0));
        let mut flat = DnnModel::new("z");
        flat.layers.push(Layer::new("a", 0));
        flat.layers.push(Layer::new("b", 0));
        assert_eq!(flat.layer_compute_split(10), vec![5, 5]);
        assert!(DnnModel::new("empty").layer_compute_split(7).is_empty());
    }

    #[test]
    fn histogram_buckets() {
        let mut m = DnnModel::new("h");
        m.layers.push(Layer::new("tiny", 10)); // 40 B -> small
        m.layers.push(Layer::new("mid", 20_000)); // 80 KB -> medium
        m.layers.push(Layer::new("big", 1 << 20)); // 4 MB -> large
        m.layers.push(Layer::new("huge", 30 << 20)); // 120 MB -> very large
        assert_eq!(m.size_class_histogram(), [1, 1, 1, 1]);
    }
}
