//! Ring reduce-scatter: the buffer is split into `n` near-equal segments;
//! at step `t` every rank forwards one accumulating segment to its right
//! neighbour, which reduces it into its own partial. After `n−1` steps
//! rank `s` holds segment `s` of the full reduction, having moved only
//! `(n−1)/n × M` bytes per rank — the bandwidth-optimal first half of the
//! ring allreduce.
//!
//! `T = (n−1) × (t_s + M/(nB))`
//!
//! Reduction arithmetic is modelled as free: the simulator times
//! transfers, and on-GPU element-wise adds run orders of magnitude faster
//! than the fabric moves the operands.

use crate::comm::{chunk::equal_parts, Comm};
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{CollectiveKind, CollectivePlan, CollectiveSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &CollectiveSpec) -> CollectivePlan {
    template(comm, spec).cp
}

pub fn template(comm: &mut Comm, spec: &CollectiveSpec) -> CollectiveTemplate {
    debug_assert_eq!(spec.kind, CollectiveKind::ReduceScatter);
    let n = spec.n_ranks;
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if n == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: CollectivePlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: "ring-reduce-scatter".into(),
            },
        };
    }
    let parts = equal_parts(spec.bytes, n);
    // acc[v][s] = op after which rank v's partial for segment s contains
    // every upstream contribution (None = own contribution only)
    let mut acc: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];
    for t in 0..n - 1 {
        let mut arrivals: Vec<(usize, usize, OpId)> = Vec::new();
        for v in 0..n {
            // the segment that ends at rank s travels s+1 -> s+2 -> … -> s;
            // at step t rank v carries segment (v - t - 1) mod n
            let s = (v + n - t - 1) % n;
            let dst = (v + 1) % n;
            let deps = Deps::from_opt(acc[v][s]);
            // only the last hop delivers the fully reduced segment
            let label = if t == n - 2 { Some((dst, s)) } else { None };
            let mark = plan.len();
            let op = comm.send(&mut plan, v, dst, parts[s], deps, label);
            rec.tag(
                &plan,
                mark,
                ByteRole::Part {
                    index: s as u32,
                    of: n as u32,
                },
                comm.size_class_of(parts[s]),
            );
            edges.push(FlowEdge::reduce(v, dst, s, op));
            arrivals.push((dst, s, op));
        }
        for (dst, s, op) in arrivals {
            acc[dst][s] = Some(op);
        }
    }
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: CollectivePlan {
            plan,
            edges,
            n_chunks: n,
            spec: spec.clone(),
            algorithm: "ring-reduce-scatter".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::validate::validate;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn every_segment_fully_reduced_at_its_owner() {
        let c = flat(6).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::reduce_scatter(6, 6000);
        let cp = plan(&mut comm, &spec);
        let result = engine.execute(&cp.plan);
        validate(&cp, &result).unwrap();
        // delivery labels: rank s receives its segment s exactly once
        for s in 0..6 {
            assert!(
                result.delivery_time(&cp.plan, s, s).is_some(),
                "segment {s} never delivered to its owner"
            );
        }
    }

    #[test]
    fn traffic_is_n_minus_one_over_n() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let m: u64 = 8 << 20;
        let spec = CollectiveSpec::reduce_scatter(8, m);
        let cp = plan(&mut comm, &spec);
        // each of the 8 ranks moves (n-1) segments of M/n
        assert_eq!(cp.plan.total_bytes(), (8 - 1) * m);
    }

    #[test]
    fn single_rank_noop() {
        let c = flat(1).unwrap();
        let mut comm = Comm::new(&c);
        let spec = CollectiveSpec::reduce_scatter(1, 100);
        let cp = plan(&mut comm, &spec);
        assert!(cp.plan.is_empty());
        assert_eq!(cp.n_chunks, 1);
    }

    #[test]
    fn odd_rank_count_and_indivisible_bytes() {
        let c = flat(7).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::reduce_scatter(7, 7013);
        let cp = plan(&mut comm, &spec);
        let result = engine.execute(&cp.plan);
        validate(&cp, &result).unwrap();
    }

    #[test]
    fn cost_matches_ring_model_on_flat() {
        // (n-1) pipelined steps; each step costs one segment hop
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m: u64 = 8 << 20;
        let hop = comm.estimate_ns(0, 1, m / 8);
        let spec = CollectiveSpec::reduce_scatter(8, m);
        let cp = plan(&mut comm, &spec);
        let r = engine.execute(&cp.plan);
        assert_eq!(r.makespan, 7 * hop);
    }
}
