//! Common types for collective algorithms.
//!
//! The paper's scope is `MPI_Bcast`, but the framework is
//! collective-agnostic: a [`CollectiveSpec`] names the operation
//! ([`CollectiveKind`]), a [`CollectivePlan`] carries its netsim op DAG
//! plus rank-level [`FlowEdge`]s whose [`EdgeSem`] (copy vs reduce) lets
//! [`super::validate`] check reduction dataflow, not just delivery
//! causality. `BcastSpec`/`BcastPlan` remain as thin aliases so the
//! original broadcast builders read unchanged.

use crate::netsim::{OpEnd, OpId, Plan};
use crate::topology::{Cluster, DeviceId};

/// Which collective operation a spec describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CollectiveKind {
    /// Rooted one-to-all copy (the paper's subject).
    Broadcast,
    /// Every rank contributes a full buffer; rank `s` ends with segment
    /// `s` of the element-wise reduction.
    ReduceScatter,
    /// Rank `r` contributes segment `r`; every rank ends with the full
    /// concatenation.
    Allgather,
    /// Every rank contributes a full buffer; every rank ends with the
    /// full element-wise reduction.
    Allreduce,
}

impl CollectiveKind {
    /// Every supported kind (tuning sweeps iterate this).
    pub const ALL: [CollectiveKind; 4] = [
        CollectiveKind::Broadcast,
        CollectiveKind::ReduceScatter,
        CollectiveKind::Allgather,
        CollectiveKind::Allreduce,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::Broadcast => "broadcast",
            CollectiveKind::ReduceScatter => "reduce-scatter",
            CollectiveKind::Allgather => "allgather",
            CollectiveKind::Allreduce => "allreduce",
        }
    }

    pub fn parse(s: &str) -> Option<CollectiveKind> {
        match s.to_ascii_lowercase().as_str() {
            "broadcast" | "bcast" => Some(CollectiveKind::Broadcast),
            "reduce-scatter" | "reducescatter" => Some(CollectiveKind::ReduceScatter),
            "allgather" => Some(CollectiveKind::Allgather),
            "allreduce" => Some(CollectiveKind::Allreduce),
            _ => None,
        }
    }

    /// Whether the operation distinguishes a root rank.
    pub fn is_rooted(&self) -> bool {
        matches!(self, CollectiveKind::Broadcast)
    }
}

/// What to run: one collective over `n_ranks` ranks moving `bytes` of
/// payload. `bytes` is the full buffer size for broadcast/reduce-scatter/
/// allreduce and the gathered total for allgather. `root` matters only
/// for rooted kinds (and as the internal tree root for tree allreduce).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveSpec {
    pub kind: CollectiveKind,
    /// Root rank (rooted collectives; tree pivot otherwise).
    pub root: usize,
    /// Number of participating ranks (0..n, must match cluster GPUs).
    pub n_ranks: usize,
    /// Message size in bytes.
    pub bytes: u64,
}

impl CollectiveSpec {
    /// A broadcast spec — the historical constructor, kept with its
    /// original three-argument shape so `BcastSpec::new` call sites stay
    /// unchanged.
    pub fn new(root: usize, n_ranks: usize, bytes: u64) -> CollectiveSpec {
        CollectiveSpec::collective(CollectiveKind::Broadcast, root, n_ranks, bytes)
    }

    /// A spec for any collective kind.
    pub fn collective(
        kind: CollectiveKind,
        root: usize,
        n_ranks: usize,
        bytes: u64,
    ) -> CollectiveSpec {
        assert!(n_ranks >= 1, "need at least one rank");
        assert!(root < n_ranks, "root out of range");
        CollectiveSpec {
            kind,
            root,
            n_ranks,
            bytes,
        }
    }

    /// An allreduce over all ranks (root 0 by convention).
    pub fn allreduce(n_ranks: usize, bytes: u64) -> CollectiveSpec {
        CollectiveSpec::collective(CollectiveKind::Allreduce, 0, n_ranks, bytes)
    }

    /// A reduce-scatter over all ranks.
    pub fn reduce_scatter(n_ranks: usize, bytes: u64) -> CollectiveSpec {
        CollectiveSpec::collective(CollectiveKind::ReduceScatter, 0, n_ranks, bytes)
    }

    /// An allgather over all ranks.
    pub fn allgather(n_ranks: usize, bytes: u64) -> CollectiveSpec {
        CollectiveSpec::collective(CollectiveKind::Allgather, 0, n_ranks, bytes)
    }

    /// Relabel rank `r` so the root is 0 (the usual trick for rooted
    /// collectives).
    #[inline]
    pub fn relabel(&self, r: usize) -> usize {
        (r + self.n_ranks - self.root) % self.n_ranks
    }

    /// Inverse of [`Self::relabel`].
    #[inline]
    pub fn unlabel(&self, v: usize) -> usize {
        (v + self.root) % self.n_ranks
    }
}

/// Historical alias: the broadcast-only name the original builders used.
pub type BcastSpec = CollectiveSpec;

/// What an incoming transfer does to the destination's buffer for that
/// chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeSem {
    /// Destination replaces its chunk content with the payload.
    Copy,
    /// Destination combines the payload into its own partial
    /// (element-wise reduction).
    Reduce,
}

/// A rank-level data-flow edge: "src sent chunk to dst; the final netsim
/// op of that send is `op`; on arrival dst applies `sem`". Used by
/// [`super::validate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowEdge {
    pub src: usize,
    pub dst: usize,
    pub chunk: usize,
    pub op: OpId,
    pub sem: EdgeSem,
}

impl FlowEdge {
    /// A copy edge (broadcast/allgather dataflow).
    pub fn copy(src: usize, dst: usize, chunk: usize, op: OpId) -> FlowEdge {
        FlowEdge {
            src,
            dst,
            chunk,
            op,
            sem: EdgeSem::Copy,
        }
    }

    /// A reduce edge (reduce-scatter/allreduce dataflow).
    pub fn reduce(src: usize, dst: usize, chunk: usize, op: OpId) -> FlowEdge {
        FlowEdge {
            src,
            dst,
            chunk,
            op,
            sem: EdgeSem::Reduce,
        }
    }
}

/// A built collective: ops + flow edges + chunk accounting.
#[derive(Debug, Clone)]
pub struct CollectivePlan {
    pub plan: Plan,
    pub edges: Vec<FlowEdge>,
    pub n_chunks: usize,
    pub spec: CollectiveSpec,
    pub algorithm: String,
}

impl CollectivePlan {
    /// Per-rank *entry* ops: ops with no in-plan dependencies, grouped
    /// by the rank owning the op's source device (a transfer's route
    /// source, a delay's device). These are the ops an external
    /// scheduler must gate to make the whole collective wait on
    /// per-rank preconditions — the overlap timeline hangs each rank's
    /// backprop delays off them ([`crate::coordinator::timeline`]).
    /// Entries whose source device is not a rank GPU are conservatively
    /// listed under every rank (gating them on anyone's precondition
    /// gates them on all).
    pub fn rank_entry_ops(&self, cluster: &Cluster) -> Vec<Vec<OpId>> {
        let n = self.spec.n_ranks;
        let mut out = vec![Vec::new(); n];
        for id in 0..self.plan.len() {
            if !self.plan.deps[id].is_empty() {
                continue;
            }
            let src = match self.plan.ends[id] {
                OpEnd::Route(route) => cluster.route_meta(route).src,
                OpEnd::Dev(dev) => dev,
            };
            match rank_of(cluster, src) {
                Some(r) if r < n => out[r].push(id),
                _ => {
                    for per_rank in out.iter_mut() {
                        per_rank.push(id);
                    }
                }
            }
        }
        out
    }

    /// Per-rank *exit* ops: ops no other op depends on, grouped by the
    /// receiving rank (the delivery label's rank when present, the route
    /// destination's owning rank otherwise). Exposed so schedulers can
    /// hang follow-on work off a specific rank's completions without
    /// rescanning the op list. Exits attributable to no rank GPU are
    /// listed under every rank.
    pub fn rank_exit_ops(&self, cluster: &Cluster) -> Vec<Vec<OpId>> {
        let n = self.spec.n_ranks;
        let has_dependent = self.plan.dependent_flags();
        let mut out = vec![Vec::new(); n];
        for id in 0..self.plan.len() {
            if has_dependent[id] {
                continue;
            }
            let rank = match self.plan.labels[id] {
                Some((r, _)) if r < n => Some(r),
                _ => {
                    let dst = match self.plan.ends[id] {
                        OpEnd::Route(route) => cluster.route_meta(route).dst,
                        OpEnd::Dev(dev) => dev,
                    };
                    rank_of(cluster, dst).filter(|&r| r < n)
                }
            };
            match rank {
                Some(r) => out[r].push(id),
                None => {
                    for per_rank in out.iter_mut() {
                        per_rank.push(id);
                    }
                }
            }
        }
        out
    }
}

/// The rank owning a GPU device, if any.
fn rank_of(cluster: &Cluster, dev: DeviceId) -> Option<usize> {
    cluster.gpu_ranks().iter().position(|&d| d == dev)
}

/// Historical alias for the broadcast builders.
pub type BcastPlan = CollectivePlan;

/// The algorithm menu (what the tuning framework selects over).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Serialized root-sends-to-all loop (Eq. 1). Never wins; baseline.
    Direct,
    /// Store-and-forward chain (Eq. 2).
    Chain,
    /// The paper's contribution: chunked, pipelined chain (Eq. 5).
    PipelinedChain { chunk: u64 },
    /// K-nomial tree (Eq. 3); binomial at k = 2.
    Knomial { k: usize },
    /// Binomial scatter + ring allgather (Eq. 4) — bandwidth-optimal for
    /// large M.
    ScatterRingAllgather,
    /// Host-staged k-nomial (Eq. 6) — the GPU-specific small-message
    /// optimisation of §IV-C.
    HostStagedKnomial { k: usize },
    /// Ring reduce-scatter: the accumulating segment walks the ring.
    RingReduceScatter,
    /// Ring allgather: every rank's segment walks the ring.
    RingAllgather,
    /// Ring allreduce = ring reduce-scatter + ring allgather —
    /// bandwidth-optimal gradient reduction (2·(n−1)/n · M per rank).
    RingAllreduce,
    /// K-nomial reduce to the root followed by a k-nomial broadcast —
    /// the latency-optimal allreduce for small messages.
    TreeAllreduce { k: usize },
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::Direct => "direct".into(),
            Algorithm::Chain => "chain".into(),
            Algorithm::PipelinedChain { chunk } => {
                format!("pipelined-chain(C={})", crate::util::bytes::format_size(*chunk))
            }
            Algorithm::Knomial { k } => format!("knomial(k={k})"),
            Algorithm::ScatterRingAllgather => "scatter-ring-allgather".into(),
            Algorithm::HostStagedKnomial { k } => format!("host-staged-knomial(k={k})"),
            Algorithm::RingReduceScatter => "ring-reduce-scatter".into(),
            Algorithm::RingAllgather => "ring-allgather".into(),
            Algorithm::RingAllreduce => "ring-allreduce".into(),
            Algorithm::TreeAllreduce { k } => format!("tree-allreduce(k={k})"),
        }
    }

    /// Stable identifier without parameters (tuning-table keys).
    pub fn family(&self) -> &'static str {
        match self {
            Algorithm::Direct => "direct",
            Algorithm::Chain => "chain",
            Algorithm::PipelinedChain { .. } => "pipelined-chain",
            Algorithm::Knomial { .. } => "knomial",
            Algorithm::ScatterRingAllgather => "scatter-ring-allgather",
            Algorithm::HostStagedKnomial { .. } => "host-staged-knomial",
            Algorithm::RingReduceScatter => "ring-reduce-scatter",
            Algorithm::RingAllgather => "ring-allgather",
            Algorithm::RingAllreduce => "ring-allreduce",
            Algorithm::TreeAllreduce { .. } => "tree-allreduce",
        }
    }

    /// The collective this algorithm implements.
    pub fn kind(&self) -> CollectiveKind {
        match self {
            Algorithm::Direct
            | Algorithm::Chain
            | Algorithm::PipelinedChain { .. }
            | Algorithm::Knomial { .. }
            | Algorithm::ScatterRingAllgather
            | Algorithm::HostStagedKnomial { .. } => CollectiveKind::Broadcast,
            Algorithm::RingReduceScatter => CollectiveKind::ReduceScatter,
            Algorithm::RingAllgather => CollectiveKind::Allgather,
            Algorithm::RingAllreduce | Algorithm::TreeAllreduce { .. } => {
                CollectiveKind::Allreduce
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relabel_roundtrip() {
        let spec = BcastSpec::new(3, 8, 100);
        for r in 0..8 {
            assert_eq!(spec.unlabel(spec.relabel(r)), r);
        }
        assert_eq!(spec.relabel(3), 0);
    }

    #[test]
    #[should_panic]
    fn root_out_of_range_panics() {
        BcastSpec::new(8, 8, 1);
    }

    #[test]
    fn algorithm_names() {
        assert_eq!(Algorithm::Knomial { k: 2 }.name(), "knomial(k=2)");
        assert_eq!(
            Algorithm::PipelinedChain { chunk: 1 << 20 }.name(),
            "pipelined-chain(C=1M)"
        );
        assert_eq!(Algorithm::PipelinedChain { chunk: 4 }.family(), "pipelined-chain");
        assert_eq!(Algorithm::RingAllreduce.name(), "ring-allreduce");
        assert_eq!(Algorithm::TreeAllreduce { k: 4 }.name(), "tree-allreduce(k=4)");
    }

    #[test]
    fn default_spec_kind_is_broadcast() {
        let spec = BcastSpec::new(0, 4, 64);
        assert_eq!(spec.kind, CollectiveKind::Broadcast);
        let ar = CollectiveSpec::allreduce(4, 64);
        assert_eq!(ar.kind, CollectiveKind::Allreduce);
        assert_eq!(ar.root, 0);
    }

    #[test]
    fn algorithm_kinds_map() {
        assert_eq!(Algorithm::Chain.kind(), CollectiveKind::Broadcast);
        assert_eq!(
            Algorithm::RingReduceScatter.kind(),
            CollectiveKind::ReduceScatter
        );
        assert_eq!(Algorithm::RingAllgather.kind(), CollectiveKind::Allgather);
        assert_eq!(
            Algorithm::TreeAllreduce { k: 2 }.kind(),
            CollectiveKind::Allreduce
        );
    }

    #[test]
    fn algorithm_is_hashable_map_key() {
        // Eq + Hash: tuning tables and dedup maps key on Algorithm
        // directly instead of round-tripping through name() strings.
        use std::collections::HashMap;
        let mut wins: HashMap<Algorithm, u64> = HashMap::new();
        wins.insert(Algorithm::PipelinedChain { chunk: 1 << 20 }, 10);
        wins.insert(Algorithm::RingAllreduce, 20);
        wins.insert(Algorithm::PipelinedChain { chunk: 1 << 20 }, 30);
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[&Algorithm::PipelinedChain { chunk: 1 << 20 }], 30);
    }

    #[test]
    fn rank_entry_exit_ops_for_pipelined_chain() {
        use crate::comm::Comm;
        use crate::topology::presets::flat;
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(1, 4, 12 << 20);
        let bp = super::super::pipelined_chain::plan(&mut comm, &spec, 4 << 20);
        // entries: the root's first send of each chunk (3 chunks)
        let entries = bp.rank_entry_ops(&c);
        assert_eq!(entries[1].len(), 3, "root owns every entry");
        for (r, ops) in entries.iter().enumerate() {
            if r != 1 {
                assert!(ops.is_empty(), "rank {r} must have no entries");
            }
            for &id in ops {
                assert!(bp.plan.deps[id].is_empty());
            }
        }
        // exits: the tail rank's receptions — the chain rooted at 1
        // walks relabeled ranks 1,2,3,0, so rank 0 is the tail
        let exits = bp.rank_exit_ops(&c);
        assert_eq!(exits[0].len(), 3, "tail receives every chunk last");
        for (r, ops) in exits.iter().enumerate() {
            if r != 0 {
                assert!(ops.is_empty(), "rank {r} must have no exits");
            }
            for &id in ops {
                let (rank, _) = bp.plan.labels[id].expect("tail receptions are labelled");
                assert_eq!(rank, 0);
            }
        }
    }

    #[test]
    fn rank_entry_ops_for_ring_allgather() {
        use crate::comm::Comm;
        use crate::topology::presets::flat;
        let c = flat(5).unwrap();
        let mut comm = Comm::new(&c);
        let spec = CollectiveSpec::allgather(5, 5000);
        let cp = super::super::allgather::plan(&mut comm, &spec);
        // every rank contributes its own segment: one entry each
        let entries = cp.rank_entry_ops(&c);
        for (r, ops) in entries.iter().enumerate() {
            assert_eq!(ops.len(), 1, "rank {r} must have exactly one entry");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in CollectiveKind::ALL {
            assert_eq!(CollectiveKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(CollectiveKind::parse("bcast"), Some(CollectiveKind::Broadcast));
        assert_eq!(CollectiveKind::parse("nope"), None);
    }
}
