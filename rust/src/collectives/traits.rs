//! Common types for broadcast algorithms.

use crate::netsim::{OpId, Plan};

/// What to broadcast.
#[derive(Debug, Clone)]
pub struct BcastSpec {
    /// Root rank.
    pub root: usize,
    /// Number of participating ranks (0..n, must match cluster GPUs).
    pub n_ranks: usize,
    /// Message size in bytes.
    pub bytes: u64,
}

impl BcastSpec {
    pub fn new(root: usize, n_ranks: usize, bytes: u64) -> BcastSpec {
        assert!(n_ranks >= 1, "need at least one rank");
        assert!(root < n_ranks, "root out of range");
        BcastSpec {
            root,
            n_ranks,
            bytes,
        }
    }

    /// Relabel rank `r` so the root is 0 (the usual trick for rooted
    /// collectives).
    #[inline]
    pub fn relabel(&self, r: usize) -> usize {
        (r + self.n_ranks - self.root) % self.n_ranks
    }

    /// Inverse of [`Self::relabel`].
    #[inline]
    pub fn unlabel(&self, v: usize) -> usize {
        (v + self.root) % self.n_ranks
    }
}

/// A rank-level data-flow edge: "src sent chunk to dst; the final netsim
/// op of that send is `op`". Used by [`super::validate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowEdge {
    pub src: usize,
    pub dst: usize,
    pub chunk: usize,
    pub op: OpId,
}

/// A built broadcast: ops + flow edges + chunk accounting.
#[derive(Debug, Clone)]
pub struct BcastPlan {
    pub plan: Plan,
    pub edges: Vec<FlowEdge>,
    pub n_chunks: usize,
    pub spec: BcastSpec,
    pub algorithm: String,
}

/// The algorithm menu (what the tuning framework selects over).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    /// Serialized root-sends-to-all loop (Eq. 1). Never wins; baseline.
    Direct,
    /// Store-and-forward chain (Eq. 2).
    Chain,
    /// The paper's contribution: chunked, pipelined chain (Eq. 5).
    PipelinedChain { chunk: u64 },
    /// K-nomial tree (Eq. 3); binomial at k = 2.
    Knomial { k: usize },
    /// Binomial scatter + ring allgather (Eq. 4) — bandwidth-optimal for
    /// large M.
    ScatterRingAllgather,
    /// Host-staged k-nomial (Eq. 6) — the GPU-specific small-message
    /// optimisation of §IV-C.
    HostStagedKnomial { k: usize },
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::Direct => "direct".into(),
            Algorithm::Chain => "chain".into(),
            Algorithm::PipelinedChain { chunk } => {
                format!("pipelined-chain(C={})", crate::util::bytes::format_size(*chunk))
            }
            Algorithm::Knomial { k } => format!("knomial(k={k})"),
            Algorithm::ScatterRingAllgather => "scatter-ring-allgather".into(),
            Algorithm::HostStagedKnomial { k } => format!("host-staged-knomial(k={k})"),
        }
    }

    /// Stable identifier without parameters (tuning-table keys).
    pub fn family(&self) -> &'static str {
        match self {
            Algorithm::Direct => "direct",
            Algorithm::Chain => "chain",
            Algorithm::PipelinedChain { .. } => "pipelined-chain",
            Algorithm::Knomial { .. } => "knomial",
            Algorithm::ScatterRingAllgather => "scatter-ring-allgather",
            Algorithm::HostStagedKnomial { .. } => "host-staged-knomial",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relabel_roundtrip() {
        let spec = BcastSpec::new(3, 8, 100);
        for r in 0..8 {
            assert_eq!(spec.unlabel(spec.relabel(r)), r);
        }
        assert_eq!(spec.relabel(3), 0);
    }

    #[test]
    #[should_panic]
    fn root_out_of_range_panics() {
        BcastSpec::new(8, 8, 1);
    }

    #[test]
    fn algorithm_names() {
        assert_eq!(Algorithm::Knomial { k: 2 }.name(), "knomial(k=2)");
        assert_eq!(
            Algorithm::PipelinedChain { chunk: 1 << 20 }.name(),
            "pipelined-chain(C=1M)"
        );
        assert_eq!(Algorithm::PipelinedChain { chunk: 4 }.family(), "pipelined-chain");
    }
}
