//! Scatter-Allgather algorithm (§III-A, Eq. 4): binomial-tree scatter of
//! `n` message parts followed by a ring allgather — the bandwidth-optimal
//! broadcast for large `M` (van de Geijn / MPICH large-message scheme):
//!
//! `T = (⌈log₂ n⌉ + n − 1) × t_s + 2 × (n−1)/n × M/B`

use crate::comm::{chunk::equal_parts, Comm};
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &BcastSpec) -> BcastPlan {
    template(comm, spec).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec) -> CollectiveTemplate {
    let n = spec.n_ranks;
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if n == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: BcastPlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: "scatter-ring-allgather".into(),
            },
        };
    }
    let parts = equal_parts(spec.bytes, n);
    // part_at[v][p] = op after which relabeled rank v holds part p
    let mut part_at: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];

    // ---- phase 1: binomial scatter (recursive halving) -------------------
    // holder v owns parts [v, v+size); sends the upper half to v+half
    #[allow(clippy::too_many_arguments)]
    fn scatter(
        comm: &mut Comm,
        plan: &mut crate::netsim::Plan,
        rec: &mut RoleRecorder,
        edges: &mut Vec<FlowEdge>,
        spec: &BcastSpec,
        parts: &[u64],
        part_at: &mut [Vec<Option<OpId>>],
        lo: usize,
        size: usize,
        have: Option<OpId>,
    ) {
        if size <= 1 {
            return;
        }
        let half = size / 2;
        let upper_lo = lo + size - half; // upper `half` parts move
        let bytes: u64 = parts[upper_lo..lo + size].iter().sum();
        let src = spec.unlabel(lo);
        let dst = spec.unlabel(upper_lo);
        let deps = Deps::from_opt(have);
        // the head of the upper range keeps part `upper_lo` permanently —
        // that is its *delivery*; the rest of the range is custody it
        // forwards deeper into the scatter tree
        let mark = plan.len();
        let op = comm.send(plan, src, dst, bytes, deps, Some((dst, upper_lo)));
        rec.tag(
            plan,
            mark,
            ByteRole::PartRange {
                from: upper_lo as u32,
                to: (lo + size) as u32,
                of: spec.n_ranks as u32,
            },
            comm.size_class_of(bytes),
        );
        // one flow edge per part carried (custody included) so the
        // validator can track possession precisely
        for p in upper_lo..lo + size {
            part_at[upper_lo][p] = Some(op);
            edges.push(FlowEdge::copy(src, dst, p, op));
        }
        scatter(
            comm,
            plan,
            rec,
            edges,
            spec,
            parts,
            part_at,
            lo,
            size - half,
            have,
        );
        scatter(
            comm,
            plan,
            rec,
            edges,
            spec,
            parts,
            part_at,
            upper_lo,
            half,
            Some(op),
        );
    }
    scatter(
        comm, &mut plan, &mut rec, &mut edges, spec, &parts, &mut part_at, 0, n, None,
    );

    // ---- phase 2: ring allgather -----------------------------------------
    // After scatter, rank v's working buffer holds exactly part v (root
    // holds everything); intermediate scatter custody is not reused.
    let mut owned: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];
    for v in 1..n {
        owned[v][v] = part_at[v][v];
        debug_assert!(owned[v][v].is_some(), "scatter left rank {v} empty");
    }
    // step t: rank v sends part (v - t) mod n to (v+1) mod n
    for t in 0..n - 1 {
        let mut new_ops: Vec<(usize, usize, OpId)> = Vec::new();
        for v in 0..n {
            let part = (v + n - t) % n;
            let dst_v = (v + 1) % n;
            let src = spec.unlabel(v);
            let dst = spec.unlabel(dst_v);
            // root (v = 0) owns every part from the start: no dependency
            if owned[v][part].is_none() {
                assert!(v == 0, "ring allgather: rank {v} missing part {part}");
            }
            let deps = Deps::from_opt(owned[v][part]);
            let mark = plan.len();
            let op = comm.send(&mut plan, src, dst, parts[part], deps, Some((dst, part)));
            rec.tag(
                &plan,
                mark,
                ByteRole::Part {
                    index: part as u32,
                    of: n as u32,
                },
                comm.size_class_of(parts[part]),
            );
            edges.push(FlowEdge::copy(src, dst, part, op));
            new_ops.push((dst_v, part, op));
        }
        for (dst_v, part, op) in new_ops {
            // root never *needs* arrivals; keep its sends dependency-free
            if dst_v != 0 {
                owned[dst_v][part] = Some(op);
            }
        }
    }

    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: n,
            spec: spec.clone(),
            algorithm: "scatter-ring-allgather".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn bandwidth_optimal_for_large_messages() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m: u64 = 64 << 20;
        let spec = BcastSpec::new(0, 8, m);
        let t_sag = engine.execute(&plan(&mut comm, &spec).plan).makespan;
        let t_chain = engine
            .execute(&super::super::chain::plan(&mut comm, &spec).plan)
            .makespan;
        // Eq.4 moves ~2M/B vs chain's (n-1)M/B — must be much faster
        assert!(t_sag < t_chain / 2, "{t_sag} vs {t_chain}");
    }

    #[test]
    fn every_rank_gets_every_part() {
        let c = flat(6).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(2, 6, 6000);
        let bp = plan(&mut comm, &spec);
        let result = engine.execute(&bp.plan);
        for rank in 0..6 {
            if rank == 2 {
                continue;
            }
            for part in 0..6 {
                assert!(
                    result.delivery_time(&bp.plan, rank, part).is_some(),
                    "rank {rank} missing part {part}"
                );
            }
        }
    }

    #[test]
    fn total_traffic_matches_binomial_scatter_plus_ring() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let m: u64 = 8 << 20;
        let spec = BcastSpec::new(0, 8, m);
        let bp = plan(&mut comm, &spec);
        // binomial scatter *traffic* is (M/2)·log₂n byte-hops (each level
        // forwards half the range); the ring allgather has every rank
        // sending M/n at each of the n-1 steps: (n-1)·M total
        let total = bp.plan.total_bytes();
        let scatter = m / 2 * 3;
        let ring = (8 - 1) * m;
        assert_eq!(total, scatter + ring);
    }

    #[test]
    fn single_rank_noop() {
        let c = flat(1).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(0, 1, 100);
        let bp = plan(&mut comm, &spec);
        assert!(bp.plan.is_empty());
    }

    #[test]
    fn odd_rank_count_works() {
        let c = flat(7).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 7, 7013); // deliberately non-divisible
        let bp = plan(&mut comm, &spec);
        let result = engine.execute(&bp.plan);
        for rank in 1..7 {
            for part in 0..7 {
                assert!(result.delivery_time(&bp.plan, rank, part).is_some());
            }
        }
    }
}
