//! Chain algorithm (§III-A, Eq. 2): each recipient forwards the whole
//! message to the next rank. `T = (n-1) × (t_s + M/B)`. For rooted
//! collectives the chain is a logical ring *without* the wrap-around
//! (paper, §III-A).

use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &BcastSpec) -> BcastPlan {
    template(comm, spec).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec) -> CollectiveTemplate {
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    let class = comm.size_class_of(spec.bytes);
    let mut prev: Option<crate::netsim::OpId> = None;
    for v in 1..spec.n_ranks {
        let src = spec.unlabel(v - 1);
        let dst = spec.unlabel(v);
        // store-and-forward: must hold the whole message before sending on
        let deps = Deps::from_opt(prev);
        let mark = plan.len();
        let op = comm.send(&mut plan, src, dst, spec.bytes, deps, Some((dst, 0)));
        rec.tag(&plan, mark, ByteRole::Whole, class);
        edges.push(FlowEdge::copy(src, dst, 0, op));
        prev = Some(op);
    }
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: 1,
            spec: spec.clone(),
            algorithm: "chain".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn cost_matches_eq2_on_flat() {
        let c = flat(6).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 6, 4 << 20);
        let hop = comm.estimate_ns(0, 1, 4 << 20);
        let bp = plan(&mut comm, &spec);
        let r = engine.execute(&bp.plan);
        assert_eq!(r.makespan, 5 * hop); // (n-1) × (t_s + M/B)
    }

    #[test]
    fn chain_passes_through_neighbours() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(1, 4, 64);
        let bp = plan(&mut comm, &spec);
        let pairs: Vec<(usize, usize)> = bp.edges.iter().map(|e| (e.src, e.dst)).collect();
        assert_eq!(pairs, vec![(1, 2), (2, 3), (3, 0)]);
    }
}
