//! Collective plan templates: build each collective's op DAG **once**
//! per (algorithm, chunk shape, topology) and rescale per message size.
//!
//! PR 2 made *executing* a plan allocation-free; this layer removes the
//! remaining per-grid-point cost of a tuning sweep — plan
//! *construction*. All message sizes at a fixed (algorithm, chunk count,
//! topology) share the same DAG shape, routes, overheads and labels,
//! differing only in per-op byte counts, so every builder records a
//! [`ByteRole`] per op ([`RoleRecorder`]) and the [`TemplateCache`] on
//! [`Comm`] serves later sizes by rewriting bytes in place
//! (`netsim::transfer::rescale`).
//!
//! Soundness: a rescale is legal only if every size-class-sensitive op
//! stays in the class it was built with — `Comm` resolves mechanism
//! selection at a canonical per-class size, so equal class ⇒ identical
//! mechanism ⇒ identical structure. A class boundary crossing returns a
//! cache miss and the plan is rebuilt. The cache key carries the
//! cluster's topology generation (mirroring `RouteId`'s staleness
//! check), so a mutation orphans every cached structure instead of
//! serving plans whose interned routes no longer exist.

use std::collections::HashMap;

use crate::comm::{protocol, Comm};
use crate::netsim::transfer::{self, ByteRole, OpByte};
use crate::netsim::Plan;

use super::traits::{Algorithm, CollectiveKind, CollectivePlan, CollectiveSpec};

/// A built collective plus the per-op byte roles needed to rescale it.
/// `cp` is always concrete: it is the instance served to callers, and
/// rescaling mutates its byte counts in place.
#[derive(Debug, Clone)]
pub struct CollectiveTemplate {
    pub cp: CollectivePlan,
    pub roles: Vec<OpByte>,
}

impl CollectiveTemplate {
    /// Rescale the held plan to a new message size. Returns `false` —
    /// the instance is torn and must be discarded — when an op crosses
    /// its mechanism size class (see `netsim::transfer::rescale`).
    /// Under the SoA plan layout a rescale rewrites only the `bytes`
    /// column (transfer rows, per their [`ByteRole`]); ends, overheads,
    /// issue costs, caps, deps and labels are never touched, so the
    /// plan's structure — and the engine's CSR scratch reuse — survive
    /// every hit (DESIGN.md §SoA plan layout).
    pub fn rescale(&mut self, bytes: u64, classify: impl Fn(u64) -> u8) -> bool {
        if transfer::rescale(&mut self.cp.plan, &self.roles, bytes, classify) {
            self.cp.spec.bytes = bytes;
            true
        } else {
            false
        }
    }
}

/// Builder-side shim: records one [`OpByte`] per op pushed into a plan.
/// Builders mark the plan length before each emit and tag everything the
/// emit appended (staged sends append two ops; both carry the payload).
#[derive(Debug, Default)]
pub struct RoleRecorder {
    roles: Vec<OpByte>,
}

impl RoleRecorder {
    pub fn new() -> RoleRecorder {
        RoleRecorder { roles: Vec::new() }
    }

    /// Tag every op emitted since `mark` (the plan's length before the
    /// emit) with `role` at build-time size class `class`
    /// (`netsim::NO_CLASS` when the op's structure never consulted one).
    pub fn tag(&mut self, plan: &Plan, mark: usize, role: ByteRole, class: u8) {
        debug_assert_eq!(self.roles.len(), mark, "ops emitted without a byte role");
        self.roles.resize(plan.len(), OpByte { role, class });
    }

    /// Finalize; every op must have been tagged.
    pub fn finish(self, plan: &Plan) -> Vec<OpByte> {
        assert_eq!(
            self.roles.len(),
            plan.len(),
            "template builder left ops without byte roles"
        );
        self.roles
    }
}

/// What built a template: the MPI algorithm menu or an NCCL backend
/// (keyed by a parameter fingerprint, since `NcclParams` shapes the
/// plan but is not part of [`Algorithm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKey {
    Mpi(Algorithm),
    NcclRing { params_fp: u64 },
    NcclHier { chunk: u64, params_fp: u64 },
}

/// Everything that fixes a plan's structure except the message size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TemplateKey {
    pub kind: CollectiveKind,
    pub algo: AlgoKey,
    pub root: usize,
    pub n_ranks: usize,
    /// Structural shape along the size axis: the chunk/slice count for
    /// chunked algorithms (1 otherwise; the hierarchical NCCL backend
    /// packs `chunk count << 32 | total slices`). Part-based algorithms
    /// need nothing here — their shape is `n_ranks`, already in the key.
    pub shape: u64,
    /// Topology generation the template was built against
    /// ([`crate::topology::Cluster::generation`]); a mutation bumps it
    /// and orphans the entry.
    pub generation: u32,
    /// The fabric family the template was planned for
    /// ([`crate::topology::TopologyKind`]): hierarchical planners map
    /// rails/pods to stages differently per family, so a template built
    /// on one fabric must never be rescaled onto another — even when
    /// rank count, root and generation happen to coincide (e.g. across
    /// two `Comm`s sharing a cache in a sweep harness).
    pub topology: crate::topology::TopologyKind,
}

/// Number of slots `comm::chunk_sizes(total, chunk)` would produce,
/// without allocating the vector.
pub fn n_chunk_slots(total: u64, chunk: u64) -> u64 {
    if total == 0 {
        return 1;
    }
    if chunk == 0 || chunk >= total {
        return 1;
    }
    total / chunk + u64::from(total % chunk > 0)
}

fn mpi_shape(algo: &Algorithm, spec: &CollectiveSpec) -> u64 {
    match algo {
        Algorithm::PipelinedChain { chunk } => n_chunk_slots(spec.bytes, *chunk),
        _ => 1,
    }
}

/// Total cached-op budget: past this the cache clears wholesale before
/// inserting (epoch eviction). Bounds worst-case memory — the largest
/// pipelined plans at big presets run to hundreds of thousands of ops
/// each and, being chunk-count-keyed, a sweep inserts one per grid size
/// — while staying far above what one tuning sweep's reusable shapes
/// actually occupy (a few hundred thousand ops), so the clear never
/// fires on the hot path.
const OP_BUDGET: usize = 2_000_000;

/// The per-`Comm` template cache. Entries are full [`CollectiveTemplate`]s
/// whose plan instance is rescaled in place on every hit; hit/miss
/// counters feed the bench report's cache-hit-rate row. Memory is
/// bounded by [`OP_BUDGET`] total cached ops (epoch eviction).
#[derive(Debug, Clone)]
pub struct TemplateCache {
    entries: HashMap<TemplateKey, CollectiveTemplate>,
    /// Generation of the entries currently held; a key from a newer
    /// generation sweeps the map (topology changed under us).
    generation: u32,
    /// Sum of `plan.len()` over all entries (budget accounting).
    total_ops: usize,
    op_budget: usize,
    hits: u64,
    misses: u64,
}

impl Default for TemplateCache {
    fn default() -> TemplateCache {
        TemplateCache {
            entries: HashMap::new(),
            generation: 0,
            total_ops: 0,
            op_budget: OP_BUDGET,
            hits: 0,
            misses: 0,
        }
    }
}

impl TemplateCache {
    pub fn new() -> TemplateCache {
        TemplateCache::default()
    }

    /// Shrink the op budget (tests exercise the eviction path without
    /// building two million ops).
    #[cfg(test)]
    pub(crate) fn set_op_budget(&mut self, budget: usize) {
        self.op_budget = budget;
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn sweep_generation(&mut self, generation: u32) {
        if self.generation != generation {
            self.entries.clear();
            self.total_ops = 0;
            self.generation = generation;
        }
    }

    /// Try to serve `key` at `bytes` by rescaling the cached instance in
    /// place. Counts a hit on success; on failure (absent, or a class
    /// boundary was crossed) the stale entry is dropped and a miss is
    /// counted — the caller rebuilds and [`Self::insert`]s.
    pub(crate) fn try_rescale(
        &mut self,
        key: &TemplateKey,
        bytes: u64,
        classify: impl Fn(u64) -> u8,
    ) -> bool {
        self.sweep_generation(key.generation);
        let ok = match self.entries.get_mut(key) {
            Some(tpl) => tpl.cp.spec.bytes == bytes || tpl.rescale(bytes, classify),
            None => false,
        };
        if ok {
            self.hits += 1;
        } else {
            self.misses += 1;
            if let Some(old) = self.entries.remove(key) {
                self.total_ops -= old.cp.plan.len();
            }
        }
        ok
    }

    pub(crate) fn insert(&mut self, key: TemplateKey, tpl: CollectiveTemplate) {
        self.sweep_generation(key.generation);
        debug_assert_eq!(tpl.roles.len(), tpl.cp.plan.len());
        let ops = tpl.cp.plan.len();
        if self.total_ops + ops > self.op_budget && !self.entries.is_empty() {
            // epoch eviction: cheaper and simpler than LRU, and the
            // budget is sized so real sweeps never reach it
            self.entries.clear();
            self.total_ops = 0;
        }
        self.total_ops += ops;
        if let Some(old) = self.entries.insert(key, tpl) {
            self.total_ops -= old.cp.plan.len();
        }
    }

    /// The cached instance for a key known to be present.
    pub(crate) fn plan_for(&self, key: &TemplateKey) -> &CollectivePlan {
        &self.entries.get(key).expect("template cache entry").cp
    }
}

/// Acquire the plan for `algo` at `spec` through the comm's template
/// cache: a hit rescales byte counts in place (no construction at all);
/// a miss builds the template fresh and caches it. The returned plan is
/// valid until the next acquisition through the same `Comm`.
pub fn cached_plan<'a, 'c>(
    algo: &Algorithm,
    comm: &'a mut Comm<'c>,
    spec: &CollectiveSpec,
) -> &'a CollectivePlan {
    let key = TemplateKey {
        kind: spec.kind,
        algo: AlgoKey::Mpi(*algo),
        root: spec.root,
        n_ranks: spec.n_ranks,
        shape: mpi_shape(algo, spec),
        generation: comm.cluster().generation(),
        topology: comm.cluster().topology_kind(),
    };
    let params = comm.params().clone();
    let hit = comm
        .template_cache_mut()
        .try_rescale(&key, spec.bytes, |b| protocol::size_class(&params, b));
    if !hit {
        let tpl = super::template_for(algo, comm, spec);
        // debug builds statically verify each freshly built template —
        // once per structure; rescale hits reuse the proven DAG
        crate::analysis::debug_verify_collective(
            comm.cluster(),
            &tpl.cp,
            "collectives::cached_plan",
        );
        comm.template_cache_mut().insert(key, tpl);
    }
    comm.template_cache().plan_for(&key)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::kesch;

    #[test]
    fn n_chunk_slots_matches_chunk_sizes() {
        for (total, chunk) in [
            (0u64, 64u64),
            (5, 0),
            (7, 7),
            (7, 100),
            (100, 30),
            (1 << 20, 64 << 10),
            ((1 << 20) + 1, 64 << 10),
        ] {
            assert_eq!(
                n_chunk_slots(total, chunk),
                crate::comm::chunk_sizes(total, chunk).len() as u64,
                "total={total} chunk={chunk}"
            );
        }
    }

    #[test]
    fn cache_hits_across_the_size_axis() {
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let algo = Algorithm::Knomial { k: 2 };
        let mut reference = Vec::new();
        for &bytes in &[4u64, 512, 8 << 10] {
            let spec = CollectiveSpec::new(0, 8, bytes);
            let ns = engine.makespan_ns(&cached_plan(&algo, &mut comm, &spec).plan);
            reference.push((bytes, ns));
        }
        // first size misses, same-class re-sizes rescale in place
        let (hits, misses) = comm.template_cache().stats();
        assert_eq!(misses, 1, "one structural build for the whole class");
        assert_eq!(hits, 2);
        assert_eq!(comm.template_cache().len(), 1);
        // revisiting sizes is pure
        for &(bytes, want) in &reference {
            let spec = CollectiveSpec::new(0, 8, bytes);
            let ns = engine.makespan_ns(&cached_plan(&algo, &mut comm, &spec).plan);
            assert_eq!(ns, want, "revisit at {bytes}B changed the makespan");
        }
    }

    #[test]
    fn class_boundary_rebuilds() {
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        let algo = Algorithm::Knomial { k: 2 };
        let small = CollectiveSpec::new(0, 8, 4);
        let large = CollectiveSpec::new(0, 8, 1 << 20); // crosses eager
        let _ = cached_plan(&algo, &mut comm, &small);
        let _ = cached_plan(&algo, &mut comm, &large);
        let (_, misses) = comm.template_cache().stats();
        assert_eq!(misses, 2, "crossing the eager class must rebuild");
    }

    #[test]
    fn pipelined_chunk_count_keys_separately() {
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        let algo = Algorithm::PipelinedChain { chunk: 1 << 20 };
        // 8 chunks vs 9 chunks: different DAG shapes, separate entries
        let a = CollectiveSpec::new(0, 8, 8 << 20);
        let b = CollectiveSpec::new(0, 8, (8 << 20) + 1);
        let _ = cached_plan(&algo, &mut comm, &a);
        let _ = cached_plan(&algo, &mut comm, &b);
        assert_eq!(comm.template_cache().len(), 2);
        // 8 MB + 4 KB: nine slots again with the remainder still in the
        // small class — hits the second entry's shape and rescales
        let c = CollectiveSpec::new(0, 8, (8 << 20) + 4096);
        let _ = cached_plan(&algo, &mut comm, &c);
        let (hits, misses) = comm.template_cache().stats();
        assert_eq!((hits, misses), (1, 2));
    }

    #[test]
    fn remainder_chunk_class_crossing_refuses_rescale() {
        // regression for the remainder-chunk edge `pipelined_chain`
        // records: the rescale refuse-and-rebuild check must be
        // *per-chunk*, not whole-message. Both totals here sit in the
        // same whole-message class — only the remainder chunk crosses
        // the eager threshold — so a whole-message check would wrongly
        // serve a rescaled plan built for the eager remainder.
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let chunk: u64 = 64 << 10;
        let big = 4 * chunk + (32 << 10); // remainder 32K: rendezvous class
        let small = 4 * chunk + (8 << 10); // remainder 8K: eager class
        let same = 4 * chunk + (24 << 10); // remainder 24K: rendezvous class
        assert_eq!(
            comm.size_class_of(big),
            comm.size_class_of(small),
            "precondition: whole messages share a class"
        );
        assert_ne!(
            comm.size_class_of(32 << 10),
            comm.size_class_of(8 << 10),
            "precondition: remainder chunks cross the eager threshold"
        );
        assert_eq!(n_chunk_slots(big, chunk), n_chunk_slots(small, chunk));
        let algo = Algorithm::PipelinedChain { chunk };
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(0, 8, big));
        // same-class remainder: rescales in place
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(0, 8, same));
        assert_eq!(comm.template_cache().stats(), (1, 1));
        // remainder crosses the eager class: must refuse and rebuild
        let ns = engine.makespan_ns(
            &cached_plan(&algo, &mut comm, &CollectiveSpec::new(0, 8, small)).plan,
        );
        assert_eq!(
            comm.template_cache().stats().1,
            2,
            "remainder class crossing must force a rebuild"
        );
        // and the rebuilt plan is bit-identical to a fresh build
        let mut fresh_comm = Comm::new(&cluster);
        let fresh = super::super::plan(&algo, &mut fresh_comm, &CollectiveSpec::new(0, 8, small));
        assert_eq!(ns, engine.makespan_ns(&fresh.plan));
    }

    #[test]
    fn roots_key_separately() {
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        let algo = Algorithm::Chain;
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(0, 8, 4096));
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(3, 8, 4096));
        assert_eq!(comm.template_cache().len(), 2);
    }

    #[test]
    fn op_budget_bounds_cache_memory() {
        let cluster = kesch(1, 8).unwrap();
        let mut comm = Comm::new(&cluster);
        // chain at 8 ranks = 7 ops per entry; budget of 10 fits one
        comm.template_cache_mut().set_op_budget(10);
        let algo = Algorithm::Chain;
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(0, 8, 4096));
        assert_eq!(comm.template_cache().len(), 1);
        // a second root's entry would exceed the budget: epoch-evict
        let _ = cached_plan(&algo, &mut comm, &CollectiveSpec::new(3, 8, 4096));
        assert_eq!(comm.template_cache().len(), 1, "old epoch must be dropped");
        // the surviving entry still serves correct plans
        let bp = cached_plan(&algo, &mut comm, &CollectiveSpec::new(3, 8, 4096));
        assert_eq!(bp.spec.root, 3);
        assert_eq!(bp.plan.len(), 7);
    }
}
