//! **The paper's contribution** (§IV-B, Eq. 5): the CUDA-aware pipelined
//! chain design for `MPI_Bcast`.
//!
//! The root chunks the message and pushes chunks to its right neighbour;
//! every non-root, non-tail process forwards each chunk onward as soon as
//! it arrives. With chunk size `C`:
//!
//! `T = (M/C + n - 2) × (t_s + C/B)`
//!
//! Chunk-size selection is non-trivial (paper §IV-B) and is owned by the
//! tuning framework ([`crate::tuning`]); this module takes `C` as input.
//! Per §IV-C the pipelined chain does *not* host-stage: it rides CUDA IPC
//! intranode and GDR internode — which is exactly what [`Comm::send`]
//! resolves per hop.

use crate::comm::{chunk_sizes, Comm};
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &BcastSpec, chunk: u64) -> BcastPlan {
    template(comm, spec, chunk).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec, chunk: u64) -> CollectiveTemplate {
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    let chunks = chunk_sizes(spec.bytes, chunk);
    // recv_op[v][c] = op that delivered chunk c to relabeled rank v
    let n = spec.n_ranks;
    let mut recv_op: Vec<Vec<Option<OpId>>> = vec![vec![None; chunks.len()]; n];
    for (c, &cbytes) in chunks.iter().enumerate() {
        // the remainder chunk may sit in a different mechanism class
        // than the full ones — recorded per chunk
        let class = comm.size_class_of(cbytes);
        let role = ByteRole::ChunkSlot {
            index: c as u32,
            chunk,
        };
        for v in 1..n {
            let src = spec.unlabel(v - 1);
            let dst = spec.unlabel(v);
            // forward chunk c as soon as it arrived at v-1 (root always
            // has it); link FIFO order serialises chunks on the wire
            let deps = Deps::from_opt(recv_op[v - 1][c]);
            let mark = plan.len();
            let op = comm.send(&mut plan, src, dst, cbytes, deps, Some((dst, c)));
            rec.tag(&plan, mark, role, class);
            recv_op[v][c] = Some(op);
            edges.push(FlowEdge::copy(src, dst, c, op));
        }
    }
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: chunks.len(),
            spec: spec.clone(),
            algorithm: format!(
                "pipelined-chain(C={})",
                crate::util::bytes::format_size(chunk)
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn matches_eq5_on_flat() {
        // T = (M/C + n - 2) × (t_s + C/B) on the idealised fabric
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m: u64 = 32 << 20;
        let chunk: u64 = 4 << 20;
        let spec = BcastSpec::new(0, 8, m);
        let per_chunk = comm.estimate_ns(0, 1, chunk);
        let bp = plan(&mut comm, &spec, chunk);
        let r = engine.execute(&bp.plan);
        let steps = (m / chunk) + 8 - 2;
        assert_eq!(r.makespan, steps * per_chunk);
    }

    #[test]
    fn beats_plain_chain_for_large_messages() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 8, 64 << 20);
        let plain = super::super::chain::plan(&mut comm, &spec);
        let t_plain = engine.execute(&plain.plan).makespan;
        let piped = plan(&mut comm, &spec, 2 << 20);
        let t_piped = engine.execute(&piped.plan).makespan;
        assert!(
            t_piped < t_plain / 3,
            "pipelining must win big: {t_piped} vs {t_plain}"
        );
    }

    #[test]
    fn chunk_count_accounting() {
        let c = flat(3).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(0, 3, 10 << 20);
        let bp = plan(&mut comm, &spec, 4 << 20);
        assert_eq!(bp.n_chunks, 3); // 4M + 4M + 2M
        assert_eq!(bp.edges.len(), 3 * 2);
    }

    #[test]
    fn degenerate_chunk_equals_chain() {
        let c = flat(5).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 5, 1 << 20);
        let chain = super::super::chain::plan(&mut comm, &spec);
        let t_chain = engine.execute(&chain.plan).makespan;
        let piped = plan(&mut comm, &spec, 1 << 20); // C = M
        let t_piped = engine.execute(&piped.plan).makespan;
        assert_eq!(t_chain, t_piped);
    }

    #[test]
    fn two_ranks_pipelines_root_link() {
        // with n=2 the chain is a single hop; pipelining only adds
        // overhead per chunk — time = (M/C) × (t_s + C/B)
        let c = flat(2).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m = 8 << 20;
        let chunk = 1 << 20;
        let spec = BcastSpec::new(0, 2, m);
        let per_chunk = comm.estimate_ns(0, 1, chunk);
        let bp = plan(&mut comm, &spec, chunk);
        let r = engine.execute(&bp.plan);
        // chunks serialise on the single link; each adds t_s + C/B
        assert_eq!(r.makespan, (m / chunk) * per_chunk);
    }
}
