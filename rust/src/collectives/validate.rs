//! Broadcast-plan invariants.
//!
//! Every algorithm must produce a plan where (1) each non-root rank is
//! *delivered* every chunk exactly once, and (2) data flows causally: no
//! rank forwards a chunk before the simulator says it arrived. These are
//! the invariants the property tests in `rust/tests/` sweep across random
//! topologies, roots, sizes and algorithms.

use std::collections::HashMap;

use crate::netsim::{Engine, ExecResult};

use super::traits::BcastPlan;

/// Validate a plan against an execution of it.
///
/// Checks:
/// * coverage — every (non-root rank, chunk) has a labelled delivery;
/// * causality — each flow edge's op *starts* no earlier than the
///   delivery of that chunk at the edge's source rank (the root owns all
///   chunks at t=0);
/// * uniqueness — no two labelled ops deliver the same (rank, chunk).
pub fn validate(bp: &BcastPlan, result: &ExecResult) -> Result<(), String> {
    let spec = &bp.spec;

    // uniqueness + coverage from labels
    let mut seen: HashMap<(usize, usize), usize> = HashMap::new();
    for (id, op) in bp.plan.ops.iter().enumerate() {
        if let Some((rank, chunk)) = op.label {
            if rank >= spec.n_ranks {
                return Err(format!("delivery to out-of-range rank {rank}"));
            }
            if chunk >= bp.n_chunks {
                return Err(format!("delivery of out-of-range chunk {chunk}"));
            }
            if let Some(prev) = seen.insert((rank, chunk), id) {
                return Err(format!(
                    "duplicate delivery of chunk {chunk} to rank {rank} (ops {prev} and {id})"
                ));
            }
        }
    }
    for rank in 0..spec.n_ranks {
        if rank == spec.root {
            continue;
        }
        for chunk in 0..bp.n_chunks {
            if !seen.contains_key(&(rank, chunk)) {
                return Err(format!("rank {rank} never receives chunk {chunk}"));
            }
        }
    }

    // possession: when each rank first holds each chunk (via *any* flow
    // edge, including scatter custody that labels don't record)
    let mut possession: HashMap<(usize, usize), u64> = HashMap::new();
    for edge in &bp.edges {
        let t = result.done[edge.op];
        possession
            .entry((edge.dst, edge.chunk))
            .and_modify(|v| *v = (*v).min(t))
            .or_insert(t);
    }

    // causality over flow edges
    for edge in &bp.edges {
        if edge.src == spec.root {
            continue; // root owns everything at t=0
        }
        let have_at = match possession.get(&(edge.src, edge.chunk)) {
            Some(&t) => t,
            None => {
                return Err(format!(
                    "edge {} -> {} forwards chunk {} the source never received",
                    edge.src, edge.dst, edge.chunk
                ))
            }
        };
        let starts = result.start[edge.op];
        if starts < have_at {
            return Err(format!(
                "causality violation: rank {} forwards chunk {} at {}ns but receives it at {}ns",
                edge.src, edge.chunk, starts, have_at
            ));
        }
    }
    Ok(())
}

/// Convenience: plan + execute + validate in one call.
pub fn check_algorithm(
    algo: &super::Algorithm,
    comm: &mut crate::comm::Comm,
    engine: &mut Engine,
    spec: &super::BcastSpec,
) -> Result<u64, String> {
    let bp = super::plan(algo, comm, spec);
    let result = engine.execute(&bp.plan);
    validate(&bp, &result)?;
    Ok(result.makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Algorithm, BcastSpec};
    use crate::comm::Comm;
    use crate::topology::presets::{flat, kesch};

    fn all_algorithms() -> Vec<Algorithm> {
        vec![
            Algorithm::Direct,
            Algorithm::Chain,
            Algorithm::PipelinedChain { chunk: 64 << 10 },
            Algorithm::Knomial { k: 2 },
            Algorithm::Knomial { k: 4 },
            Algorithm::ScatterRingAllgather,
            Algorithm::HostStagedKnomial { k: 2 },
        ]
    }

    #[test]
    fn all_algorithms_valid_on_flat() {
        let c = flat(8);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            for root in [0, 3] {
                for bytes in [4u64, 8192, 1 << 20] {
                    let spec = BcastSpec::new(root, 8, bytes);
                    check_algorithm(&algo, &mut comm, &mut engine, &spec)
                        .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
                }
            }
        }
    }

    #[test]
    fn all_algorithms_valid_on_kesch_multinode() {
        let c = kesch(2, 8);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            let spec = BcastSpec::new(0, 16, 256 << 10);
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
        }
    }

    #[test]
    fn missing_delivery_detected() {
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1024);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: drop the final edge's label
        let last = bp.plan.ops.len() - 1;
        bp.plan.ops[last].label = None;
        let result = engine.execute(&bp.plan);
        assert!(validate(&bp, &result).is_err());
    }

    #[test]
    fn causality_violation_detected() {
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1 << 20);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: remove the dependency of the second hop so rank 1
        // "forwards" before receiving
        bp.plan.ops[1].deps.clear();
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert!(err.contains("causality"), "{err}");
    }
}
