//! Collective-plan invariants (post-execution).
//!
//! Broadcast plans must satisfy delivery + causality: every non-root rank
//! is delivered every chunk exactly once, and no rank forwards a chunk
//! before the simulator says it arrived. Reduction collectives
//! (reduce-scatter / allgather / allreduce) are checked by *dataflow
//! replay*: every rank starts with its own contribution, each
//! [`FlowEdge`] moves the source's accumulated contribution-set at the
//! op's start time (applying [`EdgeSem::Copy`] or [`EdgeSem::Reduce`] at
//! completion), and the final buffers must reflect **all n contributions
//! exactly once**. These are the invariants the property tests in
//! `rust/tests/` sweep across random topologies, roots, sizes and
//! algorithms.
//!
//! Violations are reported as the typed [`Diag`]s of [`crate::analysis`]
//! (the static verifier proves the same contracts *before* execution;
//! this validator re-proves them over the schedule that actually ran).
//! The first violation in a fixed scan order is returned — membership is
//! tracked in dense per-(rank, chunk) tables, never hash maps, so the
//! selected diagnostic is identical run to run.

use crate::analysis::{Code, Diag};
use crate::netsim::{Engine, ExecResult};

use super::traits::{CollectiveKind, CollectivePlan, EdgeSem, FlowEdge};

/// Validate a plan against an execution of it, dispatching on the spec's
/// collective kind.
pub fn validate(bp: &CollectivePlan, result: &ExecResult) -> Result<(), Diag> {
    match bp.spec.kind {
        CollectiveKind::Broadcast => validate_broadcast(bp, result),
        _ => validate_dataflow(bp, result),
    }
}

/// Broadcast checks:
/// * coverage — every (non-root rank, chunk) has a labelled delivery;
/// * causality — each flow edge's op *starts* no earlier than the
///   delivery of that chunk at the edge's source rank (the root owns all
///   chunks at t=0);
/// * uniqueness — no two labelled ops deliver the same (rank, chunk).
fn validate_broadcast(bp: &CollectivePlan, result: &ExecResult) -> Result<(), Diag> {
    let spec = &bp.spec;
    let n = spec.n_ranks;
    let k = bp.n_chunks;

    // uniqueness + coverage from labels (dense (rank, chunk) table;
    // usize::MAX = not yet delivered)
    let mut seen: Vec<usize> = vec![usize::MAX; n * k];
    for (id, label) in bp.plan.labels.iter().enumerate() {
        if let Some((rank, chunk)) = *label {
            if rank >= n {
                return Err(Diag::at(
                    Code::LabelRange,
                    id,
                    format!("delivery to out-of-range rank {rank}"),
                ));
            }
            if chunk >= k {
                return Err(Diag::at(
                    Code::LabelRange,
                    id,
                    format!("delivery of out-of-range chunk {chunk}"),
                ));
            }
            let prev = seen[rank * k + chunk];
            if prev != usize::MAX {
                return Err(Diag::at(
                    Code::DuplicateLabel,
                    id,
                    format!(
                        "duplicate delivery of chunk {chunk} to rank {rank} (ops {prev} and {id})"
                    ),
                ));
            }
            seen[rank * k + chunk] = id;
        }
    }
    for rank in 0..n {
        if rank == spec.root {
            continue;
        }
        for chunk in 0..k {
            if seen[rank * k + chunk] == usize::MAX {
                return Err(Diag::new(
                    Code::MissingDelivery,
                    format!("rank {rank} never receives chunk {chunk}"),
                ));
            }
        }
    }

    // edges index the dense possession table below: range-check first
    for e in &bp.edges {
        check_edge_range(e, n, k, result.done.len())?;
    }

    // possession: when each rank first holds each chunk (via *any* flow
    // edge, including scatter custody that labels don't record)
    let mut possession: Vec<u64> = vec![u64::MAX; n * k];
    for edge in &bp.edges {
        let t = result.done[edge.op];
        let cell = &mut possession[edge.dst * k + edge.chunk];
        *cell = (*cell).min(t);
    }

    // causality over flow edges
    for edge in &bp.edges {
        if edge.src == spec.root {
            continue; // root owns everything at t=0
        }
        let have_at = possession[edge.src * k + edge.chunk];
        if have_at == u64::MAX {
            return Err(Diag::at(
                Code::Causality,
                edge.op,
                format!(
                    "edge {} -> {} forwards chunk {} the source never received",
                    edge.src, edge.dst, edge.chunk
                ),
            ));
        }
        let starts = result.start[edge.op];
        if starts < have_at {
            return Err(Diag::at(
                Code::Causality,
                edge.op,
                format!(
                    "causality violation: rank {} forwards chunk {} at {}ns but receives it at {}ns",
                    edge.src, edge.chunk, starts, have_at
                ),
            ));
        }
    }
    Ok(())
}

fn check_edge_range(e: &FlowEdge, n: usize, k: usize, n_ops: usize) -> Result<(), Diag> {
    if e.src >= n || e.dst >= n {
        return Err(Diag::new(
            Code::EdgeRange,
            format!("edge {} -> {} out of rank range", e.src, e.dst),
        ));
    }
    if e.chunk >= k {
        return Err(Diag::new(
            Code::EdgeRange,
            format!("edge carries out-of-range chunk {}", e.chunk),
        ));
    }
    if e.op >= n_ops {
        return Err(Diag::new(
            Code::EdgeRange,
            format!("edge references unknown op {}", e.op),
        ));
    }
    Ok(())
}

/// Per-(rank, chunk) contribution counters: `counts[i]` is how many times
/// rank `i`'s contribution has been folded in.
type Contribs = Vec<u32>;

fn is_zero(c: &Contribs) -> bool {
    c.iter().all(|&x| x == 0)
}

/// Reduction-collective checks by dataflow replay: edges capture their
/// payload (the source's contribution-set) at the op's start time and
/// apply it at the dst (copy = replace, reduce = fold) at completion;
/// the final state must match the collective's contract exactly.
fn validate_dataflow(bp: &CollectivePlan, result: &ExecResult) -> Result<(), Diag> {
    let spec = &bp.spec;
    let n = spec.n_ranks;
    let k = bp.n_chunks;

    if matches!(
        spec.kind,
        CollectiveKind::ReduceScatter | CollectiveKind::Allgather
    ) && k != n
    {
        return Err(Diag::new(
            Code::ChunkCount,
            format!(
                "{} plan must carry one chunk per rank (got {k} chunks for {n} ranks)",
                spec.kind.name()
            ),
        ));
    }

    for e in &bp.edges {
        check_edge_range(e, n, k, result.done.len())?;
    }
    // copy application is idempotent in the replay, so duplicated
    // transfers (wasted traffic, double delivery) must be rejected
    // structurally. Sort-based duplicate scan: the reported edge is the
    // first (in edge order) that repeats an earlier key.
    let mut keyed: Vec<(usize, usize, usize, u8, usize)> = bp
        .edges
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let sem = match e.sem {
                EdgeSem::Copy => 0u8,
                EdgeSem::Reduce => 1u8,
            };
            (e.src, e.dst, e.chunk, sem, i)
        })
        .collect();
    keyed.sort_unstable();
    let mut dup: Option<usize> = None;
    for pair in keyed.windows(2) {
        let (a, b) = (&pair[0], &pair[1]);
        if (a.0, a.1, a.2, a.3) == (b.0, b.1, b.2, b.3) {
            // b.4 > a.4 after the sort (index is the tiebreaker)
            dup = Some(dup.map_or(b.4, |d| d.min(b.4)));
        }
    }
    if let Some(i) = dup {
        let e = &bp.edges[i];
        return Err(Diag::at(
            Code::DuplicateEdge,
            e.op,
            format!(
                "duplicate flow edge {} -> {} for chunk {}",
                e.src, e.dst, e.chunk
            ),
        ));
    }

    // labelled deliveries must be unique, as in the broadcast validator
    let mut seen_labels: Vec<usize> = vec![usize::MAX; n * k];
    for (id, label) in bp.plan.labels.iter().enumerate() {
        if let Some((rank, chunk)) = *label {
            if rank >= n || chunk >= k {
                return Err(Diag::at(
                    Code::LabelRange,
                    id,
                    format!("delivery label ({rank}, {chunk}) out of range"),
                ));
            }
            let prev = seen_labels[rank * k + chunk];
            if prev != usize::MAX {
                return Err(Diag::at(
                    Code::DuplicateLabel,
                    id,
                    format!(
                        "duplicate delivery of chunk {chunk} to rank {rank} (ops {prev} and {id})"
                    ),
                ));
            }
            seen_labels[rank * k + chunk] = id;
        }
    }

    // initial contributions
    let mut state: Vec<Vec<Contribs>> = vec![vec![vec![0u32; n]; k]; n];
    match spec.kind {
        // broadcast plans take the label-based path in `validate`
        CollectiveKind::Broadcast => unreachable!("broadcast uses validate_broadcast"),
        CollectiveKind::ReduceScatter | CollectiveKind::Allreduce => {
            for (r, chunks) in state.iter_mut().enumerate() {
                for counts in chunks.iter_mut() {
                    counts[r] = 1;
                }
            }
        }
        CollectiveKind::Allgather => {
            // segment r originates at rank r
            for (r, chunks) in state.iter_mut().enumerate() {
                chunks[r][r] = 1;
            }
        }
    }

    // replay edges in virtual-time order: completions apply before
    // captures at the same instant (an arrival at t may feed a forward
    // starting at t, matching the engine's dependency semantics)
    const APPLY: u8 = 0;
    const CAPTURE: u8 = 1;
    let mut events: Vec<(u64, u8, usize)> = Vec::with_capacity(2 * bp.edges.len());
    for (i, e) in bp.edges.iter().enumerate() {
        events.push((result.start[e.op], CAPTURE, i));
        events.push((result.done[e.op], APPLY, i));
    }
    events.sort_unstable();

    let capture = |edge: &FlowEdge, state: &[Vec<Contribs>]| -> Result<Contribs, Diag> {
        let snap = state[edge.src][edge.chunk].clone();
        if is_zero(&snap) {
            return Err(Diag::at(
                Code::Causality,
                edge.op,
                format!(
                    "causality violation: rank {} forwards chunk {} before holding any data for it",
                    edge.src, edge.chunk
                ),
            ));
        }
        Ok(snap)
    };

    let mut payloads: Vec<Option<Contribs>> = vec![None; bp.edges.len()];
    for (_t, phase, i) in events {
        let edge = &bp.edges[i];
        if phase == CAPTURE {
            if payloads[i].is_none() {
                payloads[i] = Some(capture(edge, &state)?);
            }
        } else {
            // zero-duration ops may see APPLY sorted before their own
            // CAPTURE at the same instant: capture on demand
            let payload = match payloads[i].take() {
                Some(p) => p,
                None => capture(edge, &state)?,
            };
            match edge.sem {
                EdgeSem::Reduce => {
                    for (acc, add) in state[edge.dst][edge.chunk].iter_mut().zip(&payload) {
                        *acc += add;
                    }
                }
                EdgeSem::Copy => state[edge.dst][edge.chunk] = payload,
            }
        }
    }

    // final contracts
    let check = |rank: usize, chunk: usize, want: &dyn Fn(usize) -> u32| -> Result<(), Diag> {
        for (i, &got) in state[rank][chunk].iter().enumerate() {
            let want = want(i);
            if got != want {
                return Err(Diag::new(
                    Code::Contribution,
                    format!(
                        "rank {rank} chunk {chunk}: contribution from rank {i} \
                         appears {got} times (want {want})"
                    ),
                ));
            }
        }
        Ok(())
    };
    match spec.kind {
        CollectiveKind::Broadcast => unreachable!("broadcast uses validate_broadcast"),
        CollectiveKind::Allreduce => {
            for r in 0..n {
                for c in 0..k {
                    check(r, c, &|_| 1)?;
                }
            }
        }
        CollectiveKind::ReduceScatter => {
            // rank s must own the full reduction of segment s
            for s in 0..n {
                check(s, s, &|_| 1)?;
            }
        }
        CollectiveKind::Allgather => {
            for r in 0..n {
                for c in 0..k {
                    check(r, c, &|i| u32::from(i == c))?;
                }
            }
        }
    }
    Ok(())
}

/// Convenience: plan + execute + validate in one call.
pub fn check_algorithm(
    algo: &super::Algorithm,
    comm: &mut crate::comm::Comm,
    engine: &mut Engine,
    spec: &super::CollectiveSpec,
) -> Result<u64, Diag> {
    let bp = super::plan(algo, comm, spec);
    let result = engine.execute(&bp.plan);
    validate(&bp, &result)?;
    Ok(result.makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Algorithm, BcastSpec, CollectiveSpec};
    use crate::comm::Comm;
    use crate::topology::presets::{flat, kesch};

    fn all_algorithms() -> Vec<Algorithm> {
        vec![
            Algorithm::Direct,
            Algorithm::Chain,
            Algorithm::PipelinedChain { chunk: 64 << 10 },
            Algorithm::Knomial { k: 2 },
            Algorithm::Knomial { k: 4 },
            Algorithm::ScatterRingAllgather,
            Algorithm::HostStagedKnomial { k: 2 },
        ]
    }

    #[test]
    fn all_algorithms_valid_on_flat() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            for root in [0, 3] {
                for bytes in [4u64, 8192, 1 << 20] {
                    let spec = BcastSpec::new(root, 8, bytes);
                    check_algorithm(&algo, &mut comm, &mut engine, &spec)
                        .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
                }
            }
        }
    }

    #[test]
    fn all_algorithms_valid_on_kesch_multinode() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            let spec = BcastSpec::new(0, 16, 256 << 10);
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
        }
    }

    #[test]
    fn missing_delivery_detected() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1024);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: drop the final edge's label (set_label keeps the
        // memoized deliveries map in sync)
        let last = bp.plan.len() - 1;
        bp.plan.set_label(last, None);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::MissingDelivery, "{err}");
    }

    #[test]
    fn causality_violation_detected() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1 << 20);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: remove the dependency of the second hop so rank 1
        // "forwards" before receiving
        bp.plan.deps[1] = crate::netsim::Deps::none();
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::Causality, "{err}");
        assert!(err.to_string().contains("causality"), "{err}");
    }

    #[test]
    fn reduction_collectives_valid() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for (algo, spec) in [
            (Algorithm::RingReduceScatter, CollectiveSpec::reduce_scatter(16, 1 << 20)),
            (Algorithm::RingAllgather, CollectiveSpec::allgather(16, 1 << 20)),
            (Algorithm::RingAllreduce, CollectiveSpec::allreduce(16, 1 << 20)),
            (Algorithm::TreeAllreduce { k: 2 }, CollectiveSpec::allreduce(16, 8 << 10)),
            (Algorithm::TreeAllreduce { k: 4 }, CollectiveSpec::allreduce(16, 8 << 10)),
        ] {
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
        }
    }

    #[test]
    fn dropped_reduction_edge_detected() {
        // sabotage a ring allreduce: drop one reduce-scatter flow edge so
        // its contribution never folds in — every final buffer for that
        // segment must come up short
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        bp.edges.remove(0);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::Contribution, "{err}");
        assert!(err.to_string().contains("appears"), "unexpected error: {err}");
    }

    #[test]
    fn duplicated_reduce_edge_detected() {
        // shipping the same contribution twice must be rejected
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        let dup = bp.edges[0];
        bp.edges.push(dup);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::DuplicateEdge, "{err}");
        assert!(err.to_string().contains("duplicate"), "unexpected error: {err}");
    }

    #[test]
    fn duplicated_copy_edge_detected() {
        // copy replay is idempotent, so double deliveries must be caught
        // structurally — duplicate an allgather-phase edge
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        let ag_edge = *bp
            .edges
            .iter()
            .find(|e| e.sem == crate::collectives::EdgeSem::Copy)
            .expect("ring allreduce has copy edges");
        bp.edges.push(ag_edge);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::DuplicateEdge, "{err}");
    }

    #[test]
    fn wrong_chunk_count_rejected() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::reduce_scatter(4, 4096);
        let mut bp = crate::collectives::reduce_scatter::plan(&mut comm, &spec);
        bp.n_chunks = 2;
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert_eq!(err.code, Code::ChunkCount, "{err}");
    }
}
