//! Collective-plan invariants.
//!
//! Broadcast plans must satisfy delivery + causality: every non-root rank
//! is delivered every chunk exactly once, and no rank forwards a chunk
//! before the simulator says it arrived. Reduction collectives
//! (reduce-scatter / allgather / allreduce) are checked by *dataflow
//! replay*: every rank starts with its own contribution, each
//! [`FlowEdge`] moves the source's accumulated contribution-set at the
//! op's start time (applying [`EdgeSem::Copy`] or [`EdgeSem::Reduce`] at
//! completion), and the final buffers must reflect **all n contributions
//! exactly once**. These are the invariants the property tests in
//! `rust/tests/` sweep across random topologies, roots, sizes and
//! algorithms.

use std::collections::HashMap;

use crate::netsim::{Engine, ExecResult};

use super::traits::{CollectiveKind, CollectivePlan, EdgeSem, FlowEdge};

/// Validate a plan against an execution of it, dispatching on the spec's
/// collective kind.
pub fn validate(bp: &CollectivePlan, result: &ExecResult) -> Result<(), String> {
    match bp.spec.kind {
        CollectiveKind::Broadcast => validate_broadcast(bp, result),
        _ => validate_dataflow(bp, result),
    }
}

/// Broadcast checks:
/// * coverage — every (non-root rank, chunk) has a labelled delivery;
/// * causality — each flow edge's op *starts* no earlier than the
///   delivery of that chunk at the edge's source rank (the root owns all
///   chunks at t=0);
/// * uniqueness — no two labelled ops deliver the same (rank, chunk).
fn validate_broadcast(bp: &CollectivePlan, result: &ExecResult) -> Result<(), String> {
    let spec = &bp.spec;

    // uniqueness + coverage from labels
    let mut seen: HashMap<(usize, usize), usize> = HashMap::new();
    for (id, label) in bp.plan.labels.iter().enumerate() {
        if let Some((rank, chunk)) = *label {
            if rank >= spec.n_ranks {
                return Err(format!("delivery to out-of-range rank {rank}"));
            }
            if chunk >= bp.n_chunks {
                return Err(format!("delivery of out-of-range chunk {chunk}"));
            }
            if let Some(prev) = seen.insert((rank, chunk), id) {
                return Err(format!(
                    "duplicate delivery of chunk {chunk} to rank {rank} (ops {prev} and {id})"
                ));
            }
        }
    }
    for rank in 0..spec.n_ranks {
        if rank == spec.root {
            continue;
        }
        for chunk in 0..bp.n_chunks {
            if !seen.contains_key(&(rank, chunk)) {
                return Err(format!("rank {rank} never receives chunk {chunk}"));
            }
        }
    }

    // possession: when each rank first holds each chunk (via *any* flow
    // edge, including scatter custody that labels don't record)
    let mut possession: HashMap<(usize, usize), u64> = HashMap::new();
    for edge in &bp.edges {
        let t = result.done[edge.op];
        possession
            .entry((edge.dst, edge.chunk))
            .and_modify(|v| *v = (*v).min(t))
            .or_insert(t);
    }

    // causality over flow edges
    for edge in &bp.edges {
        if edge.src == spec.root {
            continue; // root owns everything at t=0
        }
        let have_at = match possession.get(&(edge.src, edge.chunk)) {
            Some(&t) => t,
            None => {
                return Err(format!(
                    "edge {} -> {} forwards chunk {} the source never received",
                    edge.src, edge.dst, edge.chunk
                ))
            }
        };
        let starts = result.start[edge.op];
        if starts < have_at {
            return Err(format!(
                "causality violation: rank {} forwards chunk {} at {}ns but receives it at {}ns",
                edge.src, edge.chunk, starts, have_at
            ));
        }
    }
    Ok(())
}

/// Per-(rank, chunk) contribution counters: `counts[i]` is how many times
/// rank `i`'s contribution has been folded in.
type Contribs = Vec<u32>;

fn is_zero(c: &Contribs) -> bool {
    c.iter().all(|&x| x == 0)
}

/// Reduction-collective checks by dataflow replay: edges capture their
/// payload (the source's contribution-set) at the op's start time and
/// apply it at the dst (copy = replace, reduce = fold) at completion;
/// the final state must match the collective's contract exactly.
fn validate_dataflow(bp: &CollectivePlan, result: &ExecResult) -> Result<(), String> {
    let spec = &bp.spec;
    let n = spec.n_ranks;
    let k = bp.n_chunks;

    if matches!(
        spec.kind,
        CollectiveKind::ReduceScatter | CollectiveKind::Allgather
    ) && k != n
    {
        return Err(format!(
            "{} plan must carry one chunk per rank (got {k} chunks for {n} ranks)",
            spec.kind.name()
        ));
    }

    let mut seen_edges = std::collections::HashSet::new();
    for e in &bp.edges {
        if e.src >= n || e.dst >= n {
            return Err(format!("edge {} -> {} out of rank range", e.src, e.dst));
        }
        if e.chunk >= k {
            return Err(format!("edge carries out-of-range chunk {}", e.chunk));
        }
        if e.op >= result.done.len() {
            return Err(format!("edge references unknown op {}", e.op));
        }
        // copy application is idempotent in the replay, so duplicated
        // transfers (wasted traffic, double delivery) must be rejected
        // structurally
        if !seen_edges.insert((e.src, e.dst, e.chunk, e.sem)) {
            return Err(format!(
                "duplicate flow edge {} -> {} for chunk {}",
                e.src, e.dst, e.chunk
            ));
        }
    }

    // labelled deliveries must be unique, as in the broadcast validator
    let mut seen_labels: HashMap<(usize, usize), usize> = HashMap::new();
    for (id, label) in bp.plan.labels.iter().enumerate() {
        if let Some((rank, chunk)) = *label {
            if rank >= n || chunk >= k {
                return Err(format!("delivery label ({rank}, {chunk}) out of range"));
            }
            if let Some(prev) = seen_labels.insert((rank, chunk), id) {
                return Err(format!(
                    "duplicate delivery of chunk {chunk} to rank {rank} (ops {prev} and {id})"
                ));
            }
        }
    }

    // initial contributions
    let mut state: Vec<Vec<Contribs>> = vec![vec![vec![0u32; n]; k]; n];
    match spec.kind {
        // broadcast plans take the label-based path in `validate`
        CollectiveKind::Broadcast => unreachable!("broadcast uses validate_broadcast"),
        CollectiveKind::ReduceScatter | CollectiveKind::Allreduce => {
            for (r, chunks) in state.iter_mut().enumerate() {
                for counts in chunks.iter_mut() {
                    counts[r] = 1;
                }
            }
        }
        CollectiveKind::Allgather => {
            // segment r originates at rank r
            for (r, chunks) in state.iter_mut().enumerate() {
                chunks[r][r] = 1;
            }
        }
    }

    // replay edges in virtual-time order: completions apply before
    // captures at the same instant (an arrival at t may feed a forward
    // starting at t, matching the engine's dependency semantics)
    const APPLY: u8 = 0;
    const CAPTURE: u8 = 1;
    let mut events: Vec<(u64, u8, usize)> = Vec::with_capacity(2 * bp.edges.len());
    for (i, e) in bp.edges.iter().enumerate() {
        events.push((result.start[e.op], CAPTURE, i));
        events.push((result.done[e.op], APPLY, i));
    }
    events.sort_unstable();

    let capture = |edge: &FlowEdge, state: &[Vec<Contribs>]| -> Result<Contribs, String> {
        let snap = state[edge.src][edge.chunk].clone();
        if is_zero(&snap) {
            return Err(format!(
                "causality violation: rank {} forwards chunk {} before holding any data for it",
                edge.src, edge.chunk
            ));
        }
        Ok(snap)
    };

    let mut payloads: Vec<Option<Contribs>> = vec![None; bp.edges.len()];
    for (_t, phase, i) in events {
        let edge = &bp.edges[i];
        if phase == CAPTURE {
            if payloads[i].is_none() {
                payloads[i] = Some(capture(edge, &state)?);
            }
        } else {
            // zero-duration ops may see APPLY sorted before their own
            // CAPTURE at the same instant: capture on demand
            let payload = match payloads[i].take() {
                Some(p) => p,
                None => capture(edge, &state)?,
            };
            match edge.sem {
                EdgeSem::Reduce => {
                    for (acc, add) in state[edge.dst][edge.chunk].iter_mut().zip(&payload) {
                        *acc += add;
                    }
                }
                EdgeSem::Copy => state[edge.dst][edge.chunk] = payload,
            }
        }
    }

    // final contracts
    let check = |rank: usize, chunk: usize, want: &dyn Fn(usize) -> u32| -> Result<(), String> {
        for (i, &got) in state[rank][chunk].iter().enumerate() {
            let want = want(i);
            if got != want {
                return Err(format!(
                    "rank {rank} chunk {chunk}: contribution from rank {i} \
                     appears {got} times (want {want})"
                ));
            }
        }
        Ok(())
    };
    match spec.kind {
        CollectiveKind::Broadcast => unreachable!("broadcast uses validate_broadcast"),
        CollectiveKind::Allreduce => {
            for r in 0..n {
                for c in 0..k {
                    check(r, c, &|_| 1)?;
                }
            }
        }
        CollectiveKind::ReduceScatter => {
            // rank s must own the full reduction of segment s
            for s in 0..n {
                check(s, s, &|_| 1)?;
            }
        }
        CollectiveKind::Allgather => {
            for r in 0..n {
                for c in 0..k {
                    check(r, c, &|i| u32::from(i == c))?;
                }
            }
        }
    }
    Ok(())
}

/// Convenience: plan + execute + validate in one call.
pub fn check_algorithm(
    algo: &super::Algorithm,
    comm: &mut crate::comm::Comm,
    engine: &mut Engine,
    spec: &super::CollectiveSpec,
) -> Result<u64, String> {
    let bp = super::plan(algo, comm, spec);
    let result = engine.execute(&bp.plan);
    validate(&bp, &result)?;
    Ok(result.makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Algorithm, BcastSpec, CollectiveSpec};
    use crate::comm::Comm;
    use crate::topology::presets::{flat, kesch};

    fn all_algorithms() -> Vec<Algorithm> {
        vec![
            Algorithm::Direct,
            Algorithm::Chain,
            Algorithm::PipelinedChain { chunk: 64 << 10 },
            Algorithm::Knomial { k: 2 },
            Algorithm::Knomial { k: 4 },
            Algorithm::ScatterRingAllgather,
            Algorithm::HostStagedKnomial { k: 2 },
        ]
    }

    #[test]
    fn all_algorithms_valid_on_flat() {
        let c = flat(8);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            for root in [0, 3] {
                for bytes in [4u64, 8192, 1 << 20] {
                    let spec = BcastSpec::new(root, 8, bytes);
                    check_algorithm(&algo, &mut comm, &mut engine, &spec)
                        .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
                }
            }
        }
    }

    #[test]
    fn all_algorithms_valid_on_kesch_multinode() {
        let c = kesch(2, 8);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for algo in all_algorithms() {
            let spec = BcastSpec::new(0, 16, 256 << 10);
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
        }
    }

    #[test]
    fn missing_delivery_detected() {
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1024);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: drop the final edge's label (set_label keeps the
        // memoized deliveries map in sync)
        let last = bp.plan.len() - 1;
        bp.plan.set_label(last, None);
        let result = engine.execute(&bp.plan);
        assert!(validate(&bp, &result).is_err());
    }

    #[test]
    fn causality_violation_detected() {
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 1 << 20);
        let mut bp = crate::collectives::chain::plan(&mut comm, &spec);
        // sabotage: remove the dependency of the second hop so rank 1
        // "forwards" before receiving
        bp.plan.deps[1] = crate::netsim::Deps::none();
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert!(err.contains("causality"), "{err}");
    }

    #[test]
    fn reduction_collectives_valid() {
        let c = kesch(2, 8);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for (algo, spec) in [
            (Algorithm::RingReduceScatter, CollectiveSpec::reduce_scatter(16, 1 << 20)),
            (Algorithm::RingAllgather, CollectiveSpec::allgather(16, 1 << 20)),
            (Algorithm::RingAllreduce, CollectiveSpec::allreduce(16, 1 << 20)),
            (Algorithm::TreeAllreduce { k: 2 }, CollectiveSpec::allreduce(16, 8 << 10)),
            (Algorithm::TreeAllreduce { k: 4 }, CollectiveSpec::allreduce(16, 8 << 10)),
        ] {
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
        }
    }

    #[test]
    fn dropped_reduction_edge_detected() {
        // sabotage a ring allreduce: drop one reduce-scatter flow edge so
        // its contribution never folds in — every final buffer for that
        // segment must come up short
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        bp.edges.remove(0);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert!(err.contains("appears"), "unexpected error: {err}");
    }

    #[test]
    fn duplicated_reduce_edge_detected() {
        // shipping the same contribution twice must be rejected
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        let dup = bp.edges[0];
        bp.edges.push(dup);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert!(err.contains("duplicate"), "unexpected error: {err}");
    }

    #[test]
    fn duplicated_copy_edge_detected() {
        // copy replay is idempotent, so double deliveries must be caught
        // structurally — duplicate an allgather-phase edge
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(4, 4096);
        let mut bp = crate::collectives::allreduce::ring(&mut comm, &spec);
        let ag_edge = *bp
            .edges
            .iter()
            .find(|e| e.sem == crate::collectives::EdgeSem::Copy)
            .expect("ring allreduce has copy edges");
        bp.edges.push(ag_edge);
        let result = engine.execute(&bp.plan);
        let err = validate(&bp, &result).unwrap_err();
        assert!(err.contains("duplicate"), "unexpected error: {err}");
    }

    #[test]
    fn wrong_chunk_count_rejected() {
        let c = flat(4);
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::reduce_scatter(4, 4096);
        let mut bp = crate::collectives::reduce_scatter::plan(&mut comm, &spec);
        bp.n_chunks = 2;
        let result = engine.execute(&bp.plan);
        assert!(validate(&bp, &result).is_err());
    }
}
