//! Allreduce: every rank contributes a full buffer and every rank ends
//! with the element-wise reduction — the collective that dominates modern
//! data-parallel DNN training (gradient averaging), and the first
//! post-paper workload this framework models.
//!
//! Two designs, mirroring the broadcast menu's latency/bandwidth split:
//!
//! * [`ring`] — ring reduce-scatter followed by ring allgather. Each rank
//!   moves `2·(n−1)/n × M` bytes: bandwidth-optimal, the large-message
//!   winner.  `T = 2 × (n−1) × (t_s + M/(nB))`
//! * [`tree`] — k-nomial reduce to a root followed by a k-nomial
//!   broadcast. `2·⌈log_k n⌉` rounds of the full message: latency-optimal
//!   for small messages where `t_s` dominates.
//!   `T ≈ 2 × ⌈log_k n⌉ × (t_s + M/B)`
//!
//! Reduction arithmetic is modelled as free (see
//! [`super::reduce_scatter`]).

use crate::comm::{chunk::equal_parts, Comm};
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{CollectiveKind, CollectivePlan, CollectiveSpec, FlowEdge};

/// Ring allreduce: reduce-scatter phase (reduce edges) then allgather
/// phase (copy edges) in one plan.
pub fn ring(comm: &mut Comm, spec: &CollectiveSpec) -> CollectivePlan {
    ring_template(comm, spec).cp
}

pub fn ring_template(comm: &mut Comm, spec: &CollectiveSpec) -> CollectiveTemplate {
    debug_assert_eq!(spec.kind, CollectiveKind::Allreduce);
    let n = spec.n_ranks;
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if n == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: CollectivePlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: "ring-allreduce".into(),
            },
        };
    }
    let parts = equal_parts(spec.bytes, n);

    // ---- phase 1: ring reduce-scatter --------------------------------
    // acc[v][s] = op after which rank v's partial for segment s contains
    // every upstream contribution (None = own contribution only)
    let mut acc: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];
    for t in 0..n - 1 {
        let mut arrivals: Vec<(usize, usize, OpId)> = Vec::new();
        for v in 0..n {
            let s = (v + n - t - 1) % n;
            let dst = (v + 1) % n;
            let deps = Deps::from_opt(acc[v][s]);
            // the last hop delivers rank s its fully reduced segment
            let label = if t == n - 2 { Some((dst, s)) } else { None };
            let mark = plan.len();
            let op = comm.send(&mut plan, v, dst, parts[s], deps, label);
            rec.tag(
                &plan,
                mark,
                ByteRole::Part {
                    index: s as u32,
                    of: n as u32,
                },
                comm.size_class_of(parts[s]),
            );
            edges.push(FlowEdge::reduce(v, dst, s, op));
            arrivals.push((dst, s, op));
        }
        for (dst, s, op) in arrivals {
            acc[dst][s] = Some(op);
        }
    }

    // ---- phase 2: ring allgather of the reduced segments -------------
    // own[v][c] = op after which rank v holds the *final* segment c
    let mut own: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];
    for (v, row) in own.iter_mut().enumerate() {
        row[v] = acc[v][v]; // set by the reduce-scatter's last step (n >= 2)
        debug_assert!(row[v].is_some(), "reduce-scatter left rank {v} empty");
    }
    for t in 0..n - 1 {
        let mut arrivals: Vec<(usize, usize, OpId)> = Vec::new();
        for v in 0..n {
            let c = (v + n - t) % n;
            let dst = (v + 1) % n;
            let deps = Deps::from_opt(own[v][c]);
            let mark = plan.len();
            let op = comm.send(&mut plan, v, dst, parts[c], deps, Some((dst, c)));
            rec.tag(
                &plan,
                mark,
                ByteRole::Part {
                    index: c as u32,
                    of: n as u32,
                },
                comm.size_class_of(parts[c]),
            );
            edges.push(FlowEdge::copy(v, dst, c, op));
            arrivals.push((dst, c, op));
        }
        for (dst, c, op) in arrivals {
            own[dst][c] = Some(op);
        }
    }

    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: CollectivePlan {
            plan,
            edges,
            n_chunks: n,
            spec: spec.clone(),
            algorithm: "ring-allreduce".into(),
        },
    }
}

/// Tree allreduce: k-nomial reduce to `spec.root`, then k-nomial
/// broadcast of the reduced buffer.
pub fn tree(comm: &mut Comm, spec: &CollectiveSpec, k: usize) -> CollectivePlan {
    tree_template(comm, spec, k).cp
}

pub fn tree_template(comm: &mut Comm, spec: &CollectiveSpec, k: usize) -> CollectiveTemplate {
    debug_assert_eq!(spec.kind, CollectiveKind::Allreduce);
    assert!(k >= 2, "tree allreduce requires k >= 2");
    let n = spec.n_ranks;
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if n == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: CollectivePlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: format!("tree-allreduce(k={k})"),
            },
        };
    }
    let class = comm.size_class_of(spec.bytes);

    // ---- phase 1: k-nomial reduce toward relabeled rank 0 -------------
    // acc[v] = ops that must complete before relabeled rank v's partial
    // holds its whole subtree's contributions
    let mut acc: Vec<Vec<OpId>> = vec![Vec::new(); n];
    reduce_range(comm, &mut plan, &mut rec, &mut edges, spec, k, class, 0, n, &mut acc);

    // ---- phase 2: k-nomial broadcast of the reduced buffer ------------
    let root_ready = acc[0].clone();
    bcast_range(
        comm,
        &mut plan,
        &mut rec,
        &mut edges,
        spec,
        k,
        class,
        0,
        n,
        &root_ready,
    );

    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: CollectivePlan {
            plan,
            edges,
            n_chunks: 1,
            spec: spec.clone(),
            algorithm: format!("tree-allreduce(k={k})"),
        },
    }
}

/// Split `[lo, lo+size)` into k near-equal sub-ranges (the split used by
/// [`super::knomial`], mirrored here for both tree phases).
fn knomial_ranges(k: usize, lo: usize, size: usize) -> Vec<(usize, usize)> {
    let sub = size.div_ceil(k);
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut cursor = lo;
    while cursor < lo + size {
        let len = sub.min(lo + size - cursor);
        ranges.push((cursor, len));
        cursor += len;
    }
    ranges
}

/// Reduce relabeled range `[lo, lo+size)` onto its head `lo`: every
/// sub-range first reduces onto its own head, then the sub-heads send
/// their accumulated partials to `lo` (reduce edges).
#[allow(clippy::too_many_arguments)]
fn reduce_range(
    comm: &mut Comm,
    plan: &mut crate::netsim::Plan,
    rec: &mut RoleRecorder,
    edges: &mut Vec<FlowEdge>,
    spec: &CollectiveSpec,
    k: usize,
    class: u8,
    lo: usize,
    size: usize,
    acc: &mut Vec<Vec<OpId>>,
) {
    if size <= 1 {
        return;
    }
    let ranges = knomial_ranges(k, lo, size);
    let head_len = ranges[0].1;
    reduce_range(comm, plan, rec, edges, spec, k, class, lo, head_len, acc);
    for &(start, len) in ranges.iter().skip(1) {
        reduce_range(comm, plan, rec, edges, spec, k, class, start, len, acc);
        let src = spec.unlabel(start);
        let dst = spec.unlabel(lo);
        // the sub-head's partial is complete only after all its receives
        // (≤2 children inline, wider joins spill)
        let deps = Deps::from_slice(&acc[start]);
        let mark = plan.len();
        let op = comm.send(plan, src, dst, spec.bytes, deps, None);
        rec.tag(plan, mark, ByteRole::Whole, class);
        edges.push(FlowEdge::reduce(src, dst, 0, op));
        acc[lo].push(op);
    }
}

/// Broadcast the reduced buffer through relabeled range `[lo, lo+size)`
/// whose head already holds it once every op in `have` completes.
#[allow(clippy::too_many_arguments)]
fn bcast_range(
    comm: &mut Comm,
    plan: &mut crate::netsim::Plan,
    rec: &mut RoleRecorder,
    edges: &mut Vec<FlowEdge>,
    spec: &CollectiveSpec,
    k: usize,
    class: u8,
    lo: usize,
    size: usize,
    have: &[OpId],
) {
    if size <= 1 {
        return;
    }
    let ranges = knomial_ranges(k, lo, size);
    let mut child_ops: Vec<(usize, usize, OpId)> = Vec::new();
    for &(start, len) in ranges.iter().skip(1) {
        let src = spec.unlabel(lo);
        let dst = spec.unlabel(start);
        let mark = plan.len();
        let op = comm.send(plan, src, dst, spec.bytes, Deps::from_slice(have), Some((dst, 0)));
        rec.tag(plan, mark, ByteRole::Whole, class);
        edges.push(FlowEdge::copy(src, dst, 0, op));
        child_ops.push((start, len, op));
    }
    let head_len = ranges[0].1;
    bcast_range(comm, plan, rec, edges, spec, k, class, lo, head_len, have);
    for (start, len, op) in child_ops {
        bcast_range(comm, plan, rec, edges, spec, k, class, start, len, &[op]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::validate::validate;
    use crate::netsim::Engine;
    use crate::topology::presets::{flat, kesch};

    #[test]
    fn ring_all_contributions_exactly_once() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for bytes in [0u64, 4, 8192, 1 << 20] {
            let spec = CollectiveSpec::allreduce(8, bytes);
            let cp = ring(&mut comm, &spec);
            let result = engine.execute(&cp.plan);
            validate(&cp, &result).unwrap_or_else(|e| panic!("{bytes}B: {e}"));
        }
    }

    #[test]
    fn tree_all_contributions_exactly_once() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        for k in [2, 3, 4, 8] {
            for root in [0, 5] {
                let spec =
                    CollectiveSpec::collective(CollectiveKind::Allreduce, root, 16, 64 << 10);
                let cp = tree(&mut comm, &spec, k);
                let result = engine.execute(&cp.plan);
                validate(&cp, &result).unwrap_or_else(|e| panic!("k={k} root={root}: {e}"));
            }
        }
    }

    #[test]
    fn ring_traffic_is_bandwidth_optimal() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let m: u64 = 8 << 20;
        let spec = CollectiveSpec::allreduce(8, m);
        let cp = ring(&mut comm, &spec);
        // 2 phases × (n-1) steps × n concurrent sends of M/n
        assert_eq!(cp.plan.total_bytes(), 2 * (8 - 1) * m);
    }

    #[test]
    fn tree_edge_and_traffic_accounting() {
        let c = flat(9).unwrap();
        let mut comm = Comm::new(&c);
        let spec = CollectiveSpec::allreduce(9, 4096);
        let cp = tree(&mut comm, &spec, 3);
        // n-1 reduce sends + n-1 bcast sends, full message each
        assert_eq!(cp.edges.len(), 2 * 8);
        assert_eq!(cp.plan.total_bytes(), 2 * 8 * 4096);
    }

    #[test]
    fn ring_beats_tree_for_large_messages() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(8, 64 << 20);
        let t_ring = engine.execute(&ring(&mut comm, &spec).plan).makespan;
        let t_tree = engine.execute(&tree(&mut comm, &spec, 2).plan).makespan;
        assert!(t_ring < t_tree, "ring {t_ring} vs tree {t_tree}");
    }

    #[test]
    fn tree_beats_ring_for_small_messages_at_scale() {
        let c = kesch(1, 16).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allreduce(16, 4);
        let t_ring = engine.execute(&ring(&mut comm, &spec).plan).makespan;
        let t_tree = engine.execute(&tree(&mut comm, &spec, 2).plan).makespan;
        assert!(t_tree < t_ring, "tree {t_tree} vs ring {t_ring}");
    }

    #[test]
    fn ring_cost_matches_model_on_flat() {
        // 2 × (n-1) pipelined segment hops
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m: u64 = 8 << 20;
        let hop = comm.estimate_ns(0, 1, m / 8);
        let spec = CollectiveSpec::allreduce(8, m);
        let cp = ring(&mut comm, &spec);
        let r = engine.execute(&cp.plan);
        assert_eq!(r.makespan, 2 * 7 * hop);
    }

    #[test]
    fn single_rank_noop() {
        let c = flat(1).unwrap();
        let mut comm = Comm::new(&c);
        let spec = CollectiveSpec::allreduce(1, 100);
        assert!(ring(&mut comm, &spec).plan.is_empty());
        assert!(tree(&mut comm, &spec, 2).plan.is_empty());
    }
}
