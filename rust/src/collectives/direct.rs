//! Direct algorithm (§III-A, Eq. 1): a serialized loop of sends from the
//! root. `T = n × (t_s + M/B)`. Never competitive — kept as the baseline
//! the paper models first.

use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &BcastSpec) -> BcastPlan {
    template(comm, spec).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec) -> CollectiveTemplate {
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    let class = comm.size_class_of(spec.bytes);
    let mut prev: Option<crate::netsim::OpId> = None;
    for v in 1..spec.n_ranks {
        let dst = spec.unlabel(v);
        // blocking MPI_Send loop: each send departs after the previous
        // completes
        let deps = Deps::from_opt(prev);
        let mark = plan.len();
        let op = comm.send(&mut plan, spec.root, dst, spec.bytes, deps, Some((dst, 0)));
        rec.tag(&plan, mark, ByteRole::Whole, class);
        edges.push(FlowEdge::copy(spec.root, dst, 0, op));
        prev = Some(op);
    }
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: 1,
            spec: spec.clone(),
            algorithm: "direct".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn cost_is_n_minus_one_serial_sends() {
        let c = flat(5).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 5, 1 << 20);
        let one = comm.estimate_ns(0, 1, 1 << 20);
        let bp = plan(&mut comm, &spec);
        let r = engine.execute(&bp.plan);
        assert_eq!(r.makespan, 4 * one);
    }

    #[test]
    fn single_rank_empty_plan() {
        let c = flat(1).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(0, 1, 1024);
        let bp = plan(&mut comm, &spec);
        assert!(bp.plan.is_empty());
    }

    #[test]
    fn nonzero_root_covers_all() {
        let c = flat(4).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(2, 4, 64);
        let bp = plan(&mut comm, &spec);
        let mut dsts: Vec<usize> = bp.edges.iter().map(|e| e.dst).collect();
        dsts.sort_unstable();
        assert_eq!(dsts, vec![0, 1, 3]);
    }
}
