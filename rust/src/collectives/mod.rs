//! Collective algorithms — the paper's broadcast menu (§III and §IV)
//! generalized into a collective-agnostic layer with reduction
//! collectives on top.
//!
//! Every algorithm builds a [`CollectivePlan`] — a netsim op DAG plus
//! rank-level data-flow edges with copy/reduce semantics — from a
//! [`Comm`] point-to-point engine, for a [`CollectiveSpec`] naming the
//! operation ([`CollectiveKind`]), root, rank count and message size.
//!
//! **Broadcast** (the paper's subject; `BcastSpec`/`BcastPlan` are thin
//! aliases kept for these builders): the paper's contribution, the
//! **pipelined chain** (§IV-B, Eq. 5), lives in [`pipelined_chain`]; the
//! classical baselines of §III-A are [`direct`] (Eq. 1), [`chain`]
//! (Eq. 2), [`knomial`] (Eq. 3, binomial at k=2) and [`scatter_allgather`]
//! (Eq. 4); the GPU-specific host-staged k-nomial of §IV-C is
//! [`host_staged`] (Eq. 6).
//!
//! **Reduction collectives** (the post-paper workload — gradient
//! exchange for data-parallel training): [`reduce_scatter`] and
//! [`allgather`] are the classic rings; [`allreduce`] composes them into
//! the bandwidth-optimal ring allreduce and adds a latency-optimal
//! k-nomial reduce→broadcast tree for small messages.
//!
//! [`validate`] checks the invariants every plan must satisfy —
//! delivery + causality for broadcast, all-contributions-exactly-once
//! dataflow for reductions; the property tests in `rust/tests/` lean on
//! it.

pub mod allgather;
pub mod allreduce;
pub mod chain;
pub mod direct;
pub mod host_staged;
pub mod knomial;
pub mod pipelined_chain;
pub mod reduce_scatter;
pub mod scatter_allgather;
pub mod template;
pub mod traits;
pub mod validate;

pub use template::{cached_plan, CollectiveTemplate, TemplateCache};
pub use traits::{
    Algorithm, BcastPlan, BcastSpec, CollectiveKind, CollectivePlan, CollectiveSpec, EdgeSem,
    FlowEdge,
};

use crate::comm::Comm;

/// Build the template for `algo` over all cluster ranks: the plan plus
/// the per-op byte roles that let the template cache rescale it across
/// the message-size axis. The algorithm must implement the spec's
/// collective kind.
pub fn template_for(
    algo: &Algorithm,
    comm: &mut Comm,
    spec: &CollectiveSpec,
) -> CollectiveTemplate {
    debug_assert_eq!(
        algo.kind(),
        spec.kind,
        "{} cannot build a {} plan",
        algo.name(),
        spec.kind.name()
    );
    match algo {
        Algorithm::Direct => direct::template(comm, spec),
        Algorithm::Chain => chain::template(comm, spec),
        Algorithm::PipelinedChain { chunk } => pipelined_chain::template(comm, spec, *chunk),
        Algorithm::Knomial { k } => knomial::template(comm, spec, *k),
        Algorithm::ScatterRingAllgather => scatter_allgather::template(comm, spec),
        Algorithm::HostStagedKnomial { k } => host_staged::template(comm, spec, *k),
        Algorithm::RingReduceScatter => reduce_scatter::template(comm, spec),
        Algorithm::RingAllgather => allgather::template(comm, spec),
        Algorithm::RingAllreduce => allreduce::ring_template(comm, spec),
        Algorithm::TreeAllreduce { k } => allreduce::tree_template(comm, spec, *k),
    }
}

/// Build a fresh plan for `algo` (no template caching — one-off callers
/// and the parity suites; hot paths go through [`cached_plan`]).
pub fn plan(algo: &Algorithm, comm: &mut Comm, spec: &CollectiveSpec) -> CollectivePlan {
    let cp = template_for(algo, comm, spec).cp;
    // debug builds statically verify every freshly built collective plan
    // (DAG + routes + dataflow contract); no-op in release
    crate::analysis::debug_verify_collective(comm.cluster(), &cp, "collectives::plan");
    cp
}

/// Simulated collective latency (plan makespan), ns. Acquires the plan
/// through the comm's template cache — across a sweep's message-size
/// axis the DAG is built once and rescaled — and uses the engine's
/// makespan-only execution path, so the inner loop performs no per-op
/// heap allocation (DESIGN.md §Perf, §Plan templates).
pub fn latency_ns(
    algo: &Algorithm,
    comm: &mut Comm,
    engine: &mut crate::netsim::Engine,
    spec: &CollectiveSpec,
) -> u64 {
    let bp = cached_plan(algo, comm, spec);
    engine.makespan_ns(&bp.plan)
}
