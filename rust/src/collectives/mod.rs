//! Broadcast algorithms (§III and §IV of the paper).
//!
//! Every algorithm builds a [`BcastPlan`] — a netsim op DAG plus rank-level
//! data-flow edges — from a [`Comm`] point-to-point engine. The paper's
//! contribution, the **pipelined chain** (§IV-B, Eq. 5), lives in
//! [`pipelined_chain`]; the classical baselines of §III-A are
//! [`direct`] (Eq. 1), [`chain`] (Eq. 2), [`knomial`] (Eq. 3, binomial at
//! k=2) and [`scatter_allgather`] (Eq. 4); the GPU-specific host-staged
//! k-nomial of §IV-C is [`host_staged`] (Eq. 6).
//!
//! [`validate`] checks the causality and delivery invariants every plan
//! must satisfy; the property tests in `rust/tests/` lean on it.

pub mod chain;
pub mod direct;
pub mod host_staged;
pub mod knomial;
pub mod pipelined_chain;
pub mod scatter_allgather;
pub mod traits;
pub mod validate;

pub use traits::{Algorithm, BcastPlan, BcastSpec, FlowEdge};

use crate::comm::Comm;

/// Build the plan for `algo` over all cluster ranks.
pub fn plan(algo: &Algorithm, comm: &mut Comm, spec: &BcastSpec) -> BcastPlan {
    match algo {
        Algorithm::Direct => direct::plan(comm, spec),
        Algorithm::Chain => chain::plan(comm, spec),
        Algorithm::PipelinedChain { chunk } => pipelined_chain::plan(comm, spec, *chunk),
        Algorithm::Knomial { k } => knomial::plan(comm, spec, *k),
        Algorithm::ScatterRingAllgather => scatter_allgather::plan(comm, spec),
        Algorithm::HostStagedKnomial { k } => host_staged::plan(comm, spec, *k),
    }
}

/// Simulated broadcast latency (max over rank completions), ns.
pub fn latency_ns(
    algo: &Algorithm,
    comm: &mut Comm,
    engine: &mut crate::netsim::Engine,
    spec: &BcastSpec,
) -> u64 {
    let bp = plan(algo, comm, spec);
    let result = engine.execute(&bp.plan);
    result.makespan
}
