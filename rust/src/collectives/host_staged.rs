//! Host-staged k-nomial broadcast (§IV-C, Eq. 6):
//!
//! `T = M/B_PCIe + ⌈log_k n⌉ × (t_s + M/B)`
//!
//! The root copies GPU→host once, the broadcast runs between *hosts*
//! (cheap CPU-side sends: shared memory over QPI intranode, host-based IB
//! internode), and each host fans out to its local GPUs with GDR writes.
//! This sidesteps the GDR-read bottleneck entirely and — because the
//! up-front `M/B_PCIe` term is negligible for small `M` — it is the
//! small/medium-message winner the paper's tuned MV2-GDR-Opt selects.

use std::collections::HashMap;

use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps, OpId, NO_CLASS};
use crate::topology::DeviceId;

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

/// Host-to-host send startup costs (CPU-initiated, cheaper than
/// GPU-involved paths).
const HOST_INTRA_TS_NS: u64 = 600;
const HOST_INTER_EAGER_TS_NS: u64 = 1_600;
const HOST_INTER_RNDV_TS_NS: u64 = 4_200;
/// GDR H2D fan-out write: end-to-end latency vs back-to-back issue rate.
const GDR_WRITE_TS_NS: u64 = 1_300;
const GDR_WRITE_ISSUE_NS: u64 = 250;

pub fn plan(comm: &mut Comm, spec: &BcastSpec, k: usize) -> BcastPlan {
    template(comm, spec, k).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec, k: usize) -> CollectiveTemplate {
    assert!(k >= 2);
    let cluster = comm.cluster();
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if spec.n_ranks == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: BcastPlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: format!("host-staged-knomial(k={k})"),
            },
        };
    }

    // group ranks by staging host, in rank order; root's host first
    let mut host_of_rank: Vec<DeviceId> = Vec::with_capacity(spec.n_ranks);
    for r in 0..spec.n_ranks {
        host_of_rank.push(
            cluster
                .staging_host(cluster.rank_device(r))
                .expect("staging host"),
        );
    }
    let root_host = host_of_rank[spec.root];
    let mut hosts: Vec<DeviceId> = Vec::new();
    let mut ranks_of_host: HashMap<DeviceId, Vec<usize>> = HashMap::new();
    for r in 0..spec.n_ranks {
        let h = host_of_rank[(r + spec.root) % spec.n_ranks];
        if !hosts.contains(&h) {
            hosts.push(h);
        }
    }
    for r in 0..spec.n_ranks {
        ranks_of_host.entry(host_of_rank[r]).or_default().push(r);
    }
    debug_assert_eq!(hosts[0], root_host);

    // ---- stage 1: root GPU -> its host (the M/B_PCIe term) ---------------
    // fixed per-copy overhead, mechanism never varies with size: the
    // template can rescale this op across any class (NO_CLASS)
    let root_dev = cluster.rank_device(spec.root);
    let mark = plan.len();
    let d2h = comm.raw_transfer(
        &mut plan,
        root_dev,
        root_host,
        spec.bytes,
        comm.params().staging_copy_overhead_ns,
        Deps::none(),
        None,
    );
    rec.tag(&plan, mark, ByteRole::Whole, NO_CLASS);

    // ---- stage 2: k-nomial over hosts -------------------------------------
    // have[i] = op after which hosts[i] holds the data
    let mut have: Vec<Option<OpId>> = vec![None; hosts.len()];
    have[0] = Some(d2h);
    // the host-to-host startup cost switches at the eager threshold, so
    // these ops are class-sensitive
    let class = comm.size_class_of(spec.bytes);
    knomial_hosts(
        comm,
        &mut plan,
        &mut rec,
        &hosts,
        &mut have,
        k,
        class,
        0,
        hosts.len(),
        spec.bytes,
    );

    // ---- stage 3: each host fans out to its GPUs (GDR write) -------------
    for (i, &host) in hosts.iter().enumerate() {
        let have_op = have[i].expect("host missed data");
        for &r in &ranks_of_host[&host] {
            if r == spec.root {
                continue;
            }
            let gpu = cluster.rank_device(r);
            let mark = plan.len();
            let op = comm.raw_transfer_issue(
                &mut plan,
                host,
                gpu,
                spec.bytes,
                GDR_WRITE_TS_NS,
                GDR_WRITE_ISSUE_NS,
                Deps::one(have_op),
                Some((r, 0)),
            );
            rec.tag(&plan, mark, ByteRole::Whole, NO_CLASS);
            // attribute the rank-level edge to the nearest rank upstream:
            // the root (data origin) — host hops are transport detail
            edges.push(FlowEdge::copy(spec.root, r, 0, op));
        }
    }

    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: 1,
            spec: spec.clone(),
            algorithm: format!("host-staged-knomial(k={k})"),
        },
    }
}

/// K-nomial expansion over the host list (indices into `hosts`).
#[allow(clippy::too_many_arguments)]
fn knomial_hosts(
    comm: &mut Comm,
    plan: &mut crate::netsim::Plan,
    rec: &mut RoleRecorder,
    hosts: &[DeviceId],
    have: &mut [Option<OpId>],
    k: usize,
    class: u8,
    lo: usize,
    size: usize,
    bytes: u64,
) {
    if size <= 1 {
        return;
    }
    let sub = size.div_ceil(k);
    let mut cursor = lo;
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    while cursor < lo + size {
        let len = sub.min(lo + size - cursor);
        ranges.push((cursor, len));
        cursor += len;
    }
    let cluster = comm.cluster();
    for &(start, _len) in ranges.iter().skip(1) {
        let src = hosts[lo];
        let dst = hosts[start];
        let ts = if cluster.same_node(src, dst) {
            HOST_INTRA_TS_NS
        } else if bytes <= comm.params().eager_threshold {
            HOST_INTER_EAGER_TS_NS
        } else {
            HOST_INTER_RNDV_TS_NS
        };
        // serialization across the head's sends comes from its shared
        // egress link + creation order (see collectives::knomial)
        let deps = Deps::from_opt(have[lo]);
        let mark = plan.len();
        let op = comm.raw_transfer(plan, src, dst, bytes, ts, deps, None);
        rec.tag(plan, mark, ByteRole::Whole, class);
        have[start] = Some(op);
    }
    let (_, head_len) = ranges[0];
    knomial_hosts(comm, plan, rec, hosts, have, k, class, lo, head_len, bytes);
    for &(start, len) in ranges.iter().skip(1) {
        knomial_hosts(comm, plan, rec, hosts, have, k, class, start, len, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::kesch;

    #[test]
    fn covers_all_ranks_intranode() {
        let c = kesch(1, 16).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 16, 4);
        let bp = plan(&mut comm, &spec, 2);
        let result = engine.execute(&bp.plan);
        for r in 1..16 {
            assert!(result.delivery_time(&bp.plan, r, 0).is_some(), "rank {r}");
        }
    }

    #[test]
    fn small_message_beats_ipc_binomial_at_16_gpus() {
        // the §IV-C claim: for small M the staged design's M/B_PCIe cost
        // vanishes and host-side fan-out wins over GPU-to-GPU trees
        let c = kesch(1, 16).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 16, 4);
        let t_staged = engine.execute(&plan(&mut comm, &spec, 2).plan).makespan;
        let t_knomial = engine
            .execute(&super::super::knomial::plan(&mut comm, &spec, 2).plan)
            .makespan;
        assert!(
            t_staged < t_knomial,
            "staged {t_staged} vs knomial {t_knomial}"
        );
    }

    #[test]
    fn large_message_pays_pcie_staging() {
        // for very large M the M/B_PCIe term dominates and direct designs
        // win — exactly why the tuner switches algorithms
        let c = kesch(1, 4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 4, 128 << 20);
        let t_staged = engine.execute(&plan(&mut comm, &spec, 2).plan).makespan;
        let t_pipe = engine
            .execute(
                &super::super::pipelined_chain::plan(&mut comm, &spec, 4 << 20).plan,
            )
            .makespan;
        assert!(t_pipe < t_staged, "pipe {t_pipe} vs staged {t_staged}");
    }

    #[test]
    fn internode_hosts_participate() {
        let c = kesch(2, 8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 16, 8192);
        let bp = plan(&mut comm, &spec, 4);
        let result = engine.execute(&bp.plan);
        for r in 1..16 {
            assert!(result.delivery_time(&bp.plan, r, 0).is_some(), "rank {r}");
        }
    }

    #[test]
    fn nonzero_root_works() {
        let c = kesch(2, 4).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(5, 8, 1024);
        let bp = plan(&mut comm, &spec, 2);
        let result = engine.execute(&bp.plan);
        for r in 0..8 {
            if r != 5 {
                assert!(result.delivery_time(&bp.plan, r, 0).is_some());
            }
        }
    }
}
