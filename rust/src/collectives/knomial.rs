//! K-nomial tree algorithm (§III-A, Eq. 3): `T = ⌈log_k n⌉ × (t_s + M/B)`.
//!
//! At k = 2 this is the classic binomial tree — the workhorse of MPI
//! runtimes for small/medium messages. Implemented by recursive range
//! splitting: a holder of range `[lo, hi)` splits it into k sub-ranges,
//! keeps the first, and sends the whole message to the head of each other
//! sub-range (sequentially, as blocking sends do).

use crate::comm::Comm;
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{BcastPlan, BcastSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &BcastSpec, k: usize) -> BcastPlan {
    template(comm, spec, k).cp
}

pub fn template(comm: &mut Comm, spec: &BcastSpec, k: usize) -> CollectiveTemplate {
    assert!(k >= 2, "knomial requires k >= 2");
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    let class = comm.size_class_of(spec.bytes);
    // (holder, range) worklist in relabeled space; holder owns range[0]
    expand(
        comm,
        &mut plan,
        &mut rec,
        &mut edges,
        spec,
        k,
        class,
        0,
        spec.n_ranks,
        None,
    );
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: BcastPlan {
            plan,
            edges,
            n_chunks: 1,
            spec: spec.clone(),
            algorithm: format!("knomial(k={k})"),
        },
    }
}

/// Recursively broadcast within relabeled range `[lo, lo+size)` whose head
/// `lo` already holds the data as of op `have` (None = initial root data).
#[allow(clippy::too_many_arguments)]
fn expand(
    comm: &mut Comm,
    plan: &mut crate::netsim::Plan,
    rec: &mut RoleRecorder,
    edges: &mut Vec<FlowEdge>,
    spec: &BcastSpec,
    k: usize,
    class: u8,
    lo: usize,
    size: usize,
    have: Option<OpId>,
) {
    if size <= 1 {
        return;
    }
    // split [lo, lo+size) into k near-equal sub-ranges (ceil split keeps
    // the tree depth at ⌈log_k n⌉)
    let sub = size.div_ceil(k);
    let mut starts: Vec<(usize, usize)> = Vec::new(); // (start, len)
    let mut cursor = lo;
    while cursor < lo + size {
        let len = sub.min(lo + size - cursor);
        starts.push((cursor, len));
        cursor += len;
    }
    // The head keeps sub-range 0 and sends to each other head. Blocking-
    // send serialization is realised by the simulator: all these sends
    // share the head's egress link and the same ready time (`have`), so
    // they run in creation (= program) order, each occupying t_s + M/B.
    let mut child_ops: Vec<(usize, usize, OpId)> = Vec::new();
    for &(start, len) in starts.iter().skip(1) {
        let src = spec.unlabel(lo);
        let dst = spec.unlabel(start);
        let deps = Deps::from_opt(have);
        let mark = plan.len();
        let op = comm.send(plan, src, dst, spec.bytes, deps, Some((dst, 0)));
        rec.tag(plan, mark, ByteRole::Whole, class);
        edges.push(FlowEdge::copy(src, dst, 0, op));
        child_ops.push((start, len, op));
    }
    // recurse into sub-ranges
    let (_, head_len) = starts[0];
    expand(comm, plan, rec, edges, spec, k, class, lo, head_len, have);
    for (start, len, op) in child_ops {
        expand(comm, plan, rec, edges, spec, k, class, start, len, Some(op));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn binomial_depth_on_flat() {
        // with k=2 and n=8 the critical path is 3 rounds
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 8, 1 << 20);
        let hop = comm.estimate_ns(0, 1, 1 << 20);
        let bp = plan(&mut comm, &spec, 2);
        let t = engine.execute(&bp.plan).makespan;
        assert_eq!(t, 3 * hop);
    }

    #[test]
    fn edge_count_is_n_minus_one() {
        let c = flat(13).unwrap();
        let mut comm = Comm::new(&c);
        for k in [2, 3, 4, 8] {
            let spec = BcastSpec::new(0, 13, 4096);
            let bp = plan(&mut comm, &spec, k);
            assert_eq!(bp.edges.len(), 12, "k={k}");
        }
    }

    #[test]
    fn all_ranks_reached_any_root() {
        let c = flat(9).unwrap();
        let mut comm = Comm::new(&c);
        for root in [0, 4, 8] {
            let spec = BcastSpec::new(root, 9, 256);
            let bp = plan(&mut comm, &spec, 3);
            let mut got: Vec<usize> = bp.edges.iter().map(|e| e.dst).collect();
            got.push(root);
            got.sort_unstable();
            got.dedup();
            assert_eq!(got, (0..9).collect::<Vec<_>>());
        }
    }

    #[test]
    fn higher_k_shallower_but_wider() {
        // n=16: k=2 -> 4 rounds; k=4 -> 2 rounds of up to 3 serialized
        // sends each; both must complete correctly
        let c = flat(16).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = BcastSpec::new(0, 16, 4096);
        let t2 = engine
            .execute(&plan(&mut comm, &spec, 2).plan)
            .makespan;
        let t4 = engine
            .execute(&plan(&mut comm, &spec, 4).plan)
            .makespan;
        assert!(t2 > 0 && t4 > 0);
        // k=2 critical path: 4 hops; k=4: root does 3 serial sends, child
        // does up to 3 -> 6 hops worst-case: k=2 wins on latency here
        assert!(t2 <= t4);
    }

    #[test]
    fn two_ranks_single_send() {
        let c = flat(2).unwrap();
        let mut comm = Comm::new(&c);
        let spec = BcastSpec::new(0, 2, 64);
        let bp = plan(&mut comm, &spec, 2);
        assert_eq!(bp.plan.len(), 1);
    }
}
