//! Ring allgather: rank `r` contributes segment `r`; at step `t` every
//! rank forwards the segment it received at step `t−1` to its right
//! neighbour. After `n−1` steps every rank holds the full concatenation,
//! having moved `(n−1)/n × M` bytes per rank — the same ring the
//! large-message broadcast of Eq. 4 uses for its second phase, exposed
//! here as a standalone collective.
//!
//! `T = (n−1) × (t_s + M/(nB))`

use crate::comm::{chunk::equal_parts, Comm};
use crate::netsim::{ByteRole, Deps, OpId};

use super::template::{CollectiveTemplate, RoleRecorder};
use super::traits::{CollectiveKind, CollectivePlan, CollectiveSpec, FlowEdge};

pub fn plan(comm: &mut Comm, spec: &CollectiveSpec) -> CollectivePlan {
    template(comm, spec).cp
}

pub fn template(comm: &mut Comm, spec: &CollectiveSpec) -> CollectiveTemplate {
    debug_assert_eq!(spec.kind, CollectiveKind::Allgather);
    let n = spec.n_ranks;
    let mut plan = crate::netsim::Plan::new();
    let mut rec = RoleRecorder::new();
    let mut edges = Vec::new();
    if n == 1 {
        return CollectiveTemplate {
            roles: rec.finish(&plan),
            cp: CollectivePlan {
                plan,
                edges,
                n_chunks: 1,
                spec: spec.clone(),
                algorithm: "ring-allgather".into(),
            },
        };
    }
    let parts = equal_parts(spec.bytes, n);
    // own[v][c] = op after which rank v holds segment c (None = its own
    // contribution, c == v)
    let mut own: Vec<Vec<Option<OpId>>> = vec![vec![None; n]; n];
    for t in 0..n - 1 {
        let mut arrivals: Vec<(usize, usize, OpId)> = Vec::new();
        for v in 0..n {
            let c = (v + n - t) % n;
            let dst = (v + 1) % n;
            debug_assert!(own[v][c].is_some() || c == v, "rank {v} missing segment {c}");
            let deps = Deps::from_opt(own[v][c]);
            let mark = plan.len();
            let op = comm.send(&mut plan, v, dst, parts[c], deps, Some((dst, c)));
            rec.tag(
                &plan,
                mark,
                ByteRole::Part {
                    index: c as u32,
                    of: n as u32,
                },
                comm.size_class_of(parts[c]),
            );
            edges.push(FlowEdge::copy(v, dst, c, op));
            arrivals.push((dst, c, op));
        }
        for (dst, c, op) in arrivals {
            own[dst][c] = Some(op);
        }
    }
    CollectiveTemplate {
        roles: rec.finish(&plan),
        cp: CollectivePlan {
            plan,
            edges,
            n_chunks: n,
            spec: spec.clone(),
            algorithm: "ring-allgather".into(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::validate::validate;
    use crate::netsim::Engine;
    use crate::topology::presets::flat;

    #[test]
    fn every_rank_gathers_every_segment() {
        let c = flat(6).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let spec = CollectiveSpec::allgather(6, 6000);
        let cp = plan(&mut comm, &spec);
        let result = engine.execute(&cp.plan);
        validate(&cp, &result).unwrap();
        for r in 0..6 {
            for c in 0..6 {
                if c == r {
                    continue; // own segment: held from the start
                }
                assert!(
                    result.delivery_time(&cp.plan, r, c).is_some(),
                    "rank {r} missing segment {c}"
                );
            }
        }
    }

    #[test]
    fn traffic_is_n_minus_one_over_n() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let m: u64 = 8 << 20;
        let spec = CollectiveSpec::allgather(8, m);
        let cp = plan(&mut comm, &spec);
        assert_eq!(cp.plan.total_bytes(), (8 - 1) * m);
    }

    #[test]
    fn single_rank_noop() {
        let c = flat(1).unwrap();
        let mut comm = Comm::new(&c);
        let spec = CollectiveSpec::allgather(1, 100);
        let cp = plan(&mut comm, &spec);
        assert!(cp.plan.is_empty());
    }

    #[test]
    fn cost_matches_ring_model_on_flat() {
        let c = flat(8).unwrap();
        let mut comm = Comm::new(&c);
        let mut engine = Engine::new(&c);
        let m: u64 = 8 << 20;
        let hop = comm.estimate_ns(0, 1, m / 8);
        let spec = CollectiveSpec::allgather(8, m);
        let cp = plan(&mut comm, &spec);
        let r = engine.execute(&cp.plan);
        assert_eq!(r.makespan, 7 * hop);
    }
}
