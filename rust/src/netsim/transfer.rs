//! Plan representation: ops, dependencies, labels — and plan *templates*.
//!
//! Hot-path design (DESIGN.md §Perf, §SoA plan layout): a [`Plan`] stores
//! its ops as parallel *columns* (struct-of-arrays) — kind/target
//! ([`OpEnd`]), payload bytes, overheads, issue costs, bandwidth caps,
//! dependencies and labels each live in their own `Vec`. The execute loop
//! streams exactly the columns it needs (`bytes`/`ends`/`overheads`/
//! `deps`) instead of striding over fat per-op structs, and
//! [`rescale`]-ing a template rewrites the `bytes` column alone. The
//! [`PlannedOp`] row view survives as an *accessor* ([`Plan::planned`])
//! for consumers that want the old shape; [`SimOp`] remains the builder-
//! facing currency ([`Plan::push`] decomposes it into the columns,
//! [`Plan::op`] reconstructs it).
//!
//! A `Transfer` carries an interned [`RouteId`] — not an owned hop list —
//! and an op's dependencies live in an inline [`Deps`] buffer (≤2
//! predecessors, which covers every collective builder's common case)
//! that only spills to the heap for wide joins. Building a plan therefore
//! performs no per-op allocations beyond the column vectors themselves.
//!
//! Plan templates (DESIGN.md §Plan templates): every message size at a
//! fixed (algorithm, chunk count, topology) shares the same DAG shape and
//! routes, differing only in per-op byte counts. A [`ByteRole`] names how
//! an op's payload derives from the total message size (whole message /
//! equal-part index / chunk slot / …); [`rescale`] re-instantiates a
//! previously built plan for a new total by rewriting only the byte
//! column — deps, labels, routes, overheads and the memoized deliveries
//! map are untouched.

use crate::topology::{DeviceId, RouteId};

use super::time::SimTime;

/// Index of an op within a [`Plan`].
pub type OpId = usize;

/// One schedulable unit.
#[derive(Debug, Clone)]
pub enum SimOp {
    /// Move `bytes` from the route's src to its dst, cut-through,
    /// occupying every link on the path. `overhead_ns` is the protocol
    /// startup cost (the t_s of the paper's models) and contributes to the
    /// completion time; `issue_ns` is the portion of that startup which
    /// *occupies the channel* — back-to-back transfers on one link are
    /// spaced by `issue_ns + transmission`. MPI sends use
    /// `issue == overhead` (Eq. 5 semantics); posted GDR writes issue much
    /// faster than their end-to-end latency. `bw_cap` optionally caps the
    /// effective bandwidth below the links' own (e.g. the GDR-read
    /// ceiling). The route is an interned id resolved through the
    /// cluster's route table at execution time — topology mutation
    /// (`add_device`/`connect`) invalidates the table, so plans must not
    /// outlive changes to the cluster they were built against.
    Transfer {
        route: RouteId,
        bytes: u64,
        overhead_ns: SimTime,
        issue_ns: SimTime,
        bw_cap: Option<f64>,
    },
    /// Occupy a device for a fixed duration (kernel launch, compute).
    Delay { dev: DeviceId, dur_ns: SimTime },
}

impl SimOp {
    pub fn bytes(&self) -> u64 {
        match self {
            SimOp::Transfer { bytes, .. } => *bytes,
            SimOp::Delay { .. } => 0,
        }
    }
}

/// The kind/target column entry of the SoA [`Plan`]: what an op *is*
/// (transfer along a route, or a device-local delay). The remaining
/// per-op parameters live in the sibling columns — for a `Route` entry
/// the plan's `bytes`/`overheads`/`issues`/`bw_caps` columns hold the
/// transfer parameters; for a `Dev` entry the `overheads` column holds
/// the delay duration (the other columns carry neutral values).
#[derive(Debug, Clone, Copy)]
pub enum OpEnd {
    /// A cut-through transfer along an interned route.
    Route(RouteId),
    /// A fixed-duration occupancy of a device.
    Dev(DeviceId),
}

/// An op's dependency list: up to two predecessor ids inline (the
/// overwhelmingly common case for collective plans — "previous hop" and
/// "data availability"), spilling to a heap `Vec` only for wider joins
/// (e.g. a k-nomial reduce head waiting on all of its children).
#[derive(Debug, Clone)]
pub enum Deps {
    Inline { buf: [OpId; 2], len: u8 },
    Spill(Vec<OpId>),
}

impl Deps {
    /// No dependencies.
    pub const fn none() -> Deps {
        Deps::Inline { buf: [0; 2], len: 0 }
    }

    /// A single dependency.
    pub fn one(a: OpId) -> Deps {
        Deps::Inline { buf: [a, 0], len: 1 }
    }

    /// Two dependencies.
    pub fn two(a: OpId, b: OpId) -> Deps {
        Deps::Inline { buf: [a, b], len: 2 }
    }

    /// `none()` or `one(..)` from an optional predecessor — the shape
    /// every chain/ring builder produces.
    pub fn from_opt(op: Option<OpId>) -> Deps {
        match op {
            Some(a) => Deps::one(a),
            None => Deps::none(),
        }
    }

    /// Inline when the slice fits, spilled otherwise.
    pub fn from_slice(ids: &[OpId]) -> Deps {
        match ids {
            [] => Deps::none(),
            &[a] => Deps::one(a),
            &[a, b] => Deps::two(a, b),
            _ => Deps::Spill(ids.to_vec()),
        }
    }

    /// Append a dependency, spilling if the inline buffer is full.
    pub fn push(&mut self, id: OpId) {
        match self {
            Deps::Inline { buf, len } => {
                if (*len as usize) < buf.len() {
                    buf[*len as usize] = id;
                    *len += 1;
                } else {
                    let mut v = buf.to_vec();
                    v.push(id);
                    *self = Deps::Spill(v);
                }
            }
            Deps::Spill(v) => v.push(id),
        }
    }

    pub fn as_slice(&self) -> &[OpId] {
        match self {
            Deps::Inline { buf, len } => &buf[..*len as usize],
            Deps::Spill(v) => v,
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [OpId] {
        match self {
            Deps::Inline { buf, len } => &mut buf[..*len as usize],
            Deps::Spill(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for Deps {
    fn default() -> Deps {
        Deps::none()
    }
}

impl From<Vec<OpId>> for Deps {
    fn from(v: Vec<OpId>) -> Deps {
        if v.len() > 2 {
            Deps::Spill(v)
        } else {
            Deps::from_slice(&v)
        }
    }
}

impl From<Option<OpId>> for Deps {
    fn from(op: Option<OpId>) -> Deps {
        Deps::from_opt(op)
    }
}

/// A reconstructed *row view* of the SoA [`Plan`]: an op plus its
/// dependencies and an optional (rank, chunk) label used by collectives
/// to map completions back to "rank r received chunk c". Not the storage
/// layout — gather one via [`Plan::planned`]; hot paths should stream the
/// plan's columns instead.
#[derive(Debug, Clone)]
pub struct PlannedOp {
    pub op: SimOp,
    pub deps: Deps,
    /// (destination rank, chunk index) for delivery-tracking transfers.
    pub label: Option<(usize, usize)>,
}

/// Stride between label namespaces: every plan merged into another via
/// [`Plan::merge`]/[`Plan::merge_after`] has its labels' chunk indices
/// offset by `namespace * LABEL_NS_STRIDE`, so deliveries from different
/// merged sub-plans stay distinguishable instead of colliding (or, as
/// before the fix, being dropped). Leaf plans built by the collective
/// builders keep chunk indices far below the stride (debug-asserted on
/// merge).
pub const LABEL_NS_STRIDE: usize = 1 << 32;

/// The chunk key under which merge namespace `ns` holds chunk `chunk` —
/// pair with [`Plan::deliveries`] / `ExecResult::delivery_time` to query
/// a merged sub-plan's deliveries through a [`MergeHandle`].
pub fn ns_chunk(ns: usize, chunk: usize) -> usize {
    debug_assert!(chunk < LABEL_NS_STRIDE, "chunk index overflows its namespace");
    ns * LABEL_NS_STRIDE + chunk
}

/// Where a plan merged via [`Plan::merge`]/[`Plan::merge_after`] landed:
/// its ops occupy `offset..offset + len` of the destination, and its
/// labels moved to chunk namespace `namespace` (see [`ns_chunk`]) — a
/// leaf plan's labels land exactly there; a plan that was itself built
/// by merging occupies the range `namespace ..= namespace + its own
/// merge count`, keeping nested namespaces distinct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MergeHandle {
    pub offset: OpId,
    pub len: usize,
    pub namespace: usize,
}

/// Per-flow bandwidth caps are stored as plain `f64` in the cap column;
/// `f64::INFINITY` means uncapped (`bw_cap: None`).
fn cap_to_col(cap: Option<f64>) -> f64 {
    cap.unwrap_or(f64::INFINITY)
}

fn cap_from_col(cap: f64) -> Option<f64> {
    if cap.is_finite() {
        Some(cap)
    } else {
        None
    }
}

/// A dependency DAG of ops, stored as parallel columns (SoA — see the
/// module docs and DESIGN.md §SoA plan layout).
///
/// Column ownership: builders append through [`Plan::push`] /
/// [`Plan::merge`]; [`rescale`] rewrites the `bytes` column only;
/// [`Plan::add_dep`] and [`Plan::set_label`] touch the `deps` and
/// `labels` columns respectively; the engine reads every column but
/// writes none. The columns are crate-visible so the engine, validators
/// and tests can stream (and tests mutate) them directly; external
/// consumers go through the row accessors ([`Plan::op`],
/// [`Plan::planned`], [`Plan::label_of`], [`Plan::deps_of`]). Direct
/// label mutation bypasses the deliveries-cache invalidation — use
/// [`Plan::set_label`].
#[derive(Debug, Clone, Default)]
pub struct Plan {
    /// Kind/target column: route for transfers, device for delays.
    pub(crate) ends: Vec<OpEnd>,
    /// Payload bytes (0 for delays) — the only column [`rescale`] writes.
    pub(crate) bytes: Vec<u64>,
    /// Transfer `overhead_ns`, or a delay's `dur_ns`.
    pub(crate) overheads: Vec<SimTime>,
    /// Transfer `issue_ns` (0 for delays).
    pub(crate) issues: Vec<SimTime>,
    /// Per-flow bandwidth cap; `f64::INFINITY` = uncapped.
    pub(crate) bw_caps: Vec<f64>,
    /// Dependency lists (inline ≤2, spilled beyond).
    pub(crate) deps: Vec<Deps>,
    /// Optional (rank, chunk) delivery labels.
    pub(crate) labels: Vec<Option<(usize, usize)>>,
    /// Number of plans merged in so far; merge `k` (1-based) namespaces
    /// its labels at chunk offset `k * LABEL_NS_STRIDE` (directly pushed
    /// labels live in namespace 0).
    merge_seq: usize,
    /// Labelled deliveries `(rank, chunk) -> op id`, built lazily on the
    /// first [`Plan::deliveries`] call (later ops overwrite earlier ones
    /// with the same label: delivery = last write) and invalidated by
    /// labelled pushes / [`Plan::set_label`] / labelled merges. Lazy so
    /// the plan-build hot path performs no per-op hashing. Mutating
    /// the `labels` column directly bypasses the invalidation — use
    /// `set_label`.
    deliveries: std::cell::OnceCell<std::collections::HashMap<(usize, usize), OpId>>,
}

impl Plan {
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Append an op; returns its id. Decomposes the [`SimOp`] into the
    /// plan's columns.
    pub fn push(
        &mut self,
        op: SimOp,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let deps = deps.into();
        debug_assert!(
            deps.as_slice().iter().all(|&d| d < self.ends.len()),
            "dep on future op"
        );
        let id = self.ends.len();
        if label.is_some() {
            // a labelled push after a deliveries() query invalidates the
            // cached map; a no-op (None) before the first query
            let _ = self.deliveries.take();
        }
        let (end, bytes, overhead, issue, cap) = match op {
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns,
                issue_ns,
                bw_cap,
            } => (OpEnd::Route(route), bytes, overhead_ns, issue_ns, cap_to_col(bw_cap)),
            SimOp::Delay { dev, dur_ns } => (OpEnd::Dev(dev), 0, dur_ns, 0, f64::INFINITY),
        };
        self.ends.push(end);
        self.bytes.push(bytes);
        self.overheads.push(overhead);
        self.issues.push(issue);
        self.bw_caps.push(cap);
        self.deps.push(deps);
        self.labels.push(label);
        id
    }

    pub fn len(&self) -> usize {
        self.ends.len()
    }

    /// Reconstruct op `id` from the columns. A `bw_cap` of
    /// `Some(f64::INFINITY)` pushed in round-trips as `None` — the two
    /// spell the same "uncapped" semantics.
    pub fn op(&self, id: OpId) -> SimOp {
        match self.ends[id] {
            OpEnd::Route(route) => SimOp::Transfer {
                route,
                bytes: self.bytes[id],
                overhead_ns: self.overheads[id],
                issue_ns: self.issues[id],
                bw_cap: cap_from_col(self.bw_caps[id]),
            },
            OpEnd::Dev(dev) => SimOp::Delay {
                dev,
                dur_ns: self.overheads[id],
            },
        }
    }

    /// Reconstruct the full row view of op `id` (op + deps + label).
    /// Clones the dependency list — diagnostics and tests, not hot paths.
    pub fn planned(&self, id: OpId) -> PlannedOp {
        PlannedOp {
            op: self.op(id),
            deps: self.deps[id].clone(),
            label: self.labels[id],
        }
    }

    /// Op `id`'s dependency list, borrowed from the deps column.
    pub fn deps_of(&self, id: OpId) -> &Deps {
        &self.deps[id]
    }

    /// Op `id`'s delivery label.
    pub fn label_of(&self, id: OpId) -> Option<(usize, usize)> {
        self.labels[id]
    }

    /// Re-label an op, invalidating the cached deliveries map. Use this
    /// instead of assigning the `labels` column directly (tests sabotage
    /// plans this way).
    pub fn set_label(&mut self, id: OpId, label: Option<(usize, usize)>) {
        let _ = self.deliveries.take();
        self.labels[id] = label;
    }

    /// Append another plan's ops (shifting its internal dependencies) so
    /// independent collectives can execute concurrently on the shared
    /// fabric — contention on common links resolves in the engine.
    /// Merged-in labels are kept, with their chunk indices moved into a
    /// fresh namespace (`handle.namespace`, see [`ns_chunk`]) so
    /// deliveries from different merged sub-plans stay distinguishable
    /// and `ExecResult::{delivery_time, rank_completion}` keep working on
    /// merged schedules.
    pub fn merge(&mut self, other: &Plan) -> MergeHandle {
        self.merge_after(other, &[])
    }

    /// [`Plan::merge`] with cross-plan dependency stitching: every op of
    /// `other` that has no in-plan dependencies additionally depends on
    /// `external` (op ids in `self`, which must all precede the merge).
    /// This is how the overlap timeline gates a merged collective on
    /// compute ops or on another merged plan's completions. Only the
    /// `deps` and `labels` columns are transformed; the parameter
    /// columns append verbatim.
    pub fn merge_after(&mut self, other: &Plan, external: &[OpId]) -> MergeHandle {
        let offset = self.ends.len();
        debug_assert!(
            external.iter().all(|&d| d < offset),
            "external dep on an op at or past the merge point"
        );
        // allocate a namespace *range*, not a single slot, so merging an
        // already-merged plan keeps its internal namespaces distinct
        // (closed under composition): `other`'s namespace k lands at
        // `namespace + k`, and the next merge starts past all of them
        let namespace = self.merge_seq + 1;
        self.merge_seq += other.merge_seq + 1;
        let mut merged_label = false;
        self.ends.extend_from_slice(&other.ends);
        self.bytes.extend_from_slice(&other.bytes);
        self.overheads.extend_from_slice(&other.overheads);
        self.issues.extend_from_slice(&other.issues);
        self.bw_caps.extend_from_slice(&other.bw_caps);
        for &label in &other.labels {
            let shifted = match label {
                Some((rank, chunk)) => {
                    debug_assert!(
                        chunk < (other.merge_seq + 1) * LABEL_NS_STRIDE,
                        "chunk index overflows the merged plan's namespace range"
                    );
                    merged_label = true;
                    Some((rank, chunk + namespace * LABEL_NS_STRIDE))
                }
                None => None,
            };
            self.labels.push(shifted);
        }
        for deps in &other.deps {
            let mut shifted = deps.clone();
            if shifted.is_empty() {
                shifted = Deps::from_slice(external);
            } else {
                for d in shifted.as_mut_slice() {
                    *d += offset;
                }
            }
            self.deps.push(shifted);
        }
        if merged_label {
            // a labelled merge after a deliveries() query must not serve
            // the stale pre-merge map
            let _ = self.deliveries.take();
        }
        MergeHandle {
            offset,
            len: other.len(),
            namespace,
        }
    }

    /// Append a dependency to an existing op (cross-plan stitching:
    /// gating a merged sub-plan's entry ops on ops pushed earlier).
    /// Dependencies don't affect labels, so the deliveries cache stays
    /// valid. The caller is responsible for not closing a cycle — the
    /// engine fails fast on cyclic plans.
    pub fn add_dep(&mut self, op: OpId, dep: OpId) {
        debug_assert!(op < self.len() && dep < self.len(), "op id out of range");
        debug_assert_ne!(op, dep, "op depending on itself");
        self.deps[op].push(dep);
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    /// Total payload bytes moved by the plan (sum over transfers; delay
    /// rows hold zero in the byte column).
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Lengths of all seven SoA columns, in declaration order
    /// (`ends`/`bytes`/`overheads`/`issues`/`bw_caps`/`deps`/`labels`).
    /// [`Plan::push`]/[`Plan::merge`] keep them equal by construction; the
    /// static verifier re-proves it so column-level sabotage (tests) and
    /// future partial-append bugs surface as a diagnostic, not an index
    /// panic deep in the engine.
    pub(crate) fn column_lens(&self) -> [usize; 7] {
        [
            self.ends.len(),
            self.bytes.len(),
            self.overheads.len(),
            self.issues.len(),
            self.bw_caps.len(),
            self.deps.len(),
            self.labels.len(),
        ]
    }

    /// `flags[i]` ⇔ some other op depends on op `i`. One pass over the
    /// deps column; shared by exit-op discovery
    /// (`CollectivePlan::rank_exit_ops`) and the verifier's terminal-op
    /// lint.
    pub fn dependent_flags(&self) -> Vec<bool> {
        let mut flags = vec![false; self.len()];
        for deps in &self.deps {
            for &d in deps.as_slice() {
                if d < flags.len() {
                    flags[d] = true;
                }
            }
        }
        flags
    }

    /// All labelled deliveries `(rank, chunk) -> op id`. Later ops
    /// overwrite earlier ones with the same label (delivery = last
    /// write). Built once on first use and cached; repeated queries
    /// (`delivery_time` loops, validators) borrow the same map.
    pub fn deliveries(&self) -> &std::collections::HashMap<(usize, usize), OpId> {
        self.deliveries.get_or_init(|| {
            let mut map = std::collections::HashMap::new();
            for (id, label) in self.labels.iter().enumerate() {
                if let Some(label) = *label {
                    map.insert(label, id);
                }
            }
            map
        })
    }
}

/// `chunk_sizes(total, chunk)[index]` without building the vector.
fn chunk_slot_bytes(total: u64, chunk: u64, index: u32) -> u64 {
    if total == 0 {
        return 0;
    }
    if chunk == 0 || chunk >= total {
        debug_assert_eq!(index, 0, "single-slot plan rescaled out of range");
        return total;
    }
    let full = total / chunk;
    if (index as u64) < full {
        chunk
    } else {
        total % chunk
    }
}

/// Sum of `equal_parts(total, of)[..upto]` without building the vector.
/// `of == 0` names a zero-part split ([`crate::comm::chunk::equal_parts`]
/// returns no parts), so every prefix is empty: 0, not a div-by-zero.
fn part_prefix_bytes(total: u64, of: u32, upto: u32) -> u64 {
    if of == 0 {
        return 0;
    }
    let of = of as u64;
    let upto = upto as u64;
    let base = total / of;
    let extra = total % of; // the first `extra` parts carry one extra byte
    base * upto + upto.min(extra)
}

/// Symbolic byte count of a templated op: how to recompute the op's
/// payload for a new total message size without rebuilding the plan.
/// Each variant mirrors one byte-partitioning scheme the collective
/// builders use (`comm::chunk::{chunk_sizes, equal_parts}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteRole {
    /// Bytes independent of the message size (delays, fixed control).
    Fixed(u64),
    /// The whole message.
    Whole,
    /// `equal_parts(total, of)[index]` — a ring segment / scatter part.
    Part { index: u32, of: u32 },
    /// Sum of `equal_parts(total, of)[from..to]` — a scatter subtree's
    /// custody payload.
    PartRange { from: u32, to: u32, of: u32 },
    /// `chunk_sizes(total, chunk)[index]` — a pipelined-chain chunk or an
    /// NCCL ring slice.
    ChunkSlot { index: u32, chunk: u64 },
    /// Slice `index` (granularity `slice`) of chunk `outer` (granularity
    /// `chunk`) of the total — the hierarchical NCCL pipeline's nesting.
    SliceOfChunk {
        outer: u32,
        chunk: u64,
        index: u32,
        slice: u64,
    },
}

impl ByteRole {
    /// The concrete byte count this role takes at a given total message
    /// size. Pure arithmetic — no allocation.
    pub fn bytes(&self, total: u64) -> u64 {
        match *self {
            ByteRole::Fixed(b) => b,
            ByteRole::Whole => total,
            ByteRole::Part { index, of } => {
                if of == 0 {
                    // a zero-part split has no parts to take bytes from
                    return 0;
                }
                let base = total / of as u64;
                let extra = total % of as u64;
                base + u64::from((index as u64) < extra)
            }
            ByteRole::PartRange { from, to, of } => {
                part_prefix_bytes(total, of, to) - part_prefix_bytes(total, of, from)
            }
            ByteRole::ChunkSlot { index, chunk } => chunk_slot_bytes(total, chunk, index),
            ByteRole::SliceOfChunk {
                outer,
                chunk,
                index,
                slice,
            } => chunk_slot_bytes(chunk_slot_bytes(total, chunk, outer), slice, index),
        }
    }
}

/// Size-class sentinel for ops whose structure and parameters never
/// consulted a mechanism size class (raw transfers with fixed overheads,
/// NCCL ring hops, delays) — rescaling them can never require a rebuild.
pub const NO_CLASS: u8 = u8::MAX;

/// Per-op template metadata: the byte role plus the mechanism size class
/// the op's payload had when the template was built ([`NO_CLASS`] when
/// irrelevant). Equal class ⇒ identical mechanism selection ⇒ identical
/// structure, because `comm::Comm` resolves path plans at a canonical
/// per-class byte size.
#[derive(Debug, Clone, Copy)]
pub struct OpByte {
    pub role: ByteRole,
    pub class: u8,
}

/// Rescale a templated plan in place to a new total message size: every
/// transfer op's byte count is recomputed from its [`ByteRole`] and
/// written into the plan's byte *column* — the only column a rescale may
/// touch; deps, labels, routes, overheads and the memoized deliveries
/// map are left untouched. Returns `false` — leaving the plan partially
/// rescaled, so the caller must discard and rebuild — when some op's new
/// byte count falls in a different mechanism size class (`classify`)
/// than the one recorded at build time: crossing a class boundary can
/// change mechanism selection and therefore plan *structure*, which a
/// rescale cannot express.
pub fn rescale(
    plan: &mut Plan,
    roles: &[OpByte],
    total: u64,
    classify: impl Fn(u64) -> u8,
) -> bool {
    debug_assert_eq!(plan.len(), roles.len(), "byte roles out of sync with ops");
    for (i, meta) in roles.iter().enumerate() {
        if let OpEnd::Route(_) = plan.ends[i] {
            let nb = meta.role.bytes(total);
            if meta.class != NO_CLASS && classify(nb) != meta.class {
                return false;
            }
            plan.bytes[i] = nb;
        }
    }
    true
}

/// A plan plus the per-op byte roles needed to [`rescale`] it: built once
/// per (algorithm, chunk count, topology), re-instantiated per message
/// size. The collectives layer wraps this with flow edges and caching
/// (`collectives::template`).
#[derive(Debug, Clone, Default)]
pub struct PlanTemplate {
    pub plan: Plan,
    pub roles: Vec<OpByte>,
}

impl PlanTemplate {
    /// Rescale the held plan in place; see [`rescale`].
    pub fn rescale(&mut self, total: u64, classify: impl Fn(u64) -> u8) -> bool {
        rescale(&mut self.plan, &self.roles, total, classify)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::flat;
    use crate::topology::DeviceId;

    #[test]
    fn plan_builds_and_counts() {
        let c = flat(2).unwrap();
        let mut p = Plan::new();
        let a = p.push(
            SimOp::Delay {
                dev: DeviceId(0),
                dur_ns: 10,
            },
            vec![],
            None,
        );
        let r = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let b = p.push(
            SimOp::Transfer {
                route: r,
                bytes: 128,
                overhead_ns: 5,
                issue_ns: 5,
                bw_cap: None,
            },
            vec![a],
            Some((1, 0)),
        );
        assert_eq!(p.len(), 2);
        assert_eq!(p.total_bytes(), 128);
        assert_eq!(p.deliveries().get(&(1, 0)), Some(&b));
    }

    #[test]
    fn deps_inline_then_spill() {
        let mut d = Deps::none();
        assert!(d.is_empty());
        d.push(7);
        d.push(9);
        assert!(matches!(d, Deps::Inline { .. }));
        assert_eq!(d.as_slice(), &[7, 9]);
        d.push(11);
        assert!(matches!(d, Deps::Spill(_)));
        assert_eq!(d.as_slice(), &[7, 9, 11]);
        assert_eq!(Deps::from_slice(&[1, 2]).as_slice(), &[1, 2]);
        assert_eq!(Deps::from_opt(None).len(), 0);
        assert_eq!(Deps::from_opt(Some(3)).as_slice(), &[3]);
        let from_vec: Deps = vec![1, 2, 3, 4].into();
        assert_eq!(from_vec.as_slice(), &[1, 2, 3, 4]);
    }

    #[test]
    fn soa_round_trips_through_op_and_planned() {
        // the column decomposition must reconstruct exactly what was
        // pushed — for both op kinds, with and without a bandwidth cap
        let c = flat(2).unwrap();
        let mut p = Plan::new();
        let r = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        p.push(
            SimOp::Transfer {
                route: r,
                bytes: 4096,
                overhead_ns: 7,
                issue_ns: 3,
                bw_cap: Some(2.5e9),
            },
            vec![],
            Some((1, 0)),
        );
        p.push(
            SimOp::Delay {
                dev: DeviceId(1),
                dur_ns: 123,
            },
            vec![0],
            None,
        );
        match p.op(0) {
            SimOp::Transfer {
                bytes,
                overhead_ns,
                issue_ns,
                bw_cap,
                ..
            } => {
                assert_eq!((bytes, overhead_ns, issue_ns), (4096, 7, 3));
                assert_eq!(bw_cap, Some(2.5e9));
            }
            other => panic!("expected a transfer, got {other:?}"),
        }
        match p.op(1) {
            SimOp::Delay { dev, dur_ns } => {
                assert_eq!((dev, dur_ns), (DeviceId(1), 123));
            }
            other => panic!("expected a delay, got {other:?}"),
        }
        let row = p.planned(1);
        assert_eq!(row.deps.as_slice(), &[0]);
        assert_eq!(row.label, None);
        assert_eq!(p.label_of(0), Some((1, 0)));
        assert_eq!(p.deps_of(1).as_slice(), &[0]);
        // an uncapped transfer round-trips to bw_cap: None
        let mut q = Plan::new();
        q.push(
            SimOp::Transfer {
                route: r,
                bytes: 1,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            vec![],
            None,
        );
        assert!(matches!(q.op(0), SimOp::Transfer { bw_cap: None, .. }));
    }

    #[test]
    fn deliveries_track_last_write() {
        let mut p = Plan::new();
        let dev = DeviceId(0);
        p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        let second = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        assert_eq!(p.deliveries().get(&(1, 0)), Some(&second));
    }

    #[test]
    fn set_label_keeps_deliveries_in_sync() {
        let mut p = Plan::new();
        let dev = DeviceId(0);
        let a = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        p.set_label(a, None);
        assert!(p.deliveries().is_empty());
        p.set_label(a, Some((2, 3)));
        assert_eq!(p.deliveries().get(&(2, 3)), Some(&a));
        // an op whose label was overwritten by a later push must not
        // remove the newer delivery when it is itself unlabelled
        let first = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((5, 0)));
        let newer = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((5, 0)));
        p.set_label(first, None);
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&newer));
        // relabelling an *earlier* op to a label a later op holds must
        // not steal the delivery (delivery = last write)
        p.set_label(a, Some((5, 0)));
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&newer));
        // ...but a later op relabelled onto an earlier op's label wins,
        // and its old label falls back to the earlier holder
        p.set_label(newer, Some((2, 3)));
        assert_eq!(p.deliveries().get(&(2, 3)), Some(&newer));
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&a));
    }

    #[test]
    fn byte_roles_match_chunk_and_part_helpers() {
        use crate::comm::chunk::{chunk_sizes, equal_parts};
        for total in [0u64, 1, 7, 4096, (1 << 20) + 13, 9 << 20] {
            for chunk in [1u64 << 10, 256 << 10, 4 << 20] {
                let slots = chunk_sizes(total, chunk);
                for (i, &expect) in slots.iter().enumerate() {
                    let role = ByteRole::ChunkSlot {
                        index: i as u32,
                        chunk,
                    };
                    assert_eq!(role.bytes(total), expect, "total={total} chunk={chunk} i={i}");
                }
            }
            for of in [1usize, 3, 8] {
                let parts = equal_parts(total, of);
                for (i, &expect) in parts.iter().enumerate() {
                    let role = ByteRole::Part {
                        index: i as u32,
                        of: of as u32,
                    };
                    assert_eq!(role.bytes(total), expect, "total={total} of={of} i={i}");
                }
                for from in 0..of {
                    for to in from..=of {
                        let expect: u64 = parts[from..to].iter().sum();
                        let role = ByteRole::PartRange {
                            from: from as u32,
                            to: to as u32,
                            of: of as u32,
                        };
                        assert_eq!(role.bytes(total), expect);
                    }
                }
            }
        }
        // nesting: slice 1 of chunk 2 of 9M+5 at 4M chunks / 256K slices
        let total = (9u64 << 20) + 5;
        let outer = ByteRole::ChunkSlot { index: 2, chunk: 4 << 20 }.bytes(total);
        assert_eq!(outer, (1 << 20) + 5);
        let nested = ByteRole::SliceOfChunk {
            outer: 2,
            chunk: 4 << 20,
            index: 1,
            slice: 256 << 10,
        };
        assert_eq!(
            nested.bytes(total),
            crate::comm::chunk::chunk_sizes(outer, 256 << 10)[1]
        );
        assert_eq!(ByteRole::Whole.bytes(total), total);
        assert_eq!(ByteRole::Fixed(42).bytes(total), 42);
    }

    #[test]
    fn rescale_rewrites_bytes_and_respects_classes() {
        let c = flat(3).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r12 = c.route(c.rank_device(1), c.rank_device(2)).unwrap();
        let mut tpl = PlanTemplate::default();
        let built: u64 = 10 << 20;
        let a = tpl.plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: built,
                overhead_ns: 5,
                issue_ns: 5,
                bw_cap: None,
            },
            Deps::none(),
            Some((1, 0)),
        );
        tpl.plan.push(
            SimOp::Transfer {
                route: r12,
                bytes: built / 2,
                overhead_ns: 5,
                issue_ns: 5,
                bw_cap: None,
            },
            Deps::one(a),
            Some((2, 0)),
        );
        let threshold: u64 = 1 << 20;
        let classify = move |b: u64| u8::from(b > threshold);
        tpl.roles.push(OpByte {
            role: ByteRole::Whole,
            class: classify(built),
        });
        tpl.roles.push(OpByte {
            role: ByteRole::Part { index: 0, of: 2 },
            class: NO_CLASS,
        });
        // deliveries memoized before the rescale must survive it
        assert_eq!(tpl.plan.deliveries().len(), 2);
        assert!(tpl.rescale(8 << 20, classify));
        assert_eq!(tpl.plan.op(0).bytes(), 8 << 20);
        assert_eq!(tpl.plan.op(1).bytes(), 4 << 20);
        assert_eq!(tpl.plan.deliveries().len(), 2);
        assert_eq!(tpl.plan.deps[0].len(), 0);
        assert_eq!(tpl.plan.deps[1].as_slice(), &[0]);
        // dropping below the class boundary must refuse the rescale
        assert!(!tpl.rescale(4096, classify));
        // a NO_CLASS-only plan rescales across any boundary
        tpl.roles[0].class = NO_CLASS;
        assert!(tpl.rescale(4096, classify));
        assert_eq!(tpl.plan.op(0).bytes(), 4096);
        assert_eq!(tpl.plan.op(1).bytes(), 2048);
    }

    #[test]
    fn merge_namespaces_labels_and_shifts_deps() {
        let dev = DeviceId(0);
        let mut a = Plan::new();
        a.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((0, 0)));
        let mut b = Plan::new();
        let first = b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![first], Some((0, 0)));
        let h = a.merge(&b);
        assert_eq!((h.offset, h.len, h.namespace), (1, 2, 1));
        assert_eq!(a.len(), 3);
        assert_eq!(a.deps[2].as_slice(), &[1]);
        // the merged label survives, moved into namespace 1 — it must
        // not collide with a's own (0, 0) delivery
        assert_eq!(a.labels[2], Some((0, ns_chunk(1, 0))));
        assert_eq!(a.deliveries().get(&(0, 0)), Some(&0));
        assert_eq!(a.deliveries().get(&(0, ns_chunk(h.namespace, 0))), Some(&2));
        // a second merge of the same plan lands in namespace 2
        let h2 = a.merge(&b);
        assert_eq!((h2.offset, h2.namespace), (3, 2));
        assert_eq!(a.deliveries().get(&(0, ns_chunk(2, 0))), Some(&4));
    }

    #[test]
    fn merge_invalidates_memoized_deliveries() {
        // regression: merge used to leave the OnceCell warm, so a
        // labelled merge after a deliveries() query served a stale map
        let dev = DeviceId(0);
        let mut a = Plan::new();
        a.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        assert_eq!(a.deliveries().len(), 1); // warm the cache
        let mut b = Plan::new();
        b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((2, 0)));
        let h = a.merge(&b);
        assert_eq!(a.deliveries().len(), 2);
        assert_eq!(a.deliveries().get(&(2, ns_chunk(h.namespace, 0))), Some(&1));
        // an unlabelled merge needn't invalidate — and must not lose
        // what's there
        let mut c = Plan::new();
        c.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        a.merge(&c);
        assert_eq!(a.deliveries().len(), 2);
    }

    #[test]
    fn nested_merges_keep_namespaces_distinct() {
        // merging an already-merged plan must not fold its namespaces
        // onto a later merge's (release builds have no assert to catch
        // a collision — the allocation itself must be collision-free)
        let dev = DeviceId(0);
        let mut leaf1 = Plan::new();
        leaf1.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((0, 7)));
        let mut leaf2 = Plan::new();
        leaf2.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((0, 9)));
        let mut a = Plan::new();
        let _ = a.merge(&leaf1); // a's ns 1
        let _ = a.merge(&leaf2); // a's ns 2
        let mut c = Plan::new();
        let ha = c.merge(&a); // consumes ns 1..=3 (a's 0..=2 shifted)
        let hb = c.merge(&leaf2); // must land past all of a's namespaces
        assert_eq!(ha.namespace, 1);
        assert_eq!(hb.namespace, 4);
        // all three labels stay distinct deliveries
        assert_eq!(c.deliveries().len(), 3);
        assert_eq!(c.deliveries().get(&(0, ns_chunk(2, 7))), Some(&0));
        assert_eq!(c.deliveries().get(&(0, ns_chunk(3, 9))), Some(&1));
        assert_eq!(c.deliveries().get(&(0, ns_chunk(4, 9))), Some(&2));
    }

    #[test]
    fn merge_after_gates_entry_ops_on_externals() {
        let dev = DeviceId(0);
        let mut a = Plan::new();
        let g0 = a.push(SimOp::Delay { dev, dur_ns: 5 }, vec![], None);
        let g1 = a.push(SimOp::Delay { dev, dur_ns: 7 }, vec![], None);
        let mut b = Plan::new();
        let first = b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![first], None);
        let h = a.merge_after(&b, &[g0, g1]);
        // b's dep-less op now waits on both externals; its internal
        // dependency is shifted, not re-gated
        assert_eq!(a.deps[h.offset].as_slice(), &[g0, g1]);
        assert_eq!(a.deps[h.offset + 1].as_slice(), &[h.offset]);
    }

    #[test]
    fn add_dep_extends_existing_ops() {
        let dev = DeviceId(0);
        let mut p = Plan::new();
        let a = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        let b = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        p.add_dep(b, a);
        assert_eq!(p.deps[b].as_slice(), &[a]);
    }

    #[test]
    fn degenerate_byte_roles_are_guarded() {
        // of == 0 names a zero-part split: no parts, zero bytes, no
        // div-by-zero panic
        assert_eq!(ByteRole::Part { index: 0, of: 0 }.bytes(1 << 20), 0);
        assert_eq!(
            ByteRole::PartRange { from: 0, to: 0, of: 0 }.bytes(1 << 20),
            0
        );
        // chunk == 0 collapses to a single whole-message slot
        assert_eq!(ByteRole::ChunkSlot { index: 0, chunk: 0 }.bytes(4096), 4096);
    }
}
