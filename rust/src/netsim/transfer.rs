//! Plan representation: ops, dependencies, labels.

use crate::topology::{DeviceId, Route};

use super::time::SimTime;

/// Index of an op within a [`Plan`].
pub type OpId = usize;

/// One schedulable unit.
#[derive(Debug, Clone)]
pub enum SimOp {
    /// Move `bytes` from `route.src` to `route.dst`, cut-through,
    /// occupying every link on the path. `overhead_ns` is the protocol
    /// startup cost (the t_s of the paper's models) and contributes to the
    /// completion time; `issue_ns` is the portion of that startup which
    /// *occupies the channel* — back-to-back transfers on one link are
    /// spaced by `issue_ns + transmission`. MPI sends use
    /// `issue == overhead` (Eq. 5 semantics); posted GDR writes issue much
    /// faster than their end-to-end latency. `bw_cap` optionally caps the
    /// effective bandwidth below the links' own (e.g. the GDR-read
    /// ceiling).
    Transfer {
        route: Route,
        bytes: u64,
        overhead_ns: SimTime,
        issue_ns: SimTime,
        bw_cap: Option<f64>,
    },
    /// Occupy a device for a fixed duration (kernel launch, compute).
    Delay { dev: DeviceId, dur_ns: SimTime },
}

impl SimOp {
    pub fn bytes(&self) -> u64 {
        match self {
            SimOp::Transfer { bytes, .. } => *bytes,
            SimOp::Delay { .. } => 0,
        }
    }
}

/// An op plus its dependencies and an optional (rank, chunk) label used by
/// collectives to map completions back to "rank r received chunk c".
#[derive(Debug, Clone)]
pub struct PlannedOp {
    pub op: SimOp,
    pub deps: Vec<OpId>,
    /// (destination rank, chunk index) for delivery-tracking transfers.
    pub label: Option<(usize, usize)>,
}

/// A dependency DAG of ops.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub ops: Vec<PlannedOp>,
}

impl Plan {
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Append an op; returns its id.
    pub fn push(&mut self, op: SimOp, deps: Vec<OpId>, label: Option<(usize, usize)>) -> OpId {
        debug_assert!(deps.iter().all(|&d| d < self.ops.len()), "dep on future op");
        let id = self.ops.len();
        self.ops.push(PlannedOp { op, deps, label });
        id
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Append another plan's ops (shifting its internal dependencies) so
    /// independent collectives can execute concurrently on the shared
    /// fabric — contention on common links resolves in the engine. The
    /// merged-in labels are dropped (delivery bookkeeping stays with the
    /// original plans).
    pub fn merge(&mut self, other: &Plan) {
        let offset = self.ops.len();
        for op in &other.ops {
            let mut shifted = op.clone();
            shifted.label = None;
            for d in &mut shifted.deps {
                *d += offset;
            }
            self.ops.push(shifted);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total payload bytes moved by the plan (sum over transfers).
    pub fn total_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.op.bytes()).sum()
    }

    /// All labelled deliveries `(rank, chunk) -> op id`. Later ops
    /// overwrite earlier ones with the same label (delivery = last write).
    pub fn deliveries(&self) -> std::collections::HashMap<(usize, usize), OpId> {
        let mut map = std::collections::HashMap::new();
        for (id, op) in self.ops.iter().enumerate() {
            if let Some(label) = op.label {
                map.insert(label, id);
            }
        }
        map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::DeviceId;

    #[test]
    fn plan_builds_and_counts() {
        let mut p = Plan::new();
        let a = p.push(
            SimOp::Delay {
                dev: DeviceId(0),
                dur_ns: 10,
            },
            vec![],
            None,
        );
        let r = Route::trivial(DeviceId(0));
        let b = p.push(
            SimOp::Transfer {
                route: r,
                bytes: 128,
                overhead_ns: 5,
                issue_ns: 5,
                bw_cap: None,
            },
            vec![a],
            Some((1, 0)),
        );
        assert_eq!(p.len(), 2);
        assert_eq!(p.total_bytes(), 128);
        assert_eq!(p.deliveries().get(&(1, 0)), Some(&b));
    }
}
