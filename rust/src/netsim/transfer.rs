//! Plan representation: ops, dependencies, labels.
//!
//! Hot-path design (DESIGN.md §Perf): a [`SimOp::Transfer`] carries an
//! interned [`RouteId`] — not an owned hop list — and a [`PlannedOp`]'s
//! dependencies live in an inline [`Deps`] buffer (≤2 predecessors, which
//! covers every collective builder's common case) that only spills to the
//! heap for wide joins. Building a plan therefore performs no per-op
//! allocations beyond the `ops` vector itself.

use crate::topology::{DeviceId, RouteId};

use super::time::SimTime;

/// Index of an op within a [`Plan`].
pub type OpId = usize;

/// One schedulable unit.
#[derive(Debug, Clone)]
pub enum SimOp {
    /// Move `bytes` from the route's src to its dst, cut-through,
    /// occupying every link on the path. `overhead_ns` is the protocol
    /// startup cost (the t_s of the paper's models) and contributes to the
    /// completion time; `issue_ns` is the portion of that startup which
    /// *occupies the channel* — back-to-back transfers on one link are
    /// spaced by `issue_ns + transmission`. MPI sends use
    /// `issue == overhead` (Eq. 5 semantics); posted GDR writes issue much
    /// faster than their end-to-end latency. `bw_cap` optionally caps the
    /// effective bandwidth below the links' own (e.g. the GDR-read
    /// ceiling). The route is an interned id resolved through the
    /// cluster's route table at execution time — topology mutation
    /// (`add_device`/`connect`) invalidates the table, so plans must not
    /// outlive changes to the cluster they were built against.
    Transfer {
        route: RouteId,
        bytes: u64,
        overhead_ns: SimTime,
        issue_ns: SimTime,
        bw_cap: Option<f64>,
    },
    /// Occupy a device for a fixed duration (kernel launch, compute).
    Delay { dev: DeviceId, dur_ns: SimTime },
}

impl SimOp {
    pub fn bytes(&self) -> u64 {
        match self {
            SimOp::Transfer { bytes, .. } => *bytes,
            SimOp::Delay { .. } => 0,
        }
    }
}

/// An op's dependency list: up to two predecessor ids inline (the
/// overwhelmingly common case for collective plans — "previous hop" and
/// "data availability"), spilling to a heap `Vec` only for wider joins
/// (e.g. a k-nomial reduce head waiting on all of its children).
#[derive(Debug, Clone)]
pub enum Deps {
    Inline { buf: [OpId; 2], len: u8 },
    Spill(Vec<OpId>),
}

impl Deps {
    /// No dependencies.
    pub const fn none() -> Deps {
        Deps::Inline { buf: [0; 2], len: 0 }
    }

    /// A single dependency.
    pub fn one(a: OpId) -> Deps {
        Deps::Inline { buf: [a, 0], len: 1 }
    }

    /// Two dependencies.
    pub fn two(a: OpId, b: OpId) -> Deps {
        Deps::Inline { buf: [a, b], len: 2 }
    }

    /// `none()` or `one(..)` from an optional predecessor — the shape
    /// every chain/ring builder produces.
    pub fn from_opt(op: Option<OpId>) -> Deps {
        match op {
            Some(a) => Deps::one(a),
            None => Deps::none(),
        }
    }

    /// Inline when the slice fits, spilled otherwise.
    pub fn from_slice(ids: &[OpId]) -> Deps {
        match ids {
            [] => Deps::none(),
            &[a] => Deps::one(a),
            &[a, b] => Deps::two(a, b),
            _ => Deps::Spill(ids.to_vec()),
        }
    }

    /// Append a dependency, spilling if the inline buffer is full.
    pub fn push(&mut self, id: OpId) {
        match self {
            Deps::Inline { buf, len } => {
                if (*len as usize) < buf.len() {
                    buf[*len as usize] = id;
                    *len += 1;
                } else {
                    let mut v = buf.to_vec();
                    v.push(id);
                    *self = Deps::Spill(v);
                }
            }
            Deps::Spill(v) => v.push(id),
        }
    }

    pub fn as_slice(&self) -> &[OpId] {
        match self {
            Deps::Inline { buf, len } => &buf[..*len as usize],
            Deps::Spill(v) => v,
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [OpId] {
        match self {
            Deps::Inline { buf, len } => &mut buf[..*len as usize],
            Deps::Spill(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for Deps {
    fn default() -> Deps {
        Deps::none()
    }
}

impl From<Vec<OpId>> for Deps {
    fn from(v: Vec<OpId>) -> Deps {
        if v.len() > 2 {
            Deps::Spill(v)
        } else {
            Deps::from_slice(&v)
        }
    }
}

impl From<Option<OpId>> for Deps {
    fn from(op: Option<OpId>) -> Deps {
        Deps::from_opt(op)
    }
}

/// An op plus its dependencies and an optional (rank, chunk) label used by
/// collectives to map completions back to "rank r received chunk c".
#[derive(Debug, Clone)]
pub struct PlannedOp {
    pub op: SimOp,
    pub deps: Deps,
    /// (destination rank, chunk index) for delivery-tracking transfers.
    pub label: Option<(usize, usize)>,
}

/// A dependency DAG of ops.
#[derive(Debug, Clone, Default)]
pub struct Plan {
    /// Crate-visible so validators/tests can inspect (and tests mutate)
    /// ops directly; external consumers read via [`Plan::ops`]. Direct
    /// label mutation bypasses the deliveries-cache invalidation — use
    /// [`Plan::set_label`].
    pub(crate) ops: Vec<PlannedOp>,
    /// Labelled deliveries `(rank, chunk) -> op id`, built lazily on the
    /// first [`Plan::deliveries`] call (later ops overwrite earlier ones
    /// with the same label: delivery = last write) and invalidated by
    /// labelled pushes / [`Plan::set_label`]. Lazy so the plan-build hot
    /// path performs no per-op hashing. Mutating `ops[..].label`
    /// directly bypasses the invalidation — use `set_label`.
    deliveries: std::cell::OnceCell<std::collections::HashMap<(usize, usize), OpId>>,
}

impl Plan {
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Append an op; returns its id.
    pub fn push(
        &mut self,
        op: SimOp,
        deps: impl Into<Deps>,
        label: Option<(usize, usize)>,
    ) -> OpId {
        let deps = deps.into();
        debug_assert!(
            deps.as_slice().iter().all(|&d| d < self.ops.len()),
            "dep on future op"
        );
        let id = self.ops.len();
        if label.is_some() {
            // a labelled push after a deliveries() query invalidates the
            // cached map; a no-op (None) before the first query
            let _ = self.deliveries.take();
        }
        self.ops.push(PlannedOp { op, deps, label });
        id
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Read-only view of the op list.
    pub fn ops(&self) -> &[PlannedOp] {
        &self.ops
    }

    /// Re-label an op, invalidating the cached deliveries map. Use this
    /// instead of assigning `ops[id].label` directly (tests sabotage
    /// plans this way).
    pub fn set_label(&mut self, id: OpId, label: Option<(usize, usize)>) {
        let _ = self.deliveries.take();
        self.ops[id].label = label;
    }

    /// Append another plan's ops (shifting its internal dependencies) so
    /// independent collectives can execute concurrently on the shared
    /// fabric — contention on common links resolves in the engine. The
    /// merged-in labels are dropped (delivery bookkeeping stays with the
    /// original plans).
    pub fn merge(&mut self, other: &Plan) {
        let offset = self.ops.len();
        for op in &other.ops {
            let mut shifted = op.clone();
            shifted.label = None;
            for d in shifted.deps.as_mut_slice() {
                *d += offset;
            }
            self.ops.push(shifted);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total payload bytes moved by the plan (sum over transfers).
    pub fn total_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.op.bytes()).sum()
    }

    /// All labelled deliveries `(rank, chunk) -> op id`. Later ops
    /// overwrite earlier ones with the same label (delivery = last
    /// write). Built once on first use and cached; repeated queries
    /// (`delivery_time` loops, validators) borrow the same map.
    pub fn deliveries(&self) -> &std::collections::HashMap<(usize, usize), OpId> {
        self.deliveries.get_or_init(|| {
            let mut map = std::collections::HashMap::new();
            for (id, op) in self.ops.iter().enumerate() {
                if let Some(label) = op.label {
                    map.insert(label, id);
                }
            }
            map
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::flat;
    use crate::topology::DeviceId;

    #[test]
    fn plan_builds_and_counts() {
        let c = flat(2);
        let mut p = Plan::new();
        let a = p.push(
            SimOp::Delay {
                dev: DeviceId(0),
                dur_ns: 10,
            },
            vec![],
            None,
        );
        let r = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let b = p.push(
            SimOp::Transfer {
                route: r,
                bytes: 128,
                overhead_ns: 5,
                issue_ns: 5,
                bw_cap: None,
            },
            vec![a],
            Some((1, 0)),
        );
        assert_eq!(p.len(), 2);
        assert_eq!(p.total_bytes(), 128);
        assert_eq!(p.deliveries().get(&(1, 0)), Some(&b));
    }

    #[test]
    fn deps_inline_then_spill() {
        let mut d = Deps::none();
        assert!(d.is_empty());
        d.push(7);
        d.push(9);
        assert!(matches!(d, Deps::Inline { .. }));
        assert_eq!(d.as_slice(), &[7, 9]);
        d.push(11);
        assert!(matches!(d, Deps::Spill(_)));
        assert_eq!(d.as_slice(), &[7, 9, 11]);
        assert_eq!(Deps::from_slice(&[1, 2]).as_slice(), &[1, 2]);
        assert_eq!(Deps::from_opt(None).len(), 0);
        assert_eq!(Deps::from_opt(Some(3)).as_slice(), &[3]);
        let from_vec: Deps = vec![1, 2, 3, 4].into();
        assert_eq!(from_vec.as_slice(), &[1, 2, 3, 4]);
    }

    #[test]
    fn deliveries_track_last_write() {
        let mut p = Plan::new();
        let dev = DeviceId(0);
        p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        let second = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        assert_eq!(p.deliveries().get(&(1, 0)), Some(&second));
    }

    #[test]
    fn set_label_keeps_deliveries_in_sync() {
        let mut p = Plan::new();
        let dev = DeviceId(0);
        let a = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((1, 0)));
        p.set_label(a, None);
        assert!(p.deliveries().is_empty());
        p.set_label(a, Some((2, 3)));
        assert_eq!(p.deliveries().get(&(2, 3)), Some(&a));
        // an op whose label was overwritten by a later push must not
        // remove the newer delivery when it is itself unlabelled
        let first = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((5, 0)));
        let newer = p.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], Some((5, 0)));
        p.set_label(first, None);
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&newer));
        // relabelling an *earlier* op to a label a later op holds must
        // not steal the delivery (delivery = last write)
        p.set_label(a, Some((5, 0)));
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&newer));
        // ...but a later op relabelled onto an earlier op's label wins,
        // and its old label falls back to the earlier holder
        p.set_label(newer, Some((2, 3)));
        assert_eq!(p.deliveries().get(&(2, 3)), Some(&newer));
        assert_eq!(p.deliveries().get(&(5, 0)), Some(&a));
    }

    #[test]
    fn merge_drops_labels_and_shifts_deps() {
        let dev = DeviceId(0);
        let mut a = Plan::new();
        a.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        let mut b = Plan::new();
        let first = b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![], None);
        b.push(SimOp::Delay { dev, dur_ns: 1 }, vec![first], Some((0, 0)));
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.ops[2].deps.as_slice(), &[1]);
        assert!(a.ops[2].label.is_none());
        assert!(a.deliveries().is_empty());
    }
}
