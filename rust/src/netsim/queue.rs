//! The engine's ready queue: an indexed two-level bucket queue over
//! monotone ready times.
//!
//! The list scheduler pops ready ops in `(ready_time, op_id)` order, and
//! every push is at or after the last popped time (an op becomes ready
//! only when a parent *completes*, and completions never precede the
//! current virtual time). That monotonicity lets us replace the
//! `BinaryHeap`'s per-op `O(log n)` with amortised `O(1)`:
//!
//! * **level 1** — a window of [`BUCKETS`] time buckets of width
//!   `1 << shift` ns starting at `base`; pushes index straight into
//!   their bucket (unsorted), pushes beyond the window land in an
//!   overflow vector;
//! * **level 2** — the *active* bucket, sorted once on activation and
//!   drained through a cursor; same-bucket pushes (the common
//!   zero-latency successor case) insert in sorted position within the
//!   undrained tail;
//! * when the window drains, the queue **rebases** onto the overflow:
//!   the bucket width is recomputed from the remaining spread (so each
//!   item is redistributed at most once per rebase epoch) and items are
//!   re-indexed;
//! * **fallback** — a spread so wide that even `1 <<`[`FALLBACK_SHIFT`]
//!   ns buckets cannot cover it (pathological: hours of simulated time
//!   between events) degrades the queue to a single globally sorted
//!   drain, which is exactly the heap's complexity without its constant.
//!
//! Pop order is identical to `BinaryHeap<Reverse<(SimTime, OpId)>>`
//! (asserted by the reference test below), so the engine's determinism
//! and the golden parity suites are unaffected.

use super::time::SimTime;
use super::transfer::OpId;

/// Level-1 window size (buckets per rebase epoch).
const BUCKETS: usize = 256;
/// Widest bucket before the sorted-drain fallback kicks in: 2^40 ns
/// buckets cover ~80 days of simulated time per window.
const FALLBACK_SHIFT: u32 = 40;
/// Initial bucket width (2^12 ns = ~4 µs; window ≈ 1 ms) — dense
/// collective plans finish within a couple of windows, and the first
/// rebase adapts the width to the plan's real spread.
const INITIAL_SHIFT: u32 = 12;

/// Monotone `(time, id)` min-priority queue. See the module docs.
#[derive(Debug)]
pub struct ReadyQueue {
    buckets: Vec<Vec<(SimTime, OpId)>>,
    /// Start time of bucket 0 of the current window.
    base: SimTime,
    /// Bucket width is `1 << shift` ns.
    shift: u32,
    /// Active bucket index; buckets below it are drained and empty.
    active: usize,
    /// Drain cursor into the active bucket (sorted from here on).
    pos: usize,
    /// Items at or beyond the window end, pending redistribution.
    overflow: Vec<(SimTime, OpId)>,
    /// Cached minimum of `overflow` — kept incrementally (overflow is
    /// append-only between rebases and wholly drained by one), so
    /// [`ReadyQueue::peek`] stays O(1) on the overflow side instead of
    /// rescanning it per probe.
    overflow_min: Option<(SimTime, OpId)>,
    len: usize,
    /// Degraded mode storage: globally sorted, drained by cursor.
    sorted: Vec<(SimTime, OpId)>,
    sorted_pos: usize,
    fallback: bool,
    #[cfg(debug_assertions)]
    last_popped: SimTime,
}

impl Default for ReadyQueue {
    fn default() -> Self {
        ReadyQueue::new()
    }
}

impl ReadyQueue {
    pub fn new() -> ReadyQueue {
        ReadyQueue {
            buckets: (0..BUCKETS).map(|_| Vec::new()).collect(),
            base: 0,
            shift: INITIAL_SHIFT,
            active: 0,
            pos: 0,
            overflow: Vec::new(),
            overflow_min: None,
            len: 0,
            sorted: Vec::new(),
            sorted_pos: 0,
            fallback: false,
            #[cfg(debug_assertions)]
            last_popped: 0,
        }
    }

    /// Reset for a new plan, keeping every allocation.
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.base = 0;
        self.shift = INITIAL_SHIFT;
        self.active = 0;
        self.pos = 0;
        self.overflow.clear();
        self.overflow_min = None;
        self.len = 0;
        self.sorted.clear();
        self.sorted_pos = 0;
        self.fallback = false;
        #[cfg(debug_assertions)]
        {
            self.last_popped = 0;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue. `t` must be at or after the last popped time (the
    /// engine's monotonicity invariant; debug-asserted).
    pub fn push(&mut self, t: SimTime, id: OpId) {
        #[cfg(debug_assertions)]
        debug_assert!(
            t >= self.last_popped,
            "non-monotone push: {t} after popping {}",
            self.last_popped
        );
        self.len += 1;
        if self.fallback {
            let tail = &self.sorted[self.sorted_pos..];
            let at = self.sorted_pos + tail.partition_point(|&e| e < (t, id));
            self.sorted.insert(at, (t, id));
            return;
        }
        debug_assert!(t >= self.base, "push below the window base");
        let idx = ((t - self.base) >> self.shift) as usize;
        if idx >= BUCKETS {
            self.overflow.push((t, id));
            self.overflow_min = Some(match self.overflow_min {
                Some(m) => m.min((t, id)),
                None => (t, id),
            });
            return;
        }
        debug_assert!(idx >= self.active, "push into a drained bucket");
        if idx == self.active {
            // the active bucket is sorted from the drain cursor on;
            // keep it that way (binary search + short memmove)
            let v = &mut self.buckets[idx];
            let at = self.pos + v[self.pos..].partition_point(|&e| e < (t, id));
            v.insert(at, (t, id));
        } else {
            self.buckets[idx].push((t, id));
        }
    }

    /// Dequeue the minimum `(time, id)` pair.
    pub fn pop(&mut self) -> Option<(SimTime, OpId)> {
        if self.len == 0 {
            return None;
        }
        self.len -= 1;
        if self.fallback {
            let e = self.sorted[self.sorted_pos];
            self.sorted_pos += 1;
            #[cfg(debug_assertions)]
            {
                self.last_popped = e.0;
            }
            return Some(e);
        }
        loop {
            while self.active < BUCKETS {
                if self.pos < self.buckets[self.active].len() {
                    let e = self.buckets[self.active][self.pos];
                    self.pos += 1;
                    #[cfg(debug_assertions)]
                    {
                        self.last_popped = e.0;
                    }
                    return Some(e);
                }
                self.buckets[self.active].clear();
                self.pos = 0;
                self.active += 1;
                if self.active < BUCKETS {
                    self.buckets[self.active].sort_unstable();
                }
            }
            // window exhausted but items remain: rebase onto the overflow
            self.rebase();
            if self.fallback {
                let e = self.sorted[self.sorted_pos];
                self.sorted_pos += 1;
                #[cfg(debug_assertions)]
                {
                    self.last_popped = e.0;
                }
                return Some(e);
            }
        }
    }

    /// Dequeue the minimum pair *and* every other entry sharing its
    /// ready time, appending the op ids to `out` in ascending id order
    /// (`out` is cleared first); returns the batch's shared ready time.
    /// Exactly equivalent to a `pop` loop that stops when the front's
    /// time changes — the engine drains whole instants in one call and
    /// retires them in a single scratch pass instead of re-entering the
    /// event loop per op.
    pub fn pop_ready_batch(&mut self, out: &mut Vec<OpId>) -> Option<SimTime> {
        out.clear();
        let (t0, first) = self.pop()?;
        out.push(first);
        if self.fallback {
            let run = self.sorted[self.sorted_pos..]
                .iter()
                .take_while(|e| e.0 == t0)
                .count();
            out.extend(
                self.sorted[self.sorted_pos..self.sorted_pos + run]
                    .iter()
                    .map(|e| e.1),
            );
            self.sorted_pos += run;
            self.len -= run;
            return Some(t0);
        }
        // pop() finished the lazy maintenance: the served bucket is the
        // active one, sorted from the cursor on. Entries with equal
        // times always share a bucket (the index is a function of the
        // time under the current base/shift — and a rebase moves *all*
        // remaining items), so the rest of the batch is exactly the
        // leading equal-time run of the active bucket's tail.
        if self.active < BUCKETS {
            let v = &self.buckets[self.active];
            let run = v[self.pos..].iter().take_while(|e| e.0 == t0).count();
            out.extend(v[self.pos..self.pos + run].iter().map(|e| e.1));
            self.pos += run;
            self.len -= run;
        }
        Some(t0)
    }

    /// The minimum `(time, id)` pair without dequeuing it — the
    /// fair-share engine's next-arrival probe. Purely observational:
    /// unlike `pop` it performs none of the lazy maintenance (bucket
    /// clearing, activation sorts, rebase). That matters for
    /// correctness, not just cleanliness — only *popped* times bound
    /// later pushes, so after a peek the engine may legally push an
    /// earlier time than the peeked front (a flow retiring before a
    /// far-future arrival); had the peek advanced the window or rebased
    /// onto the overflow, that push would land below the active bucket
    /// or the new base and be misordered.
    pub fn peek(&self) -> Option<(SimTime, OpId)> {
        if self.len == 0 {
            return None;
        }
        if self.fallback {
            return Some(self.sorted[self.sorted_pos]);
        }
        // buckets partition time in order, so the first non-empty bucket
        // holds the window minimum: the active bucket's undrained tail
        // is sorted (its first element is the bucket min); later buckets
        // are unsorted until activation (linear scan)
        if let Some(&e) = self.buckets[self.active].get(self.pos) {
            return Some(e);
        }
        for idx in self.active + 1..BUCKETS {
            if let Some(&e) = self.buckets[idx].iter().min() {
                return Some(e);
            }
        }
        // window exhausted: everything left overflowed past its end
        debug_assert_eq!(
            self.overflow_min,
            self.overflow.iter().min().copied(),
            "overflow min cache out of sync"
        );
        self.overflow_min
    }

    /// Open a fresh window over the overflow, adapting the bucket width
    /// to the remaining spread (or degrading to the sorted fallback when
    /// the spread is pathological).
    fn rebase(&mut self) {
        debug_assert!(
            !self.overflow.is_empty(),
            "queue accounting broken: len > 0 with nothing stored"
        );
        let mut lo = SimTime::MAX;
        let mut hi: SimTime = 0;
        for &(t, _) in &self.overflow {
            lo = lo.min(t);
            hi = hi.max(t);
        }
        let span = hi - lo;
        // smallest width with span < (BUCKETS - 1) << shift, so every
        // remaining item fits the new window in one redistribution
        let mut shift = 0u32;
        while shift <= FALLBACK_SHIFT && (span >> shift) >= (BUCKETS - 1) as u64 {
            shift += 1;
        }
        if shift > FALLBACK_SHIFT {
            self.fallback = true;
            self.sorted.clear();
            self.sorted_pos = 0;
            self.sorted.append(&mut self.overflow);
            self.overflow_min = None;
            self.sorted.sort_unstable();
            return;
        }
        self.shift = shift;
        self.base = lo & !((1u64 << shift) - 1);
        self.active = 0;
        self.pos = 0;
        let mut items = std::mem::take(&mut self.overflow);
        for (t, id) in items.drain(..) {
            let idx = ((t - self.base) >> self.shift) as usize;
            debug_assert!(idx < BUCKETS, "rebase left an item outside the window");
            self.buckets[idx].push((t, id));
        }
        self.overflow = items; // keep the allocation
        self.overflow_min = None;
        self.buckets[0].sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Deterministic xorshift for reference-driven tests.
    struct Xs(u64);
    impl Xs {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
    }

    /// Drive the queue and a BinaryHeap through an identical monotone
    /// push/pop schedule (`dt` draws each successor's delay); every pop
    /// must match bit-for-bit.
    fn reference_run_with(seed: u64, n: usize, mut dt: impl FnMut(&mut Xs) -> u64) {
        let mut rng = Xs(seed | 1);
        let mut q = ReadyQueue::new();
        let mut h: BinaryHeap<Reverse<(SimTime, OpId)>> = BinaryHeap::new();
        // seed a ready frontier at t = 0
        for id in 0..8usize {
            q.push(0, id);
            h.push(Reverse((0, id)));
        }
        let mut next_id = 8usize;
        let mut pushed = 8usize;
        let mut now: SimTime = 0;
        loop {
            let got = q.pop();
            let want = h.pop().map(|Reverse(e)| e);
            assert_eq!(got, want, "divergence from heap order (seed {seed})");
            let Some((t, _)) = got else { break };
            now = t;
            // each pop spawns 0–2 successors at or after `now`
            if pushed < n {
                for _ in 0..(rng.next() % 3) {
                    let d = dt(&mut rng);
                    q.push(now + d, next_id);
                    h.push(Reverse((now + d, next_id)));
                    next_id += 1;
                    pushed += 1;
                }
            }
        }
        assert!(q.is_empty());
    }

    /// [`reference_run_with`] drawing delays uniformly below `spread`.
    fn reference_run(seed: u64, n: usize, spread: u64) {
        reference_run_with(seed, n, move |rng| rng.next() % spread);
    }

    #[test]
    fn matches_binary_heap_dense() {
        // spreads around and below the bucket width
        for (seed, spread) in [(1u64, 50u64), (2, 5_000), (3, 1)] {
            reference_run(seed, 4000, spread);
        }
    }

    #[test]
    fn matches_binary_heap_window_crossing() {
        // spreads that overflow the initial 1 ms window and force rebases
        for (seed, spread) in [(7u64, 1 << 21), (8, 1 << 26), (9, 40_000_000)] {
            reference_run(seed, 2000, spread);
        }
    }

    #[test]
    fn matches_binary_heap_at_window_edge() {
        // the initial window covers BUCKETS << INITIAL_SHIFT ns; spreads
        // hugging that edge exercise the last in-window bucket, the
        // first overflow item, and the rebase that follows
        let window = (BUCKETS as u64) << INITIAL_SHIFT;
        for (seed, spread) in [
            (11u64, window - 1),
            (12, window),
            (13, window + 1),
            (14, window / 2 + 1),
            (15, 2 * window - 1),
        ] {
            reference_run(seed, 3000, spread);
        }
    }

    #[test]
    fn matches_binary_heap_across_fallback_threshold() {
        // spreads so wide that rebase cannot cover the span with
        // 1 << FALLBACK_SHIFT buckets: the queue must degrade to the
        // sorted drain and still match the heap exactly. The span needed
        // is (BUCKETS - 1) << FALLBACK_SHIFT ≈ 2^48 ns.
        // spreads stay ≤ 2^52 so ~600 chained generations cannot
        // overflow the u64 clock
        for (seed, spread) in [(21u64, 1u64 << 49), (22, 1 << 50), (23, 1 << 52)] {
            reference_run(seed, 600, spread);
        }
    }

    #[test]
    fn matches_binary_heap_bimodal_straddle() {
        // mostly-dense streams with rare giant gaps: the queue keeps
        // rebasing onto tight windows until one gap blows past the
        // fallback threshold mid-run, then drains sorted — pops must
        // stay bit-identical to the heap through the transition
        for seed in [31u64, 32, 33] {
            reference_run_with(seed, 1200, |rng| {
                if rng.next() % 64 == 0 {
                    // ~2^50 ns: guarantees the eventual fallback
                    (1u64 << 50) + rng.next() % (1 << 20)
                } else {
                    rng.next() % 5_000
                }
            });
        }
    }

    #[test]
    fn equal_times_pop_in_id_order() {
        let mut q = ReadyQueue::new();
        for id in [5usize, 1, 9, 0, 3] {
            q.push(100, id);
        }
        q.push(50, 7);
        assert_eq!(q.pop(), Some((50, 7)));
        for want in [0usize, 1, 3, 5, 9] {
            assert_eq!(q.pop(), Some((100, want)));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_bucket_insert_after_partial_drain() {
        let mut q = ReadyQueue::new();
        q.push(10, 1);
        q.push(20, 2);
        assert_eq!(q.pop(), Some((10, 1)));
        // monotone push equal to the last popped time, smaller id than
        // the remaining item: must come out first
        q.push(10, 0);
        assert_eq!(q.pop(), Some((10, 0)));
        assert_eq!(q.pop(), Some((20, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pathological_spread_falls_back_to_sorted_drain() {
        let mut q = ReadyQueue::new();
        q.push(0, 0);
        assert_eq!(q.pop(), Some((0, 0)));
        // two items ~2^55 ns apart: no sane bucket width covers the span
        let far: SimTime = 1 << 55;
        q.push(far, 2);
        q.push(far + (1 << 54), 3);
        q.push(far, 1);
        assert_eq!(q.pop(), Some((far, 1)));
        assert!(q.fallback, "spread this wide must degrade to sorted drain");
        // pushes keep working in fallback mode
        q.push(far + 5, 4);
        assert_eq!(q.pop(), Some((far, 2)));
        assert_eq!(q.pop(), Some((far + 5, 4)));
        assert_eq!(q.pop(), Some((far + (1 << 54), 3)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn peek_is_nondestructive_and_matches_pop() {
        // dense, window-crossing and fallback-triggering schedules: a
        // peek before every pop must return exactly the popped pair and
        // leave the queue's contents (and subsequent pop order) intact
        for (seed, spread) in [(41u64, 50u64), (42, 1 << 21), (43, 1 << 50)] {
            let mut rng = Xs(seed | 1);
            let mut q = ReadyQueue::new();
            let mut h: BinaryHeap<Reverse<(SimTime, OpId)>> = BinaryHeap::new();
            for id in 0..8usize {
                q.push(0, id);
                h.push(Reverse((0, id)));
            }
            let mut next_id = 8usize;
            let mut pushed = 8usize;
            loop {
                let peeked = q.peek();
                assert_eq!(q.peek(), peeked, "repeated peeks must agree");
                let got = q.pop();
                assert_eq!(got, peeked, "pop must return the peeked pair");
                let want = h.pop().map(|Reverse(e)| e);
                assert_eq!(got, want, "divergence from heap order (seed {seed})");
                let Some((t, _)) = got else { break };
                if pushed < 1500 {
                    for _ in 0..(rng.next() % 3) {
                        let d = rng.next() % spread;
                        q.push(t + d, next_id);
                        h.push(Reverse((t + d, next_id)));
                        next_id += 1;
                        pushed += 1;
                    }
                }
            }
            assert!(q.is_empty());
            assert_eq!(q.peek(), None);
        }
    }

    #[test]
    fn push_below_a_peeked_far_future_front_stays_ordered() {
        // the fair-share hazard: peeking a far-future arrival while an
        // earlier completion is about to be pushed. Were peek to perform
        // pop's window advance / overflow rebase, the later (earlier-
        // timed, still monotone) push would land below the active bucket
        // or the rebased base. Peek is purely observational, so the
        // push must come out first.
        let window = (BUCKETS as u64) << INITIAL_SHIFT;
        let mut q = ReadyQueue::new();
        q.push(0, 0);
        assert_eq!(q.pop(), Some((0, 0)));
        // far item lands in the overflow (beyond the initial window)
        q.push(5 * window, 1);
        assert_eq!(q.peek(), Some((5 * window, 1)));
        // an in-window, post-last-popped push after the peek
        q.push(100, 2);
        assert_eq!(q.peek(), Some((100, 2)));
        q.push(window - 1, 3);
        assert_eq!(q.pop(), Some((100, 2)));
        assert_eq!(q.pop(), Some((window - 1, 3)));
        assert_eq!(q.pop(), Some((5 * window, 1)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.peek(), None);
    }

    /// Drive two identical queues through the same monotone schedule:
    /// one drained with [`ReadyQueue::pop_ready_batch`], the other with
    /// the one-at-a-time reference (`pop`, then keep popping while the
    /// peeked front shares the time). Batches must agree exactly.
    fn batch_reference_run(seed: u64, n: usize, mut dt: impl FnMut(&mut Xs) -> u64) {
        let mut rng = Xs(seed | 1);
        let mut qa = ReadyQueue::new();
        let mut qb = ReadyQueue::new();
        for id in 0..16usize {
            qa.push(0, id);
            qb.push(0, id);
        }
        let mut next_id = 16usize;
        let mut pushed = 16usize;
        let mut batch = Vec::new();
        let mut want = Vec::new();
        loop {
            let got_t = qa.pop_ready_batch(&mut batch);
            want.clear();
            let want_t = match qb.pop() {
                Some((t0, id)) => {
                    want.push(id);
                    while qb.peek().is_some_and(|e| e.0 == t0) {
                        want.push(qb.pop().unwrap().1);
                    }
                    Some(t0)
                }
                None => None,
            };
            assert_eq!(got_t, want_t, "batch time diverged (seed {seed})");
            assert_eq!(batch, want, "batch contents diverged (seed {seed})");
            let Some(t0) = got_t else { break };
            if pushed < n {
                // every retired op spawns 0–2 successors at or after t0
                for _ in 0..batch.len() {
                    for _ in 0..(rng.next() % 3) {
                        let d = dt(&mut rng);
                        qa.push(t0 + d, next_id);
                        qb.push(t0 + d, next_id);
                        next_id += 1;
                        pushed += 1;
                    }
                }
            }
        }
        assert!(qa.is_empty() && qb.is_empty());
    }

    #[test]
    fn pop_ready_batch_matches_pop_loop() {
        // same-instant-heavy: half the successors arrive with zero delay,
        // so batches routinely span several ops
        for seed in [51u64, 52, 53] {
            batch_reference_run(seed, 3000, |rng| {
                if rng.next() % 2 == 0 {
                    0
                } else {
                    rng.next() % 5_000
                }
            });
        }
        // bimodal: dense zero-delay bursts, window-crossing spreads, and
        // rare ~2^50 ns gaps that force the sorted-drain fallback
        for seed in [54u64, 55] {
            batch_reference_run(seed, 800, |rng| match rng.next() % 8 {
                0 => (1u64 << 50) + rng.next() % (1 << 20),
                1..=4 => 0,
                _ => rng.next() % (1 << 21),
            });
        }
    }

    #[test]
    fn pop_ready_batch_drains_equal_times_in_id_order() {
        let mut q = ReadyQueue::new();
        for id in [5usize, 1, 9, 0, 3] {
            q.push(100, id);
        }
        q.push(50, 7);
        let mut out = Vec::new();
        assert_eq!(q.pop_ready_batch(&mut out), Some(50));
        assert_eq!(out, vec![7]);
        assert_eq!(q.pop_ready_batch(&mut out), Some(100));
        assert_eq!(out, vec![0, 1, 3, 5, 9]);
        assert_eq!(q.pop_ready_batch(&mut out), None);
        assert!(out.is_empty(), "an empty-queue batch must clear `out`");
        assert!(q.is_empty());
    }

    #[test]
    fn clear_resets_for_reuse() {
        let mut q = ReadyQueue::new();
        for id in 0..100usize {
            q.push((id as u64) * 1_000_000, id); // forces rebases
        }
        for _ in 0..40 {
            q.pop();
        }
        q.clear();
        assert!(q.is_empty());
        q.push(3, 1);
        q.push(1, 2);
        assert_eq!(q.pop(), Some((1, 2)));
        assert_eq!(q.pop(), Some((3, 1)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.len(), 0);
    }
}
