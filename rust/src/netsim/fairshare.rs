//! The max-min fair-share link-contention model.
//!
//! The FIFO engine charges every shared link as *exclusive* occupancy:
//! concurrent transfers on one link serialize back-to-back. Real
//! interconnects (PCIe, NVLink, IB — see the paper's §II and the
//! GPU-centric communication literature) instead *progressively fill*
//! shared links: every in-flight transfer is a flow, each link splits its
//! bandwidth across the flows crossing it, and a flow's rate is the
//! max-min fair allocation over its whole path. This module provides the
//! pieces the engine's fair-share execution path
//! ([`super::engine::Engine`] with [`LinkModel::FairShare`]) runs on:
//!
//! * [`LinkModel`] — the selectable contention model, threaded from the
//!   CLI/tuning layers down to the engine;
//! * [`Flow`] — one in-flight transfer (remaining bytes, current rate,
//!   per-flow cap);
//! * [`FairShareScratch`] — reusable per-engine scratch whose
//!   [`FairShareScratch::recompute_rates`] runs the progressive-filling
//!   (water-filling) allocation on every flow arrival/departure event;
//! * [`maxmin_rates`] — a standalone entry point for property tests
//!   (link-capacity conservation) and diagnostics.
//!
//! The DAG semantics (deps, delays, labels, deliveries) are identical to
//! the FIFO path; only *how concurrent transfers share links* differs.
//! See DESIGN.md §Contention models.

use crate::topology::{Cluster, LinkId, RouteId};

use super::time::SimTime;
use super::transfer::OpId;

/// Which contention model the engine resolves concurrent transfers with.
///
/// `Fifo` is the default and is bit-identical to the engine's historical
/// behaviour (the golden-parity suites pin this). `FairShare` replaces
/// link serialization with progressive-filling max-min bandwidth
/// sharing. Tuned tables record the model that produced them
/// ([`crate::tuning::TuningTable::link_model`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum LinkModel {
    /// Exclusive FIFO link occupancy: a transfer starts only once every
    /// link on its route is free, then owns the path for its issue +
    /// transmission time (the paper's Eq. 5 pipelining semantics).
    #[default]
    Fifo,
    /// Progressive-filling max-min fair sharing: concurrent flows split
    /// each link's bandwidth; rates are recomputed on every flow
    /// arrival/departure. `issue_ns` does not serialize links (there is
    /// no exclusive occupancy to serialize); per-op `overhead_ns` and
    /// route latency still charge to the completion time.
    FairShare,
}

impl LinkModel {
    pub const ALL: [LinkModel; 2] = [LinkModel::Fifo, LinkModel::FairShare];

    pub fn name(&self) -> &'static str {
        match self {
            LinkModel::Fifo => "fifo",
            LinkModel::FairShare => "fairshare",
        }
    }

    pub fn parse(s: &str) -> Option<LinkModel> {
        match s {
            "fifo" => Some(LinkModel::Fifo),
            "fairshare" | "fair-share" | "maxmin" | "max-min" => Some(LinkModel::FairShare),
            _ => None,
        }
    }
}

impl std::fmt::Display for LinkModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One in-flight transfer of the fair-share engine.
#[derive(Debug, Clone)]
pub(crate) struct Flow {
    pub op: OpId,
    pub route: RouteId,
    /// Bytes not yet drained.
    pub remaining: f64,
    /// Current max-min rate, bytes/second (recomputed every event).
    pub rate: f64,
    /// Per-flow bandwidth cap (`bw_cap`), `INFINITY` when uncapped.
    pub cap: f64,
    /// Water-filling marker: this flow's rate is finalized for the pass.
    pub fixed: bool,
    /// Predicted drain instant under the current rates (engine scratch).
    pub fin: f64,
    pub overhead_ns: SimTime,
    pub latency_ns: SimTime,
}

/// Reusable fair-share scratch hanging off the engine: the active flow
/// set plus the per-link working state of the water-filling pass. Sized
/// once per topology; steady-state execution performs no allocations
/// (the `makespan_ns` contract extends to the fair-share path).
#[derive(Debug, Default)]
pub(crate) struct FairShareScratch {
    /// Active (in-flight) flows.
    pub flows: Vec<Flow>,
    /// Per-link remaining capacity during a pass (sized `n_links`).
    caps: Vec<f64>,
    /// Per-link count of unfixed flows crossing it (sized `n_links`).
    nflows: Vec<u32>,
    /// Links charged by the current pass — reset lazily so a pass costs
    /// O(active flows × hops), not O(n_links).
    touched: Vec<LinkId>,
    /// Per-flow tightest-constraint scratch for one round.
    lims: Vec<f64>,
}

impl FairShareScratch {
    pub fn new(n_links: usize) -> FairShareScratch {
        FairShareScratch {
            flows: Vec::new(),
            caps: vec![0.0; n_links],
            nflows: vec![0; n_links],
            touched: Vec::new(),
            lims: Vec::new(),
        }
    }

    /// `true` when the per-link scratch matches the topology (the engine
    /// mirrors its generation fail-fast on this).
    pub fn sized_for(&self, n_links: usize) -> bool {
        self.caps.len() == n_links && self.nflows.len() == n_links
    }

    /// Recompute every active flow's max-min fair rate by progressive
    /// filling (water-filling): repeatedly find the tightest constraint —
    /// a link's `remaining capacity / unfixed flows crossing it`, or a
    /// flow's own cap — fix every flow attaining it at that rate, charge
    /// its links, and repeat until all flows are fixed. Each round fixes
    /// at least the arg-min flow (its limit *is* the round's level, an
    /// exact comparison between identically computed values), so the pass
    /// terminates in at most `flows` rounds; cost is
    /// O(rounds × flows × hops).
    pub fn recompute_rates(&mut self, cluster: &Cluster) {
        // reset the previous pass's per-link charges lazily
        while let Some(l) = self.touched.pop() {
            self.nflows[l.0] = 0;
        }
        for f in self.flows.iter_mut() {
            f.fixed = false;
            f.rate = 0.0;
        }
        for f in self.flows.iter() {
            for &h in cluster.route_hops(f.route).iter() {
                if self.nflows[h.0] == 0 {
                    // a zero/negative-bandwidth link contributes zero
                    // capacity: flows crossing it fix at rate 0 and the
                    // engine completes them at the unreachable sentinel
                    self.caps[h.0] = cluster.link(h).bandwidth.max(0.0);
                    self.touched.push(h);
                }
                self.nflows[h.0] += 1;
            }
        }
        let mut unfixed = self.flows.len();
        self.lims.clear();
        self.lims.resize(self.flows.len(), 0.0);
        while unfixed > 0 {
            // the round's water level: the tightest constraint over all
            // unfixed flows
            let mut level = f64::INFINITY;
            for (i, f) in self.flows.iter().enumerate() {
                if f.fixed {
                    continue;
                }
                let mut lim = f.cap;
                for &h in cluster.route_hops(f.route).iter() {
                    lim = lim.min(self.caps[h.0] / self.nflows[h.0] as f64);
                }
                self.lims[i] = lim;
                level = level.min(lim);
            }
            if level.is_infinite() {
                // no finite constraint (trivial/infinite links, uncapped
                // flows): the remainder drains instantly
                for f in self.flows.iter_mut() {
                    if !f.fixed {
                        f.fixed = true;
                        f.rate = f64::INFINITY;
                    }
                }
                break;
            }
            for i in 0..self.flows.len() {
                if self.flows[i].fixed || self.lims[i] > level {
                    continue;
                }
                self.flows[i].fixed = true;
                self.flows[i].rate = level;
                unfixed -= 1;
                let route = self.flows[i].route;
                for &h in cluster.route_hops(route).iter() {
                    self.caps[h.0] = (self.caps[h.0] - level).max(0.0);
                    self.nflows[h.0] -= 1;
                }
            }
        }
    }
}

/// Max-min fair rates (bytes/second) for a set of concurrent flows, each
/// a route plus an optional per-flow bandwidth cap — the progressive-
/// filling allocation the fair-share engine applies between events.
/// Exposed for property tests (link-capacity conservation: on every
/// link, the rates of the flows crossing it sum to at most its
/// bandwidth) and diagnostics; the engine's hot path reuses its own
/// scratch instead.
pub fn maxmin_rates(cluster: &Cluster, flows: &[(RouteId, Option<f64>)]) -> Vec<f64> {
    let mut scratch = FairShareScratch::new(cluster.n_links());
    for (i, &(route, cap)) in flows.iter().enumerate() {
        scratch.flows.push(Flow {
            op: i,
            route,
            remaining: 1.0,
            rate: 0.0,
            cap: cap.unwrap_or(f64::INFINITY),
            fixed: false,
            fin: 0.0,
            overhead_ns: 0,
            latency_ns: 0,
        });
    }
    scratch.recompute_rates(cluster);
    scratch.flows.iter().map(|f| f.rate).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::flat;

    #[test]
    fn link_model_names_parse_round_trip() {
        for m in LinkModel::ALL {
            assert_eq!(LinkModel::parse(m.name()), Some(m));
            assert_eq!(format!("{m}"), m.name());
        }
        assert_eq!(LinkModel::parse("fair-share"), Some(LinkModel::FairShare));
        assert_eq!(LinkModel::parse("max-min"), Some(LinkModel::FairShare));
        assert_eq!(LinkModel::parse("bogus"), None);
        assert_eq!(LinkModel::default(), LinkModel::Fifo);
    }

    #[test]
    fn single_flow_gets_the_bottleneck() {
        let c = flat(3);
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None)]);
        assert_eq!(rates, vec![10.0e9]); // the flat preset's Ideal links
        // a per-flow cap below the links binds instead
        let rates = maxmin_rates(&c, &[(r01, Some(2.0e9))]);
        assert_eq!(rates, vec![2.0e9]);
    }

    #[test]
    fn shared_uplink_splits_evenly() {
        // 0->1 and 0->2 share the 0->xbar uplink; downstream links are
        // private, so each flow gets half the shared 10 GB/s
        let c = flat(3);
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None), (r02, None)]);
        assert_eq!(rates, vec![5.0e9, 5.0e9]);
    }

    #[test]
    fn capped_flow_releases_share_to_the_other() {
        // max-min, not equal split: the capped flow takes its 1 GB/s and
        // the uncapped one fills the remaining 9 GB/s of the shared link
        let c = flat(3);
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, Some(1.0e9)), (r02, None)]);
        assert_eq!(rates, vec![1.0e9, 9.0e9]);
    }

    #[test]
    fn disjoint_flows_do_not_share() {
        let c = flat(4);
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r23 = c.route(c.rank_device(2), c.rank_device(3)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None), (r23, None)]);
        assert_eq!(rates, vec![10.0e9, 10.0e9]);
    }

    #[test]
    fn rates_conserve_every_link_capacity() {
        // all-to-all-ish flow set on a shared crossbar: on every link the
        // allocated rates must sum to at most its bandwidth
        let c = flat(6);
        let mut flows = Vec::new();
        for src in 0..6usize {
            for dst in 0..6usize {
                if src != dst {
                    let r = c.route(c.rank_device(src), c.rank_device(dst)).unwrap();
                    let cap = if (src + dst) % 3 == 0 { Some(1.5e9) } else { None };
                    flows.push((r, cap));
                }
            }
        }
        let rates = maxmin_rates(&c, &flows);
        let mut per_link = vec![0.0f64; c.n_links()];
        for (i, &(route, _)) in flows.iter().enumerate() {
            assert!(rates[i] > 0.0, "flow {i} starved on a live fabric");
            for &h in c.route_view(route).hops.iter() {
                per_link[h.0] += rates[i];
            }
        }
        for (l, &used) in per_link.iter().enumerate() {
            let bw = c.links()[l].bandwidth;
            assert!(
                used <= bw * (1.0 + 1e-9),
                "link {l} oversubscribed: {used} > {bw}"
            );
        }
    }

    #[test]
    fn zero_bandwidth_link_starves_only_its_flows() {
        use crate::topology::device::{DeviceKind, NodeId};
        use crate::topology::link::LinkKind;
        let mut c = Cluster::new("dead-link");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect_custom(a, b, LinkKind::Ideal, 0.0, 0);
        c.connect_custom(a, d, LinkKind::Ideal, 10.0e9, 0);
        let dead = c.route(a, b).unwrap();
        let live = c.route(a, d).unwrap();
        let rates = maxmin_rates(&c, &[(dead, None), (live, None)]);
        assert_eq!(rates[0], 0.0, "dead link must starve its flow");
        assert_eq!(rates[1], 10.0e9, "live flow must be unaffected");
    }
}
