//! The max-min fair-share link-contention model.
//!
//! The FIFO engine charges every shared link as *exclusive* occupancy:
//! concurrent transfers on one link serialize back-to-back. Real
//! interconnects (PCIe, NVLink, IB — see the paper's §II and the
//! GPU-centric communication literature) instead *progressively fill*
//! shared links: every in-flight transfer is a flow, each link splits its
//! bandwidth across the flows crossing it, and a flow's rate is the
//! max-min fair allocation over its whole path. This module provides the
//! pieces the engine's fair-share execution path
//! ([`super::engine::Engine`] with [`LinkModel::FairShare`]) runs on:
//!
//! * [`LinkModel`] — the selectable contention model, threaded from the
//!   CLI/tuning layers down to the engine;
//! * [`Flow`] — one in-flight transfer (remaining bytes, current rate,
//!   per-flow cap);
//! * [`FairShareScratch`] — reusable per-engine scratch whose
//!   [`FairShareScratch::recompute_rates`] re-solves the max-min
//!   allocation on every flow arrival/departure event, *incrementally*
//!   where possible (see below);
//! * [`maxmin_rates`] — a standalone entry point for property tests
//!   (link-capacity conservation) and diagnostics.
//!
//! ## Incremental recomputation (DESIGN.md §Incremental water-filling)
//!
//! A full progressive-filling pass costs O(rounds × flows × hops) and the
//! engine triggers one per arrival/departure — quadratic in concurrent
//! flows over a workload's lifetime. But an arrival/departure can only
//! change the rates of flows in the *same connected component* of the
//! flow↔link sharing graph: the water-filling solution decomposes
//! exactly (and, with care about iteration order, *bit-exactly*) across
//! components, because a flow's assigned rate is its own tightest
//! constraint at fix time and flows of disjoint components never share a
//! constraint. [`FairShareScratch::add`]/[`FairShareScratch::remove`]
//! therefore record the touched links as *seeds*;
//! [`FairShareScratch::recompute_rates`] grows the affected component
//! from the seeds (epoch-stamped link/flow marks, no per-event clearing)
//! and re-runs water-filling over that member set only, leaving every
//! other flow's rate untouched — those flows' subproblems are unchanged,
//! so their stored rates are still the full-solve answer (maintained
//! inductively). It falls back to the full pass when the component
//! closure doesn't converge quickly ([`MAX_CLOSURE_PASSES`]), when the
//! members exceed [the fallback threshold](FairShareScratch::recompute_rates)
//! anyway, or when a hopless flow (which joins no link component) is
//! added. Debug builds re-run the full solve after every incremental one
//! and assert bit-identical rates.
//!
//! The DAG semantics (deps, delays, labels, deliveries) are identical to
//! the FIFO path; only *how concurrent transfers share links* differs.
//! See DESIGN.md §Contention models.

use crate::topology::{Cluster, LinkId, RouteId};

use super::time::SimTime;
use super::transfer::OpId;

/// Which contention model the engine resolves concurrent transfers with.
///
/// `Fifo` is the default and is bit-identical to the engine's historical
/// behaviour (the golden-parity suites pin this). `FairShare` replaces
/// link serialization with progressive-filling max-min bandwidth
/// sharing. Tuned tables record the model that produced them
/// ([`crate::tuning::TuningTable::link_model`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum LinkModel {
    /// Exclusive FIFO link occupancy: a transfer starts only once every
    /// link on its route is free, then owns the path for its issue +
    /// transmission time (the paper's Eq. 5 pipelining semantics).
    #[default]
    Fifo,
    /// Progressive-filling max-min fair sharing: concurrent flows split
    /// each link's bandwidth; rates are recomputed on every flow
    /// arrival/departure. `issue_ns` does not serialize links (there is
    /// no exclusive occupancy to serialize); per-op `overhead_ns` and
    /// route latency still charge to the completion time.
    FairShare,
}

impl LinkModel {
    pub const ALL: [LinkModel; 2] = [LinkModel::Fifo, LinkModel::FairShare];

    pub fn name(&self) -> &'static str {
        match self {
            LinkModel::Fifo => "fifo",
            LinkModel::FairShare => "fairshare",
        }
    }

    pub fn parse(s: &str) -> Option<LinkModel> {
        match s {
            "fifo" => Some(LinkModel::Fifo),
            "fairshare" | "fair-share" | "maxmin" | "max-min" => Some(LinkModel::FairShare),
            _ => None,
        }
    }
}

impl std::fmt::Display for LinkModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One in-flight transfer of the fair-share engine.
#[derive(Debug, Clone)]
pub(crate) struct Flow {
    pub op: OpId,
    pub route: RouteId,
    /// Bytes not yet drained.
    pub remaining: f64,
    /// Current max-min rate, bytes/second (recomputed every event).
    pub rate: f64,
    /// Per-flow bandwidth cap (`bw_cap`), `INFINITY` when uncapped.
    pub cap: f64,
    /// Water-filling marker: this flow's rate is finalized for the pass.
    pub fixed: bool,
    /// Predicted drain instant under the current rates (engine scratch).
    pub fin: f64,
    /// Rate at the last emitted trace event (−1.0 before the first), so
    /// flow tracing reports only actual rate *changes*. Maintained by the
    /// engine only when a flow trace is requested.
    pub last_rate: f64,
    pub overhead_ns: SimTime,
    pub latency_ns: SimTime,
}

/// Component-closure passes before the incremental path gives up and
/// falls back to a full solve. Each pass is O(flows × hops); a ripple
/// that is still growing after this many breadth steps is wide enough
/// that the full pass costs about the same.
const MAX_CLOSURE_PASSES: u32 = 8;

/// `true` when `FAIRSHARE_FULL_RECOMPUTE` is set (to anything but `0`)
/// in the environment: every solve runs the full water-filling pass —
/// the reference mode the `engine_events` benches use to isolate the
/// incremental win. Read once per process.
fn env_full_recompute() -> bool {
    static FULL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FULL.get_or_init(|| {
        std::env::var_os("FAIRSHARE_FULL_RECOMPUTE").is_some_and(|v| v != "0")
    })
}

/// Reusable fair-share scratch hanging off the engine: the active flow
/// set plus the per-link working state of the water-filling pass. Sized
/// once per topology; steady-state execution performs no allocations
/// (the `makespan_ns` contract extends to the fair-share path).
///
/// Flow membership must go through [`FairShareScratch::add`] /
/// [`FairShareScratch::remove`] / [`FairShareScratch::reset`] — they
/// keep the incremental solver's seed set and per-flow marks in sync
/// with the flow list. Mutating a flow's `remaining`/`fin` in place
/// (the engine's drain loop) is fine.
#[derive(Debug, Default)]
pub(crate) struct FairShareScratch {
    /// Active (in-flight) flows.
    pub flows: Vec<Flow>,
    /// Per-link remaining capacity during a pass (sized `n_links`).
    caps: Vec<f64>,
    /// Per-link count of unfixed flows crossing it (sized `n_links`).
    nflows: Vec<u32>,
    /// Links charged by the current pass — reset lazily so a pass costs
    /// O(members × hops), not O(n_links).
    touched: Vec<LinkId>,
    /// Per-*member* tightest-constraint scratch for one round (indexed
    /// by member slot, not flow index).
    lims: Vec<f64>,
    /// Flow indices the current solve re-rates (the affected component,
    /// or everyone on the full path).
    members: Vec<usize>,
    /// Links on routes of flows added/removed since the last solve —
    /// the incremental closure grows the affected component from these.
    /// Link ids, not flow indices, so `remove`'s `swap_remove` cannot
    /// invalidate them.
    seeds: Vec<LinkId>,
    /// Per-link bandwidth scale (the fault-injection overlay, sized
    /// `n_links`): water-filling sees `bandwidth × bw_scale`. All-ones
    /// outside fault runs — `× 1.0` is an exact identity, so healthy
    /// runs stay bit-identical to the pre-fault solver.
    bw_scale: Vec<f64>,
    /// Links whose `bw_scale` was set since the last
    /// [`FairShareScratch::reset_scales`] — restoring the overlay walks
    /// this list instead of all `n_links` entries (duplicates are
    /// harmless; the list length is bounded by the fault event count).
    scaled: Vec<LinkId>,
    /// Epoch-stamped membership marks (`== epoch` ⇒ in the current
    /// closure), so starting a solve clears nothing.
    link_mark: Vec<u64>,
    flow_mark: Vec<u64>,
    epoch: u64,
    /// A hopless flow joined since the last solve: it belongs to no link
    /// component, so only a full pass can rate it.
    force_next_full: bool,
    /// Always run the full pass (env `FAIRSHARE_FULL_RECOMPUTE`, or
    /// [`FairShareScratch::set_full_recompute`] — the benches' reference
    /// mode).
    full_recompute: bool,
    incremental_solves: u64,
    full_solves: u64,
}

impl FairShareScratch {
    pub fn new(n_links: usize) -> FairShareScratch {
        FairShareScratch {
            flows: Vec::new(),
            caps: vec![0.0; n_links],
            nflows: vec![0; n_links],
            touched: Vec::new(),
            lims: Vec::new(),
            members: Vec::new(),
            seeds: Vec::new(),
            bw_scale: vec![1.0; n_links],
            scaled: Vec::new(),
            link_mark: vec![0; n_links],
            flow_mark: Vec::new(),
            epoch: 0,
            force_next_full: false,
            full_recompute: env_full_recompute(),
            incremental_solves: 0,
            full_solves: 0,
        }
    }

    /// `true` when the per-link scratch matches the topology (the engine
    /// mirrors its generation fail-fast on this).
    pub fn sized_for(&self, n_links: usize) -> bool {
        self.caps.len() == n_links
            && self.nflows.len() == n_links
            && self.link_mark.len() == n_links
            && self.bw_scale.len() == n_links
    }

    /// Set a link's fault-overlay bandwidth scale and seed it for the
    /// next incremental solve — a degraded/failed/restored link re-rates
    /// exactly the component it touches.
    pub fn scale_link(&mut self, l: LinkId, factor: f64) {
        self.bw_scale[l.0] = factor.max(0.0);
        self.scaled.push(l);
        self.seeds.push(l);
    }

    /// Restore every fault-overlay scale set since the last reset back
    /// to 1.0 (the engine calls this before a run when the previous run
    /// injected faults). O(scales set), not O(n_links); returns the
    /// number of entries written so the engine's reset-cost counter can
    /// account for them.
    pub fn reset_scales(&mut self) -> usize {
        let n = self.scaled.len();
        while let Some(l) = self.scaled.pop() {
            self.bw_scale[l.0] = 1.0;
        }
        n
    }

    /// Force (or un-force) the full-recompute reference mode, overriding
    /// the `FAIRSHARE_FULL_RECOMPUTE` environment default.
    pub fn set_full_recompute(&mut self, on: bool) {
        self.full_recompute = on;
    }

    /// `(incremental, full)` solve counts since construction.
    pub fn solve_counts(&self) -> (u64, u64) {
        (self.incremental_solves, self.full_solves)
    }

    /// Admit a flow. Its route's links seed the next incremental solve;
    /// a hopless flow (src == dst route) forces the next solve full,
    /// since it joins no link component.
    pub fn add(&mut self, cluster: &Cluster, flow: Flow) {
        {
            let hops = cluster.route_hops(flow.route);
            if hops.is_empty() {
                self.force_next_full = true;
            } else {
                self.seeds.extend_from_slice(&hops);
            }
        }
        self.flows.push(flow);
        self.flow_mark.push(0);
    }

    /// Retire flow `i` (swap-remove order, mirrored in the mark column).
    /// Its links seed the next solve so the component it leaves gets
    /// re-rated.
    pub fn remove(&mut self, cluster: &Cluster, i: usize) -> Flow {
        {
            let hops = cluster.route_hops(self.flows[i].route);
            self.seeds.extend_from_slice(&hops);
        }
        self.flow_mark.swap_remove(i);
        self.flows.swap_remove(i)
    }

    /// Drop all flows and pending seeds (a fresh `run`). The lazily-reset
    /// per-link scratch carries over untouched — the next solve clears
    /// exactly what the previous pass charged.
    pub fn reset(&mut self) {
        self.flows.clear();
        self.flow_mark.clear();
        self.seeds.clear();
        self.force_next_full = false;
    }

    /// Recompute active flows' max-min fair rates, incrementally when
    /// the pending arrivals/departures allow it.
    ///
    /// Full pass: progressive filling (water-filling) over every flow —
    /// repeatedly find the tightest constraint (a link's `remaining
    /// capacity / unfixed flows crossing it`, or a flow's own cap), fix
    /// every flow attaining it at that rate, charge its links, repeat.
    /// Each round fixes at least the arg-min flow (its limit *is* the
    /// round's level, an exact comparison between identically computed
    /// values), so the pass terminates in at most `flows` rounds.
    ///
    /// Incremental pass: grow the affected component from the seed links
    /// (flows crossing a marked link join and mark their own links, to a
    /// fixpoint), then water-fill the members only. Falls back to the
    /// full pass when the closure needs more than [`MAX_CLOSURE_PASSES`]
    /// growth steps or the members exceed ¾ of the active flows (the
    /// incremental bookkeeping would cost more than it saves), or when a
    /// hopless flow arrived. Rates are bit-identical either way: the
    /// max-min solution decomposes across sharing components, and member
    /// iteration preserves ascending flow order, so every comparison and
    /// subtraction sees the same operands in the same sequence as the
    /// full pass (debug builds assert this after every incremental
    /// solve).
    pub fn recompute_rates(&mut self, cluster: &Cluster) {
        let n = self.flows.len();
        if self.full_recompute || self.force_next_full {
            self.solve_full(cluster);
            return;
        }
        // grow the affected component from the seed links
        self.members.clear();
        self.epoch += 1;
        let e = self.epoch;
        for &l in &self.seeds {
            self.link_mark[l.0] = e;
        }
        self.seeds.clear();
        let mut passes = 0;
        loop {
            let mut grew = false;
            for i in 0..n {
                if self.flow_mark[i] == e {
                    continue;
                }
                let hops = cluster.route_hops(self.flows[i].route);
                if hops.iter().any(|&h| self.link_mark[h.0] == e) {
                    self.flow_mark[i] = e;
                    self.members.push(i);
                    for &h in hops.iter() {
                        if self.link_mark[h.0] != e {
                            self.link_mark[h.0] = e;
                            grew = true;
                        }
                    }
                }
            }
            if !grew {
                break;
            }
            passes += 1;
            if passes >= MAX_CLOSURE_PASSES {
                // runaway ripple — the full pass costs about the same
                self.solve_full(cluster);
                return;
            }
        }
        if self.members.len() * 4 > n * 3 {
            self.solve_full(cluster);
            return;
        }
        self.incremental_solves += 1;
        self.waterfill_members(cluster);
        #[cfg(debug_assertions)]
        self.differential_check(cluster);
    }

    fn solve_full(&mut self, cluster: &Cluster) {
        self.seeds.clear();
        self.force_next_full = false;
        self.members.clear();
        self.members.extend(0..self.flows.len());
        self.full_solves += 1;
        self.waterfill_members(cluster);
    }

    /// Water-fill the flows listed in `self.members`, leaving every other
    /// flow's rate untouched. Iterates members in the order they were
    /// pushed — ascending flow index for the full pass, which makes the
    /// full pass's arithmetic identical to the historical whole-set
    /// solver.
    fn waterfill_members(&mut self, cluster: &Cluster) {
        // reset the previous pass's per-link charges lazily (invariant:
        // a link not in `touched` has nflows == 0)
        while let Some(l) = self.touched.pop() {
            self.nflows[l.0] = 0;
        }
        for k in 0..self.members.len() {
            let i = self.members[k];
            self.flows[i].fixed = false;
            self.flows[i].rate = 0.0;
            let route = self.flows[i].route;
            for &h in cluster.route_hops(route).iter() {
                if self.nflows[h.0] == 0 {
                    // a zero/negative-bandwidth link contributes zero
                    // capacity: flows crossing it fix at rate 0 and the
                    // engine completes them at the unreachable sentinel.
                    // The fault overlay rescales here (×1.0 when healthy
                    // — exact identity).
                    self.caps[h.0] =
                        (cluster.link(h).bandwidth * self.bw_scale[h.0]).max(0.0);
                    self.touched.push(h);
                }
                self.nflows[h.0] += 1;
            }
        }
        let mut unfixed = self.members.len();
        self.lims.clear();
        self.lims.resize(self.members.len(), 0.0);
        while unfixed > 0 {
            // the round's water level: the tightest constraint over all
            // unfixed members
            let mut level = f64::INFINITY;
            for k in 0..self.members.len() {
                let f = &self.flows[self.members[k]];
                if f.fixed {
                    continue;
                }
                let mut lim = f.cap;
                for &h in cluster.route_hops(f.route).iter() {
                    lim = lim.min(self.caps[h.0] / self.nflows[h.0] as f64);
                }
                self.lims[k] = lim;
                level = level.min(lim);
            }
            if level.is_infinite() {
                // no finite constraint (trivial/infinite links, uncapped
                // flows): the remainder drains instantly
                for k in 0..self.members.len() {
                    let f = &mut self.flows[self.members[k]];
                    if !f.fixed {
                        f.fixed = true;
                        f.rate = f64::INFINITY;
                    }
                }
                break;
            }
            for k in 0..self.members.len() {
                let i = self.members[k];
                if self.flows[i].fixed || self.lims[k] > level {
                    continue;
                }
                self.flows[i].fixed = true;
                self.flows[i].rate = level;
                unfixed -= 1;
                let route = self.flows[i].route;
                for &h in cluster.route_hops(route).iter() {
                    self.caps[h.0] = (self.caps[h.0] - level).max(0.0);
                    self.nflows[h.0] -= 1;
                }
            }
        }
    }

    /// Debug-mode differential check: re-run the full pass and assert it
    /// reproduces the incremental result bit for bit. The full pass
    /// *overwrites* every rate — if the incremental solve was right this
    /// is idempotent; if not, the assert fires before the divergence can
    /// propagate into makespans.
    #[cfg(debug_assertions)]
    fn differential_check(&mut self, cluster: &Cluster) {
        let got: Vec<u64> = self.flows.iter().map(|f| f.rate.to_bits()).collect();
        self.members.clear();
        self.members.extend(0..self.flows.len());
        self.waterfill_members(cluster);
        for (i, &bits) in got.iter().enumerate() {
            debug_assert_eq!(
                bits,
                self.flows[i].rate.to_bits(),
                "incremental max-min diverged from the full solve at flow {i} (op {})",
                self.flows[i].op
            );
        }
    }
}

/// Max-min fair rates (bytes/second) for a set of concurrent flows, each
/// a route plus an optional per-flow bandwidth cap — the progressive-
/// filling allocation the fair-share engine applies between events.
/// Exposed for property tests (link-capacity conservation: on every
/// link, the rates of the flows crossing it sum to at most its
/// bandwidth) and diagnostics; the engine's hot path reuses its own
/// scratch instead.
pub fn maxmin_rates(cluster: &Cluster, flows: &[(RouteId, Option<f64>)]) -> Vec<f64> {
    let mut scratch = FairShareScratch::new(cluster.n_links());
    for (i, &(route, cap)) in flows.iter().enumerate() {
        scratch.add(
            cluster,
            Flow {
                op: i,
                route,
                remaining: 1.0,
                rate: 0.0,
                cap: cap.unwrap_or(f64::INFINITY),
                fixed: false,
                fin: 0.0,
                last_rate: -1.0,
                overhead_ns: 0,
                latency_ns: 0,
            },
        );
    }
    scratch.recompute_rates(cluster);
    scratch.flows.iter().map(|f| f.rate).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::flat;

    #[test]
    fn link_model_names_parse_round_trip() {
        for m in LinkModel::ALL {
            assert_eq!(LinkModel::parse(m.name()), Some(m));
            assert_eq!(format!("{m}"), m.name());
        }
        assert_eq!(LinkModel::parse("fair-share"), Some(LinkModel::FairShare));
        assert_eq!(LinkModel::parse("max-min"), Some(LinkModel::FairShare));
        assert_eq!(LinkModel::parse("bogus"), None);
        assert_eq!(LinkModel::default(), LinkModel::Fifo);
    }

    #[test]
    fn single_flow_gets_the_bottleneck() {
        let c = flat(3).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None)]);
        assert_eq!(rates, vec![10.0e9]); // the flat preset's Ideal links
        // a per-flow cap below the links binds instead
        let rates = maxmin_rates(&c, &[(r01, Some(2.0e9))]);
        assert_eq!(rates, vec![2.0e9]);
    }

    #[test]
    fn shared_uplink_splits_evenly() {
        // 0->1 and 0->2 share the 0->xbar uplink; downstream links are
        // private, so each flow gets half the shared 10 GB/s
        let c = flat(3).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None), (r02, None)]);
        assert_eq!(rates, vec![5.0e9, 5.0e9]);
    }

    #[test]
    fn capped_flow_releases_share_to_the_other() {
        // max-min, not equal split: the capped flow takes its 1 GB/s and
        // the uncapped one fills the remaining 9 GB/s of the shared link
        let c = flat(3).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, Some(1.0e9)), (r02, None)]);
        assert_eq!(rates, vec![1.0e9, 9.0e9]);
    }

    #[test]
    fn disjoint_flows_do_not_share() {
        let c = flat(4).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r23 = c.route(c.rank_device(2), c.rank_device(3)).unwrap();
        let rates = maxmin_rates(&c, &[(r01, None), (r23, None)]);
        assert_eq!(rates, vec![10.0e9, 10.0e9]);
    }

    #[test]
    fn rates_conserve_every_link_capacity() {
        // all-to-all-ish flow set on a shared crossbar: on every link the
        // allocated rates must sum to at most its bandwidth
        let c = flat(6).unwrap();
        let mut flows = Vec::new();
        for src in 0..6usize {
            for dst in 0..6usize {
                if src != dst {
                    let r = c.route(c.rank_device(src), c.rank_device(dst)).unwrap();
                    let cap = if (src + dst) % 3 == 0 { Some(1.5e9) } else { None };
                    flows.push((r, cap));
                }
            }
        }
        let rates = maxmin_rates(&c, &flows);
        let mut per_link = vec![0.0f64; c.n_links()];
        for (i, &(route, _)) in flows.iter().enumerate() {
            assert!(rates[i] > 0.0, "flow {i} starved on a live fabric");
            for &h in c.route_view(route).hops.iter() {
                per_link[h.0] += rates[i];
            }
        }
        for (l, &used) in per_link.iter().enumerate() {
            let bw = c.links()[l].bandwidth;
            assert!(
                used <= bw * (1.0 + 1e-9),
                "link {l} oversubscribed: {used} > {bw}"
            );
        }
    }

    #[test]
    fn zero_bandwidth_link_starves_only_its_flows() {
        use crate::topology::device::{DeviceKind, NodeId};
        use crate::topology::link::LinkKind;
        let mut c = Cluster::new("dead-link");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect_custom(a, b, LinkKind::Ideal, 0.0, 0);
        c.connect_custom(a, d, LinkKind::Ideal, 10.0e9, 0);
        let dead = c.route(a, b).unwrap();
        let live = c.route(a, d).unwrap();
        let rates = maxmin_rates(&c, &[(dead, None), (live, None)]);
        assert_eq!(rates[0], 0.0, "dead link must starve its flow");
        assert_eq!(rates[1], 10.0e9, "live flow must be unaffected");
    }

    fn mk_flow(op: OpId, route: RouteId, cap: Option<f64>) -> Flow {
        Flow {
            op,
            route,
            remaining: 1.0,
            rate: 0.0,
            cap: cap.unwrap_or(f64::INFINITY),
            fixed: false,
            fin: 0.0,
            last_rate: -1.0,
            overhead_ns: 0,
            latency_ns: 0,
        }
    }

    #[test]
    fn incremental_arrival_leaves_disjoint_components_alone() {
        // many disjoint pair-flows, then one more arrival: the solve
        // must take the incremental path (members ≪ flows) and still
        // produce the exact full-solve rates
        let c = flat(12).unwrap();
        let mut fs = FairShareScratch::new(c.n_links());
        fs.set_full_recompute(false);
        for p in 0..6usize {
            let r = c
                .route(c.rank_device(2 * p), c.rank_device(2 * p + 1))
                .unwrap();
            fs.add(&c, mk_flow(p, r, None));
            fs.recompute_rates(&c);
        }
        let (inc0, _) = fs.solve_counts();
        // a 7th flow contending with pair 0's source uplink
        let r = c.route(c.rank_device(0), c.rank_device(3)).unwrap();
        fs.add(&c, mk_flow(6, r, None));
        fs.recompute_rates(&c);
        let (inc1, _) = fs.solve_counts();
        assert!(inc1 > inc0, "arrival into a small component must solve incrementally");
        for f in &fs.flows {
            let expect = match f.op {
                // ops 0 and 6 now split device 0's 10 GB/s uplink
                0 | 6 => 5.0e9,
                _ => 10.0e9,
            };
            assert_eq!(f.rate, expect, "op {}", f.op);
        }
        // departures seed the component they leave: retire op 6 (flow
        // order is swap-remove, find it first)
        let i6 = fs.flows.iter().position(|f| f.op == 6).unwrap();
        fs.remove(&c, i6);
        fs.recompute_rates(&c);
        for f in &fs.flows {
            assert_eq!(f.rate, 10.0e9, "op {} after departure", f.op);
        }
    }

    /// A line of devices with heterogeneous link speeds: multi-hop BFS
    /// routes cross several potential bottlenecks.
    fn chain_cluster(n: usize) -> Cluster {
        use crate::topology::device::{DeviceKind, NodeId};
        use crate::topology::link::LinkKind;
        let mut c = Cluster::new("hetero-chain");
        let devs: Vec<_> = (0..n)
            .map(|i| c.add_device(DeviceKind::Gpu, NodeId(0), 0, format!("g{i}")))
            .collect();
        for i in 0..n - 1 {
            // 4, 6, 8, 10, 4, 6, ... GB/s — no uniform bottleneck
            let bw = (4.0 + 2.0 * ((i % 4) as f64)) * 1.0e9;
            c.connect_custom(devs[i], devs[i + 1], LinkKind::Ideal, bw, 0);
        }
        c
    }

    #[test]
    fn incremental_matches_full_on_random_traces() {
        use crate::util::rng::Rng;
        let clusters = [flat(8).unwrap(), chain_cluster(9)];
        for (ci, c) in clusters.iter().enumerate() {
            // every src→dst route (chain routes are multi-hop)
            let n_dev = if ci == 0 { 8 } else { 9 };
            let mut routes = Vec::new();
            for s in 0..n_dev {
                for d in 0..n_dev {
                    if s != d {
                        let (a, b) = if ci == 0 {
                            (c.rank_device(s), c.rank_device(d))
                        } else {
                            (crate::topology::DeviceId(s), crate::topology::DeviceId(d))
                        };
                        routes.push(c.route(a, b).unwrap());
                    }
                }
            }
            let mut inc = FairShareScratch::new(c.n_links());
            let mut full = FairShareScratch::new(c.n_links());
            inc.set_full_recompute(false);
            full.set_full_recompute(true);
            let mut rng = Rng::new(0x5eed_0001 + ci as u64);
            for step in 0..300usize {
                if inc.flows.is_empty() || rng.next_below(3) > 0 {
                    let r = routes[rng.range_usize(0, routes.len() - 1)];
                    let cap = if rng.next_below(4) == 0 {
                        Some((1 + rng.next_below(8)) as f64 * 0.5e9)
                    } else {
                        None
                    };
                    inc.add(c, mk_flow(step, r, cap));
                    full.add(c, mk_flow(step, r, cap));
                } else {
                    let i = rng.range_usize(0, inc.flows.len() - 1);
                    inc.remove(c, i);
                    full.remove(c, i);
                }
                inc.recompute_rates(c);
                full.recompute_rates(c);
                assert_eq!(inc.flows.len(), full.flows.len());
                for (a, b) in inc.flows.iter().zip(full.flows.iter()) {
                    assert_eq!(a.op, b.op, "flow order diverged at step {step}");
                    assert_eq!(
                        a.rate.to_bits(),
                        b.rate.to_bits(),
                        "cluster {ci} step {step} op {}: incremental {} vs full {}",
                        a.op,
                        a.rate,
                        b.rate
                    );
                }
            }
            let (incremental, _) = inc.solve_counts();
            assert!(incremental > 0, "cluster {ci}: incremental path never taken");
            let (f_inc, _) = full.solve_counts();
            assert_eq!(f_inc, 0, "reference scratch must always solve fully");
        }
    }

    #[test]
    fn hopless_flow_forces_a_full_solve_and_gets_its_cap() {
        // a src == dst route has no links: it can't join a component, so
        // the next solve must be full and rate it by its own cap
        let c = flat(4).unwrap();
        let d0 = c.rank_device(0);
        let self_route = c.route(d0, d0).unwrap();
        let pair = c.route(c.rank_device(2), c.rank_device(3)).unwrap();
        let mut fs = FairShareScratch::new(c.n_links());
        fs.set_full_recompute(false);
        fs.add(&c, mk_flow(0, pair, None));
        fs.recompute_rates(&c);
        fs.add(&c, mk_flow(1, self_route, Some(3.0e9)));
        fs.recompute_rates(&c);
        assert_eq!(fs.flows[1].rate, 3.0e9);
        let uncapped = c.route(d0, d0).unwrap();
        fs.add(&c, mk_flow(2, uncapped, None));
        fs.recompute_rates(&c);
        assert_eq!(fs.flows[2].rate, f64::INFINITY);
    }

    #[test]
    fn reset_clears_flows_and_pending_seeds() {
        let c = flat(3).unwrap();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let mut fs = FairShareScratch::new(c.n_links());
        fs.add(&c, mk_flow(0, r01, None));
        fs.reset();
        assert!(fs.flows.is_empty());
        fs.add(&c, mk_flow(1, r01, None));
        fs.recompute_rates(&c);
        assert_eq!(fs.flows[0].rate, 10.0e9);
    }
}
