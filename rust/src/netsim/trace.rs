//! Timeline capture for debugging and the paper-style timeline dumps.
//!
//! Besides the per-op rows, the fair-share model can report *flow
//! rate-change events* ([`FlowEvent`], recorded by
//! [`super::engine::Engine::execute_with_flow_trace`]): one event each
//! time the max-min allocation assigns a flow a different rate —
//! admission, a contending arrival squeezing it, or a departure letting
//! it expand. [`trace_with_flows`] merges those into the op timeline.

use crate::topology::Cluster;
use crate::util::bytes::format_us;

use super::engine::ExecResult;
use super::time::SimTime;
use super::transfer::{OpEnd, OpId, Plan};

/// One rendered timeline row.
#[derive(Debug, Clone)]
pub struct TraceRow {
    pub op_id: usize,
    pub start_ns: u64,
    pub done_ns: u64,
    pub what: String,
}

/// A fair-share flow rate change: at `t_ns`, the max-min allocation
/// granted op `op` a new `rate` (bytes/second). Emitted by
/// [`super::engine::Engine::execute_with_flow_trace`] after every rate
/// recompute, for exactly the flows whose rate differs from their
/// previous allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowEvent {
    pub t_ns: SimTime,
    pub op: OpId,
    pub rate: f64,
}

/// Produce a chronological human-readable trace of a plan execution.
pub fn trace(plan: &Plan, result: &ExecResult, cluster: &Cluster) -> Vec<TraceRow> {
    let mut rows: Vec<TraceRow> = (0..plan.len())
        .map(|id| {
            let what = match plan.ends[id] {
                OpEnd::Route(route) => {
                    let meta = cluster.route_meta(route);
                    let src = &cluster.device(meta.src).name;
                    let dst = &cluster.device(meta.dst).name;
                    let bytes = plan.bytes[id];
                    let label = plan.labels[id]
                        .map(|(r, ch)| format!(" [rank {r} chunk {ch}]"))
                        .unwrap_or_default();
                    format!("xfer {src} -> {dst} {bytes}B{label}")
                }
                OpEnd::Dev(dev) => {
                    // a Delay: its duration lives in the overheads column
                    format!(
                        "delay {} {}us",
                        cluster.device(dev).name,
                        plan.overheads[id] / 1000
                    )
                }
            };
            TraceRow {
                op_id: id,
                start_ns: result.start[id],
                done_ns: result.done[id],
                what,
            }
        })
        .collect();
    rows.sort_by_key(|r| (r.start_ns, r.op_id));
    rows
}

/// [`trace`], with the fair-share [`FlowEvent`]s merged in as
/// zero-duration `rate` rows at their emission instants — the contention
/// story (who got squeezed when, who expanded after a departure) reads
/// inline with the op timeline.
pub fn trace_with_flows(
    plan: &Plan,
    result: &ExecResult,
    cluster: &Cluster,
    events: &[FlowEvent],
) -> Vec<TraceRow> {
    let mut rows = trace(plan, result, cluster);
    rows.extend(events.iter().map(|e| TraceRow {
        op_id: e.op,
        start_ns: e.t_ns,
        done_ns: e.t_ns,
        what: format!("rate -> {:.3} GB/s", e.rate / 1.0e9),
    }));
    rows.sort_by_key(|r| (r.start_ns, r.op_id));
    rows
}

/// Render a trace to text.
pub fn render(rows: &[TraceRow]) -> String {
    let mut out = String::new();
    for r in rows {
        out.push_str(&format!(
            "{:>12}us  {:>12}us  #{:<5} {}\n",
            format_us(r.start_ns as f64),
            format_us(r.done_ns as f64),
            r.op_id,
            r.what
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::engine::Engine;
    use crate::netsim::fairshare::LinkModel;
    use crate::netsim::transfer::{Deps, Plan, SimOp};
    use crate::topology::presets::flat;

    #[test]
    fn trace_is_chronological() {
        let c = flat(3).unwrap();
        let mut plan = Plan::new();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let a = plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: 1000,
                overhead_ns: 10,
                issue_ns: 10,
                bw_cap: None,
            },
            vec![],
            Some((1, 0)),
        );
        plan.push(
            SimOp::Transfer {
                route: r02,
                bytes: 1000,
                overhead_ns: 10,
                issue_ns: 10,
                bw_cap: None,
            },
            vec![a],
            Some((2, 0)),
        );
        let mut e = Engine::new(&c);
        let result = e.execute(&plan);
        let rows = trace(&plan, &result, &c);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].start_ns <= rows[1].start_ns);
        let text = render(&rows);
        assert!(text.contains("rank 2"));
    }

    #[test]
    fn contention_trace_records_the_rate_drop_and_recovery() {
        // the closed-form two-flow scenario from the engine tests: 10 MB
        // (op 0) and 5 MB (op 1) share the 10 GB/s uplink. Both admit at
        // 5 GB/s; when the 5 MB flow drains at t = 1 ms the survivor
        // expands to the full 10 GB/s — the trace must contain both the
        // shared-rate events and the recovery event.
        let c = flat(3).unwrap();
        let mut plan = Plan::new();
        for (dst, bytes) in [(1usize, 10_000_000u64), (2, 5_000_000)] {
            let route = c.route(c.rank_device(0), c.rank_device(dst)).unwrap();
            plan.push(
                SimOp::Transfer {
                    route,
                    bytes,
                    overhead_ns: 1000,
                    issue_ns: 1000,
                    bw_cap: None,
                },
                Deps::none(),
                Some((dst, 0)),
            );
        }
        let mut e = Engine::with_model(&c, LinkModel::FairShare);
        let (result, events) = e.execute_with_flow_trace(&plan);
        assert_eq!(result.makespan, 1_501_000);
        // both flows admitted at the shared 5 GB/s rate, at t = 0
        for op in [0usize, 1] {
            assert!(
                events
                    .iter()
                    .any(|ev| ev.op == op && ev.t_ns == 0 && ev.rate == 5.0e9),
                "missing shared-rate event for op {op}: {events:?}"
            );
        }
        // the survivor expands to the full link after the departure
        assert!(
            events
                .iter()
                .any(|ev| ev.op == 0 && ev.t_ns >= 1_000_000 && ev.rate == 10.0e9),
            "missing recovery event: {events:?}"
        );
        // and no event ever repeats a flow's previous rate
        let mut last: std::collections::HashMap<usize, f64> = Default::default();
        for ev in &events {
            assert_ne!(last.get(&ev.op).copied(), Some(ev.rate), "duplicate: {ev:?}");
            last.insert(ev.op, ev.rate);
        }
        // the merged timeline interleaves rate rows with op rows
        let rows = trace_with_flows(&plan, &result, &c, &events);
        assert_eq!(rows.len(), plan.len() + events.len());
        let text = render(&rows);
        assert!(text.contains("GB/s"), "{text}");
    }
}
