//! Timeline capture for debugging and the paper-style timeline dumps.

use crate::topology::Cluster;
use crate::util::bytes::format_us;

use super::engine::ExecResult;
use super::transfer::{Plan, SimOp};

/// One rendered timeline row.
#[derive(Debug, Clone)]
pub struct TraceRow {
    pub op_id: usize,
    pub start_ns: u64,
    pub done_ns: u64,
    pub what: String,
}

/// Produce a chronological human-readable trace of a plan execution.
pub fn trace(plan: &Plan, result: &ExecResult, cluster: &Cluster) -> Vec<TraceRow> {
    let mut rows: Vec<TraceRow> = plan
        .ops
        .iter()
        .enumerate()
        .map(|(id, op)| {
            let what = match &op.op {
                SimOp::Transfer { route, bytes, .. } => {
                    let meta = cluster.route_meta(*route);
                    let src = &cluster.device(meta.src).name;
                    let dst = &cluster.device(meta.dst).name;
                    let label = op
                        .label
                        .map(|(r, ch)| format!(" [rank {r} chunk {ch}]"))
                        .unwrap_or_default();
                    format!("xfer {src} -> {dst} {bytes}B{label}")
                }
                SimOp::Delay { dev, dur_ns } => {
                    format!("delay {} {}us", cluster.device(*dev).name, dur_ns / 1000)
                }
            };
            TraceRow {
                op_id: id,
                start_ns: result.start[id],
                done_ns: result.done[id],
                what,
            }
        })
        .collect();
    rows.sort_by_key(|r| (r.start_ns, r.op_id));
    rows
}

/// Render a trace to text.
pub fn render(rows: &[TraceRow]) -> String {
    let mut out = String::new();
    for r in rows {
        out.push_str(&format!(
            "{:>12}us  {:>12}us  #{:<5} {}\n",
            format_us(r.start_ns as f64),
            format_us(r.done_ns as f64),
            r.op_id,
            r.what
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::engine::Engine;
    use crate::netsim::transfer::Plan;
    use crate::topology::presets::flat;

    #[test]
    fn trace_is_chronological() {
        let c = flat(3);
        let mut plan = Plan::new();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r02 = c.route(c.rank_device(0), c.rank_device(2)).unwrap();
        let a = plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: 1000,
                overhead_ns: 10,
                issue_ns: 10,
                bw_cap: None,
            },
            vec![],
            Some((1, 0)),
        );
        plan.push(
            SimOp::Transfer {
                route: r02,
                bytes: 1000,
                overhead_ns: 10,
                issue_ns: 10,
                bw_cap: None,
            },
            vec![a],
            Some((2, 0)),
        );
        let mut e = Engine::new(&c);
        let result = e.execute(&plan);
        let rows = trace(&plan, &result, &c);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].start_ns <= rows[1].start_ns);
        let text = render(&rows);
        assert!(text.contains("rank 2"));
    }
}
