//! The discrete-event executor.
//!
//! Greedy list scheduling over the op DAG: ops become *ready* when all
//! dependencies complete; ready ops are processed in (ready-time, op-id)
//! order; each transfer's actual start is pushed past the free time of
//! every link on its route (cut-through occupancy), giving FIFO link
//! contention. Deterministic by construction.
//!
//! Routes are interned ids resolved through the cluster's route table, so
//! executing an op touches no heap; all per-plan working state (indegree,
//! CSR dependents graph, ready times, timestamps, the scatter cursor)
//! lives in reusable scratch on the [`Engine`] (DESIGN.md §Perf). Sweeps
//! that only need the makespan should call [`Engine::makespan_ns`], which
//! skips the per-op timestamp copy entirely. The ready set is an indexed
//! two-level bucket queue ([`super::queue::ReadyQueue`]) — ready times
//! are monotone under list scheduling, so the former `BinaryHeap`'s
//! per-op `O(log n)` was the last superlinear cost on the makespan-only
//! path.

use crate::topology::Cluster;

use super::queue::ReadyQueue;
use super::time::{tx_ns, SimTime};
use super::transfer::{OpId, Plan, SimOp};

/// Execution outcome: per-op timestamps plus the makespan.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub start: Vec<SimTime>,
    pub done: Vec<SimTime>,
    pub makespan: SimTime,
}

impl ExecResult {
    /// Completion time of the transfer that delivered `(rank, chunk)`,
    /// given the plan the result came from. Uses the plan's memoized
    /// deliveries map — no per-query rebuild.
    pub fn delivery_time(&self, plan: &Plan, rank: usize, chunk: usize) -> Option<SimTime> {
        plan.deliveries().get(&(rank, chunk)).map(|&id| self.done[id])
    }

    /// Per-rank completion: max completion over all labelled deliveries to
    /// that rank (via the memoized deliveries map — no rescan of the op
    /// list). Ranks with no deliveries (the root) report 0.
    pub fn rank_completion(&self, plan: &Plan, n_ranks: usize) -> Vec<SimTime> {
        let mut out = vec![0; n_ranks];
        for (&(rank, _chunk), &id) in plan.deliveries() {
            if rank < n_ranks {
                out[rank] = out[rank].max(self.done[id]);
            }
        }
        out
    }
}

/// The simulator engine. Holds reusable scratch state so sweeps don't
/// re-allocate per collective (hot path — see DESIGN.md §Perf).
pub struct Engine<'c> {
    cluster: &'c Cluster,
    /// Route-table generation `link_free`/`dev_free` were sized against.
    /// The borrow of `cluster` makes a mutation-while-alive impossible
    /// today, but a future rebind API or interior mutability would
    /// silently desync the scratch — `run` fails fast in debug builds
    /// instead (mirroring `RouteId`'s stale-generation check).
    generation: u32,
    link_free: Vec<SimTime>,
    dev_free: Vec<SimTime>,
    // reusable scratch (per-plan O(n) state) — avoids reallocating on
    // every collective of a sweep. CSR layout for the dependents graph
    // instead of a Vec<Vec<_>> (§Perf: the per-op Vec allocations made
    // large plans superlinear).
    indegree: Vec<u32>,
    ready_time: Vec<SimTime>,
    dep_offsets: Vec<u32>,
    dep_targets: Vec<OpId>,
    cursor: Vec<u32>,
    start: Vec<SimTime>,
    done: Vec<SimTime>,
    ready: ReadyQueue,
}

impl<'c> Engine<'c> {
    pub fn new(cluster: &'c Cluster) -> Engine<'c> {
        Engine {
            cluster,
            generation: cluster.routes().generation(),
            link_free: vec![0; cluster.n_links()],
            dev_free: vec![0; cluster.n_devices()],
            indegree: Vec::new(),
            ready_time: Vec::new(),
            dep_offsets: Vec::new(),
            dep_targets: Vec::new(),
            cursor: Vec::new(),
            start: Vec::new(),
            done: Vec::new(),
            ready: ReadyQueue::new(),
        }
    }

    pub fn cluster(&self) -> &Cluster {
        self.cluster
    }

    /// Execute a plan starting at virtual time 0, returning per-op
    /// timestamps.
    pub fn execute(&mut self, plan: &Plan) -> ExecResult {
        let makespan = self.run(plan, true);
        ExecResult {
            start: self.start.clone(),
            done: self.done.clone(),
            makespan,
        }
    }

    /// Execute a plan and return only its makespan — the sweep hot path.
    /// Skips per-op timestamp bookkeeping and performs no allocations
    /// beyond scratch growth on the first (largest) plan.
    pub fn makespan_ns(&mut self, plan: &Plan) -> SimTime {
        self.run(plan, false)
    }

    fn run(&mut self, plan: &Plan, record: bool) -> SimTime {
        debug_assert_eq!(
            self.generation,
            self.cluster.routes().generation(),
            "engine scratch desynced: topology changed since Engine::new"
        );
        debug_assert_eq!(
            self.link_free.len(),
            self.cluster.n_links(),
            "engine link scratch sized for a different topology"
        );
        debug_assert_eq!(
            self.dev_free.len(),
            self.cluster.n_devices(),
            "engine device scratch sized for a different topology"
        );
        self.link_free.iter_mut().for_each(|t| *t = 0);
        self.dev_free.iter_mut().for_each(|t| *t = 0);

        let n = plan.ops.len();
        // CSR reverse-dependency graph: dep_offsets[d]..dep_offsets[d+1]
        // indexes dep_targets with the ops depending on d
        self.indegree.clear();
        self.indegree.resize(n, 0);
        self.dep_offsets.clear();
        self.dep_offsets.resize(n + 1, 0);
        for op in plan.ops.iter() {
            for &d in op.deps.as_slice() {
                self.dep_offsets[d + 1] += 1;
            }
        }
        for i in 0..n {
            self.dep_offsets[i + 1] += self.dep_offsets[i];
        }
        let total_deps = self.dep_offsets[n] as usize;
        self.dep_targets.clear();
        self.dep_targets.resize(total_deps, 0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.dep_offsets[..n]);
        for (id, op) in plan.ops.iter().enumerate() {
            self.indegree[id] = op.deps.len() as u32;
            for &d in op.deps.as_slice() {
                self.dep_targets[self.cursor[d] as usize] = id;
                self.cursor[d] += 1;
            }
        }

        self.ready_time.clear();
        self.ready_time.resize(n, 0);
        if record {
            self.start.clear();
            self.start.resize(n, 0);
            self.done.clear();
            self.done.resize(n, 0);
        }
        // (ready, id) min-queue over monotone ready times
        self.ready.clear();
        for id in 0..n {
            if self.indegree[id] == 0 {
                self.ready.push(0, id);
            }
        }

        let mut processed = 0usize;
        let mut makespan: SimTime = 0;
        while let Some((ready, id)) = self.ready.pop() {
            processed += 1;
            let (s, d) = self.run_op(&plan.ops[id].op, ready);
            if record {
                self.start[id] = s;
                self.done[id] = d;
            }
            makespan = makespan.max(d);
            let lo = self.dep_offsets[id] as usize;
            let hi = self.dep_offsets[id + 1] as usize;
            for i in lo..hi {
                let dep = self.dep_targets[i];
                self.ready_time[dep] = self.ready_time[dep].max(d);
                self.indegree[dep] -= 1;
                if self.indegree[dep] == 0 {
                    self.ready.push(self.ready_time[dep], dep);
                }
            }
        }
        assert_eq!(
            processed, n,
            "plan has a dependency cycle ({processed}/{n} ops ran)"
        );

        makespan
    }

    /// Run one op at its ready time; returns (actual start, completion).
    fn run_op(&mut self, op: &SimOp, ready: SimTime) -> (SimTime, SimTime) {
        match op {
            SimOp::Delay { dev, dur_ns } => {
                let s = ready.max(self.dev_free[dev.0]);
                let d = s + dur_ns;
                self.dev_free[dev.0] = d;
                (s, d)
            }
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns,
                issue_ns,
                bw_cap,
            } => {
                let cluster = self.cluster;
                let meta = cluster.route_meta(*route);
                if meta.hop_len == 0 {
                    // local (same-device) op: pure overhead
                    return (ready, ready + overhead_ns);
                }
                let hops = cluster.route_hops(*route);
                // start after every link on the path is free (cut-through:
                // the message occupies the whole path simultaneously)
                let mut s = ready;
                for &h in hops.iter() {
                    s = s.max(self.link_free[h.0]);
                }
                let eff_bw = match bw_cap {
                    Some(cap) => meta.bottleneck_bw.min(*cap),
                    None => meta.bottleneck_bw,
                };
                let tx = tx_ns(*bytes, eff_bw);
                // Each link is busy for the transfer's *issue* cost plus
                // its own transmission time. MPI sends set issue == t_s,
                // which makes back-to-back chunks on one link cost
                // (t_s + C/B) each — the pipelining model of the paper's
                // Eq. (5).
                for &h in hops.iter() {
                    let link_bw = match bw_cap {
                        Some(cap) => cluster.link(h).bandwidth.min(*cap),
                        None => cluster.link(h).bandwidth,
                    };
                    self.link_free[h.0] = s + issue_ns + tx_ns(*bytes, link_bw);
                }
                let d = s + overhead_ns + meta.latency_ns + tx;
                (s, d)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::transfer::{Deps, Plan};
    use crate::topology::presets::flat;

    fn transfer_plan(cluster: &Cluster, pairs: &[(usize, usize, u64)]) -> Plan {
        let mut plan = Plan::new();
        for &(src, dst, bytes) in pairs {
            let route = cluster
                .route(cluster.rank_device(src), cluster.rank_device(dst))
                .unwrap();
            plan.push(
                SimOp::Transfer {
                    route,
                    bytes,
                    overhead_ns: 1000,
                    issue_ns: 1000,
                    bw_cap: None,
                },
                Deps::none(),
                Some((dst, 0)),
            );
        }
        plan
    }

    #[test]
    fn single_transfer_cost() {
        let c = flat(2);
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000)]);
        let r = e.execute(&plan);
        // 10 MB over 10 GB/s = 1 ms, + 1 µs overhead, 0 latency
        assert_eq!(r.makespan, 1_000_000 + 1000);
    }

    #[test]
    fn independent_transfers_overlap() {
        let c = flat(4);
        let mut e = Engine::new(&c);
        // 0->1 and 2->3 share no links
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (2, 3, 10_000_000)]);
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 1_001_000);
    }

    #[test]
    fn shared_source_link_serialises() {
        let c = flat(3);
        let mut e = Engine::new(&c);
        // 0->1 and 0->2 share the 0->xbar uplink
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (0, 2, 10_000_000)]);
        let r = e.execute(&plan);
        // second transfer waits for the first's t_s + transmission
        // (1µs + 1ms), then pays its own t_s + 1ms
        assert_eq!(r.makespan, 2 * (1_000_000 + 1000));
    }

    #[test]
    fn deps_respected() {
        let c = flat(3);
        let mut e = Engine::new(&c);
        let mut plan = Plan::new();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r12 = c.route(c.rank_device(1), c.rank_device(2)).unwrap();
        let a = plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::none(),
            Some((1, 0)),
        );
        plan.push(
            SimOp::Transfer {
                route: r12,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::one(a),
            Some((2, 0)),
        );
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 2_000_000); // strictly sequential
        assert_eq!(r.start[1], 1_000_000);
    }

    #[test]
    fn bw_cap_applies() {
        let c = flat(2);
        let mut e = Engine::new(&c);
        let route = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let mut plan = Plan::new();
        plan.push(
            SimOp::Transfer {
                route,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: Some(2.0e9),
            },
            Deps::none(),
            None,
        );
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 5_000_000); // 10MB at 2GB/s
    }

    #[test]
    fn delay_serialises_on_device() {
        let c = flat(1);
        let mut e = Engine::new(&c);
        let mut plan = Plan::new();
        let dev = c.rank_device(0);
        plan.push(SimOp::Delay { dev, dur_ns: 500 }, Deps::none(), None);
        plan.push(SimOp::Delay { dev, dur_ns: 300 }, Deps::none(), None);
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 800);
    }

    #[test]
    fn rank_completion_maps_labels() {
        let c = flat(3);
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 1000), (0, 2, 1000)]);
        let r = e.execute(&plan);
        let rc = r.rank_completion(&plan, 3);
        assert_eq!(rc[0], 0);
        assert!(rc[1] > 0 && rc[2] > 0);
    }

    #[test]
    fn merged_schedules_keep_delivery_queries() {
        // regression: Plan::merge used to drop labels, so rank_completion
        // and delivery_time on a merged schedule returned empty/0
        let c = flat(3);
        let mut e = Engine::new(&c);
        let a = transfer_plan(&c, &[(0, 1, 1000)]);
        let b = transfer_plan(&c, &[(0, 2, 1000)]);
        let mut merged = Plan::new();
        let ha = merged.merge(&a);
        let hb = merged.merge(&b);
        let r = e.execute(&merged);
        let t1 = r.delivery_time(&merged, 1, crate::netsim::ns_chunk(ha.namespace, 0));
        let t2 = r.delivery_time(&merged, 2, crate::netsim::ns_chunk(hb.namespace, 0));
        assert!(t1.is_some() && t2.is_some());
        let rc = r.rank_completion(&merged, 3);
        assert_eq!(rc[1], t1.unwrap());
        assert_eq!(rc[2], t2.unwrap());
        assert_eq!(rc[0], 0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        // construct a cyclic plan by hand (bypassing push's debug_assert)
        let c = flat(2);
        let mut plan = Plan::new();
        plan.push(
            SimOp::Delay {
                dev: c.rank_device(0),
                dur_ns: 1,
            },
            Deps::none(),
            None,
        );
        plan.ops[0].deps = Deps::one(0);
        let mut e = Engine::new(&c);
        e.execute(&plan);
    }

    #[test]
    fn engine_reuse_resets_state() {
        let c = flat(2);
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000)]);
        let first = e.execute(&plan).makespan;
        let second = e.execute(&plan).makespan;
        assert_eq!(first, second);
    }

    #[test]
    fn makespan_only_path_matches_execute() {
        let c = flat(4);
        let mut e = Engine::new(&c);
        let plan = transfer_plan(
            &c,
            &[(0, 1, 10_000_000), (0, 2, 5_000_000), (2, 3, 1_000_000)],
        );
        let full = e.execute(&plan).makespan;
        let fast = e.makespan_ns(&plan);
        assert_eq!(full, fast);
        // and interleaving the two paths keeps determinism
        assert_eq!(e.execute(&plan).makespan, full);
    }
}
