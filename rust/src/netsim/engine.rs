//! The discrete-event executor.
//!
//! Greedy list scheduling over the op DAG: ops become *ready* when all
//! dependencies complete; ready ops are processed in (ready-time, op-id)
//! order. How concurrent transfers contend for links is selectable
//! ([`LinkModel`], DESIGN.md §Contention models):
//!
//! * [`LinkModel::Fifo`] (default) — each transfer's actual start is
//!   pushed past the free time of every link on its route (cut-through
//!   exclusive occupancy), so concurrent transfers on a shared link
//!   serialize back-to-back;
//! * [`LinkModel::FairShare`] — in-flight transfers are *flows* that
//!   progressively fill shared links: per-link active-flow sets determine
//!   max-min fair rates, recomputed (incrementally — see DESIGN.md
//!   §Incremental water-filling) on every flow arrival/departure event
//!   ([`super::fairshare`]). Deps, delays, labels and deliveries behave
//!   identically; only bandwidth sharing differs.
//!
//! Both paths are deterministic by construction.
//!
//! Routes are interned ids resolved through the cluster's route table, so
//! executing an op touches no heap; all per-plan working state (indegree,
//! CSR dependents graph, ready times, timestamps, the scatter cursor,
//! and the fair-share flow set) lives in reusable scratch on the
//! [`Engine`] (DESIGN.md §Perf). Sweeps that only need the makespan
//! should call [`Engine::makespan_ns`], which skips the per-op timestamp
//! copy entirely. The ready set is an indexed two-level bucket queue
//! ([`super::queue::ReadyQueue`]) — ready times are monotone under list
//! scheduling, so the former `BinaryHeap`'s per-op `O(log n)` was the
//! last superlinear cost on the makespan-only path. Both loops drain the
//! queue in whole same-instant *batches*
//! ([`super::queue::ReadyQueue::pop_ready_batch`]); a zero-duration op
//! that releases a same-instant dependent splices it into the undrained
//! batch tail by op id, which reproduces the one-at-a-time `(t, id)` pop
//! order exactly. The execute loops stream the plan's SoA columns
//! (`ends`/`bytes`/`overheads`/`issues`/`bw_caps`/`deps`) rather than
//! reconstructing per-op structs.

use crate::topology::{Cluster, DeviceId, DeviceKind, RouteId};

use super::fairshare::{FairShareScratch, Flow, LinkModel};
use super::faults::{FaultSchedule, LinkEvent};
use super::queue::ReadyQueue;
use super::time::{tx_ns, SimTime, UNREACHABLE_NS};
use super::trace::FlowEvent;
use super::transfer::{OpEnd, OpId, Plan};

/// Execution outcome: per-op timestamps plus the makespan.
#[derive(Debug, Clone)]
pub struct ExecResult {
    pub start: Vec<SimTime>,
    pub done: Vec<SimTime>,
    pub makespan: SimTime,
}

/// Per-rank delivery status of a (possibly fault-injected) run — the
/// degraded-outcome view of an [`ExecResult`]. A rank is *undelivered*
/// when any of its labelled deliveries completed at (or past) the
/// [`UNREACHABLE_NS`] sentinel: the fabric lost every route to it within
/// the retry budget and the run finished partially instead of panicking.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegradedOutcome {
    pub n_ranks: usize,
    /// Ranks whose payload never arrived, ascending.
    pub undelivered: Vec<usize>,
    /// Max completion over the ops that finished below the sentinel —
    /// the makespan of the part of the run that actually happened.
    pub delivered_makespan: SimTime,
    /// The full makespan (sentinel-valued when anything was lost).
    pub makespan: SimTime,
}

impl DegradedOutcome {
    /// Every rank got its payload.
    pub fn is_complete(&self) -> bool {
        self.undelivered.is_empty()
    }

    /// Number of ranks that did receive their payload.
    pub fn delivered_ranks(&self) -> usize {
        self.n_ranks - self.undelivered.len()
    }
}

impl ExecResult {
    /// Completion time of the transfer that delivered `(rank, chunk)`,
    /// given the plan the result came from. Uses the plan's memoized
    /// deliveries map — no per-query rebuild.
    pub fn delivery_time(&self, plan: &Plan, rank: usize, chunk: usize) -> Option<SimTime> {
        plan.deliveries().get(&(rank, chunk)).map(|&id| self.done[id])
    }

    /// Per-rank completion: max completion over all labelled deliveries to
    /// that rank (via the memoized deliveries map — no rescan of the op
    /// list). Ranks with no deliveries (the root) report 0.
    pub fn rank_completion(&self, plan: &Plan, n_ranks: usize) -> Vec<SimTime> {
        let mut out = vec![0; n_ranks];
        for (&(rank, _chunk), &id) in plan.deliveries() {
            if rank < n_ranks {
                out[rank] = out[rank].max(self.done[id]);
            }
        }
        out
    }

    /// The degraded-outcome view: which ranks were actually delivered,
    /// given the plan the result came from. On a healthy run every rank
    /// is delivered and `delivered_makespan == makespan`.
    pub fn degraded_outcome(&self, plan: &Plan, n_ranks: usize) -> DegradedOutcome {
        let mut lost = vec![false; n_ranks];
        for (&(rank, _chunk), &id) in plan.deliveries() {
            if rank < n_ranks && self.done[id] >= UNREACHABLE_NS {
                lost[rank] = true;
            }
        }
        let undelivered: Vec<usize> = (0..n_ranks).filter(|&r| lost[r]).collect();
        let delivered_makespan = self
            .done
            .iter()
            .copied()
            .filter(|&d| d < UNREACHABLE_NS)
            .max()
            .unwrap_or(0);
        DegradedOutcome {
            n_ranks,
            undelivered,
            delivered_makespan,
            makespan: self.makespan,
        }
    }
}

/// The simulator engine. Holds reusable scratch state so sweeps don't
/// re-allocate per collective (hot path — see DESIGN.md §Perf).
pub struct Engine<'c> {
    cluster: &'c Cluster,
    /// Link-contention model this engine resolves transfers with.
    model: LinkModel,
    /// Route-table generation `link_free`/`dev_free` were sized against.
    /// The borrow of `cluster` makes a mutation-while-alive impossible
    /// today, but a future rebind API or interior mutability would
    /// silently desync the scratch — `run` fails fast in debug builds
    /// instead (mirroring `RouteId`'s stale-generation check).
    generation: u32,
    /// Per-link / per-device earliest-free times, epoch-stamped: an
    /// entry is live only while its stamp in `link_epoch`/`dev_epoch`
    /// equals `epoch`; stale entries read as 0. `run` clears the whole
    /// scratch by bumping `epoch` — O(1) per run instead of O(n_links +
    /// n_devices), which matters at datacenter scale where a 64k-GPU
    /// fabric has hundreds of thousands of links and a plan may touch a
    /// few dozen.
    link_free: Vec<SimTime>,
    dev_free: Vec<SimTime>,
    link_epoch: Vec<u32>,
    dev_epoch: Vec<u32>,
    /// Current scratch epoch; stamps are valid when equal. The stamp
    /// arrays start at 0 and `run` bumps before use, so epoch 0 means
    /// "no run yet". On u32 wrap the stamps are re-zeroed once.
    epoch: u32,
    // reusable scratch (per-plan O(n) state) — avoids reallocating on
    // every collective of a sweep. CSR layout for the dependents graph
    // instead of a Vec<Vec<_>> (§Perf: the per-op Vec allocations made
    // large plans superlinear).
    indegree: Vec<u32>,
    ready_time: Vec<SimTime>,
    dep_offsets: Vec<u32>,
    dep_targets: Vec<OpId>,
    cursor: Vec<u32>,
    start: Vec<SimTime>,
    done: Vec<SimTime>,
    ready: ReadyQueue,
    /// Same-instant drain buffer for [`ReadyQueue::pop_ready_batch`].
    batch: Vec<OpId>,
    /// Fair-share flow set + water-filling scratch (unused under FIFO).
    fs: FairShareScratch,
    // ---- fault injection (DESIGN.md §Fault model) ----
    /// Active fault schedule. `None` or empty ⇒ every fault branch below
    /// is skipped and execution is bit-identical to the pre-fault engine.
    faults: Option<FaultSchedule>,
    /// `faults` is present *and* non-empty, latched per run.
    faults_active: bool,
    /// Per-link bandwidth factor currently in effect (fair-share event
    /// cursor state; FIFO looks factors up by start time instead).
    bw_factor: Vec<f64>,
    /// Per-device straggler duration multiplier (1.0 = nominal).
    dev_factor: Vec<f64>,
    /// Per-link `(at_ns, factor)` event lists, time-sorted — the
    /// factor-at-instant lookup both loops and the detour picker share.
    link_fault_events: Vec<Vec<(SimTime, f64)>>,
    /// Detour attempts left per op (seeded from the schedule's budget).
    retry_left: Vec<u32>,
    /// Detour route a re-admitted op must run on instead of its plan
    /// route (fair-share retries round-trip through the ready set).
    retry_route: Vec<Option<RouteId>>,
    /// Bytes still undrained when the op's flow was killed.
    retry_remaining: Vec<f64>,
    /// The op's next pop from the ready set is a re-admission: keep its
    /// original start, don't re-count it as processed.
    retry_pending: Vec<bool>,
    /// Virtual time charged per detour attempt (from the schedule).
    retry_timeout_ns: SimTime,
    /// The previous run injected faults: reset `bw_factor`, the
    /// fair-share scales and the event lists before the next run.
    scales_stale: bool,
    /// Link indices whose `bw_factor`/`link_fault_events` the current
    /// fault schedule touched — the pre-run reset restores exactly these
    /// instead of sweeping all `n_links` entries.
    touched_links: Vec<usize>,
    /// Device indices whose `dev_factor` the current schedule touched.
    touched_devs: Vec<usize>,
    /// Scratch entries written by `run`'s reset paths since
    /// construction — the observable the epoch-clear regression test
    /// pins to prove reset cost does not scale with topology size.
    reset_writes: u64,
}

impl<'c> Engine<'c> {
    /// An engine with the default [`LinkModel::Fifo`] contention model.
    pub fn new(cluster: &'c Cluster) -> Engine<'c> {
        Engine::with_model(cluster, LinkModel::Fifo)
    }

    /// An engine resolving link contention with an explicit model.
    pub fn with_model(cluster: &'c Cluster, model: LinkModel) -> Engine<'c> {
        Engine {
            cluster,
            model,
            generation: cluster.routes().generation(),
            link_free: vec![0; cluster.n_links()],
            dev_free: vec![0; cluster.n_devices()],
            link_epoch: vec![0; cluster.n_links()],
            dev_epoch: vec![0; cluster.n_devices()],
            epoch: 0,
            indegree: Vec::new(),
            ready_time: Vec::new(),
            dep_offsets: Vec::new(),
            dep_targets: Vec::new(),
            cursor: Vec::new(),
            start: Vec::new(),
            done: Vec::new(),
            ready: ReadyQueue::new(),
            batch: Vec::new(),
            fs: FairShareScratch::new(cluster.n_links()),
            faults: None,
            faults_active: false,
            bw_factor: Vec::new(),
            dev_factor: Vec::new(),
            link_fault_events: Vec::new(),
            retry_left: Vec::new(),
            retry_route: Vec::new(),
            retry_remaining: Vec::new(),
            retry_pending: Vec::new(),
            retry_timeout_ns: 0,
            scales_stale: false,
            touched_links: Vec::new(),
            touched_devs: Vec::new(),
            reset_writes: 0,
        }
    }

    /// Scratch entries the engine's per-run reset paths have written
    /// since construction. Healthy runs write none (the epoch-stamp
    /// clear is O(1)); faulted runs write one entry per fault-touched
    /// link/device — never O(n_links). The epoch-clear regression test
    /// asserts this count is independent of topology size.
    pub fn scratch_reset_writes(&self) -> u64 {
        self.reset_writes
    }

    /// Install (or clear) a fault schedule for subsequent runs. An empty
    /// schedule behaves exactly like `None`: the engine's fault branches
    /// are gated on non-emptiness, so healthy execution stays
    /// bit-identical to an engine that never saw this call.
    pub fn set_faults(&mut self, faults: Option<FaultSchedule>) {
        self.faults = faults;
    }

    /// The installed fault schedule, if any.
    pub fn faults(&self) -> Option<&FaultSchedule> {
        self.faults.as_ref()
    }

    pub fn cluster(&self) -> &Cluster {
        self.cluster
    }

    /// The contention model this engine runs.
    pub fn link_model(&self) -> LinkModel {
        self.model
    }

    /// Force (or un-force) the fair-share solver's full-recompute
    /// reference mode, overriding the `FAIRSHARE_FULL_RECOMPUTE`
    /// environment default — the `engine_events` benches measure both
    /// modes in one process to report the incremental speedup.
    pub fn set_full_recompute(&mut self, on: bool) {
        self.fs.set_full_recompute(on);
    }

    /// `(incremental, full)` fair-share rate-solve counts since this
    /// engine was built — lets tests and benches confirm which solver
    /// path actually ran.
    pub fn fairshare_solve_counts(&self) -> (u64, u64) {
        self.fs.solve_counts()
    }

    /// Execute a plan starting at virtual time 0, returning per-op
    /// timestamps.
    pub fn execute(&mut self, plan: &Plan) -> ExecResult {
        let makespan = self.run(plan, true, None);
        ExecResult {
            start: self.start.clone(),
            done: self.done.clone(),
            makespan,
        }
    }

    /// [`Engine::execute`], additionally recording a [`FlowEvent`] every
    /// time a fair-share flow's max-min rate changes (admission,
    /// contention shifts, departures). Under [`LinkModel::Fifo`] there
    /// are no flows and the event list comes back empty.
    pub fn execute_with_flow_trace(&mut self, plan: &Plan) -> (ExecResult, Vec<FlowEvent>) {
        let mut events = Vec::new();
        let makespan = self.run(plan, true, Some(&mut events));
        (
            ExecResult {
                start: self.start.clone(),
                done: self.done.clone(),
                makespan,
            },
            events,
        )
    }

    /// Execute a plan and return only its makespan — the sweep hot path.
    /// Skips per-op timestamp bookkeeping and performs no allocations
    /// beyond scratch growth on the first (largest) plan.
    pub fn makespan_ns(&mut self, plan: &Plan) -> SimTime {
        self.run(plan, false, None)
    }

    fn run(
        &mut self,
        plan: &Plan,
        record: bool,
        flow_trace: Option<&mut Vec<FlowEvent>>,
    ) -> SimTime {
        debug_assert_eq!(
            self.generation,
            self.cluster.routes().generation(),
            "engine scratch desynced: topology changed since Engine::new"
        );
        debug_assert_eq!(
            self.link_free.len(),
            self.cluster.n_links(),
            "engine link scratch sized for a different topology"
        );
        debug_assert_eq!(
            self.dev_free.len(),
            self.cluster.n_devices(),
            "engine device scratch sized for a different topology"
        );
        // static verification before any simulated time is spent: debug
        // builds prove structure/route invariants on every plan entering
        // the engine (no-op in release; opt out with GDRBCAST_VERIFY=0)
        crate::analysis::debug_verify_plan(self.cluster, plan, "Engine::run");
        // O(1) scratch clear: bump the epoch so every link/device
        // free-time stamp goes stale (`lf`/`df` read stale entries as
        // 0). The stamp arrays are re-zeroed only when the u32 epoch
        // wraps — once per ~4 billion runs.
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.link_epoch.iter_mut().for_each(|e| *e = 0);
            self.dev_epoch.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }

        // fault overlay: reset stale state from a previous faulted run
        // (the fair-share solver reads `bw_scale` unconditionally, so a
        // healthy run after a faulted one must see all-ones again), then
        // install the current schedule's events/stragglers/retry budget.
        // Only the entries the previous schedule actually touched are
        // restored — O(touched), not O(n_links).
        if self.scales_stale {
            self.reset_writes += self.fs.reset_scales() as u64;
            for &l in &self.touched_links {
                self.bw_factor[l] = 1.0;
                self.link_fault_events[l].clear();
                self.reset_writes += 1;
            }
            for &d in &self.touched_devs {
                self.dev_factor[d] = 1.0;
                self.reset_writes += 1;
            }
            self.touched_links.clear();
            self.touched_devs.clear();
            self.scales_stale = false;
        }
        let n = plan.len();
        self.faults_active = self.faults.as_ref().is_some_and(|f| !f.is_empty());
        if self.faults_active {
            self.scales_stale = true;
            self.bw_factor.resize(self.cluster.n_links(), 1.0);
            self.dev_factor.resize(self.cluster.n_devices(), 1.0);
            self.link_fault_events
                .resize(self.cluster.n_links(), Vec::new());
            let sched = self.faults.clone().expect("faults_active");
            for ev in &sched.link_events {
                if ev.link.0 < self.link_fault_events.len() {
                    self.link_fault_events[ev.link.0].push((ev.at_ns, ev.bw_factor));
                    self.touched_links.push(ev.link.0);
                }
            }
            for &(rank, f) in &sched.stragglers {
                if rank < self.cluster.n_gpus() {
                    let dev = self.cluster.rank_device(rank).0;
                    self.dev_factor[dev] = f;
                    self.touched_devs.push(dev);
                }
            }
            self.retry_timeout_ns = sched.retry_timeout_ns;
            self.retry_left.clear();
            self.retry_left.resize(n, sched.retry_budget);
            self.retry_route.clear();
            self.retry_route.resize(n, None);
            self.retry_remaining.clear();
            self.retry_remaining.resize(n, 0.0);
            self.retry_pending.clear();
            self.retry_pending.resize(n, false);
        }
        // CSR reverse-dependency graph: dep_offsets[d]..dep_offsets[d+1]
        // indexes dep_targets with the ops depending on d
        self.indegree.clear();
        self.indegree.resize(n, 0);
        self.dep_offsets.clear();
        self.dep_offsets.resize(n + 1, 0);
        for deps in plan.deps.iter() {
            for &d in deps.as_slice() {
                self.dep_offsets[d + 1] += 1;
            }
        }
        for i in 0..n {
            self.dep_offsets[i + 1] += self.dep_offsets[i];
        }
        let total_deps = self.dep_offsets[n] as usize;
        self.dep_targets.clear();
        self.dep_targets.resize(total_deps, 0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.dep_offsets[..n]);
        for (id, deps) in plan.deps.iter().enumerate() {
            self.indegree[id] = deps.len() as u32;
            for &d in deps.as_slice() {
                self.dep_targets[self.cursor[d] as usize] = id;
                self.cursor[d] += 1;
            }
        }

        self.ready_time.clear();
        self.ready_time.resize(n, 0);
        if record {
            self.start.clear();
            self.start.resize(n, 0);
            self.done.clear();
            self.done.resize(n, 0);
        }
        // (ready, id) min-queue over monotone ready times
        self.ready.clear();
        for id in 0..n {
            if self.indegree[id] == 0 {
                self.ready.push(0, id);
            }
        }

        let (processed, makespan) = match self.model {
            LinkModel::Fifo => self.run_fifo(plan, record),
            LinkModel::FairShare => self.run_fairshare(plan, record, flow_trace),
        };
        assert_eq!(
            processed, n,
            "plan has a dependency cycle ({processed}/{n} ops ran)"
        );

        makespan
    }

    /// The FIFO list-scheduling loop: the queue is drained one
    /// same-instant batch at a time; every op resolves its
    /// start/completion immediately against the link/device free times,
    /// and a zero-duration op's same-instant dependents splice into the
    /// batch's undrained tail (id order), reproducing the one-at-a-time
    /// pop order exactly.
    fn run_fifo(&mut self, plan: &Plan, record: bool) -> (usize, SimTime) {
        let mut processed = 0usize;
        let mut makespan: SimTime = 0;
        let mut batch = std::mem::take(&mut self.batch);
        while let Some(t) = self.ready.pop_ready_batch(&mut batch) {
            let mut i = 0;
            while i < batch.len() {
                let id = batch[i];
                i += 1;
                processed += 1;
                let (s, d) = self.run_op(plan, id, t);
                if record {
                    self.start[id] = s;
                    self.done[id] = d;
                }
                makespan = makespan.max(d);
                self.release_dependents_batched(id, d, t, &mut batch, i);
            }
        }
        self.batch = batch;
        (processed, makespan)
    }

    /// The fair-share event loop: multi-hop transfers become *flows* that
    /// progressively fill their links; max-min rates are recomputed on
    /// every flow arrival/departure, and the clock advances event to
    /// event (earliest pending arrival vs earliest predicted departure).
    /// Delays and local copies resolve immediately at their arrival —
    /// their device serialization is rate-independent. See DESIGN.md
    /// §Contention models for the event-rate-recompute algorithm.
    fn run_fairshare(
        &mut self,
        plan: &Plan,
        record: bool,
        mut flow_trace: Option<&mut Vec<FlowEvent>>,
    ) -> (usize, SimTime) {
        /// A flow is drained when this close to zero bytes remain —
        /// covers the float noise of `remaining -= rate · dt` round
        /// trips (payloads are integer bytes, so sub-milli-byte residue
        /// is never a real byte).
        const DRAIN_EPS: f64 = 1e-3;
        debug_assert!(
            self.fs.sized_for(self.cluster.n_links()),
            "fair-share scratch sized for a different topology"
        );
        let unreachable = UNREACHABLE_NS as f64;
        let cluster = self.cluster;
        let mut processed = 0usize;
        let mut makespan: SimTime = 0;
        let mut now: f64 = 0.0;
        let mut dirty = false; // active set changed since the last rate pass
        // Highest integer ready time admitted so far. At sentinel
        // magnitudes (~2^62 ns) one f64 ulp is ~1024 ns, so `now` can sit
        // *below* an admitted op's exact u64 ready time; retire instants
        // clamp up to this so released dependents never push below an
        // already-popped time (the ready queue's monotone invariant).
        // Exact at normal scales, where `now.round() >= last_admit`
        // always holds and the clamp is a no-op.
        let mut last_admit: SimTime = 0;
        self.fs.reset();
        // fault overlay: the schedule's event list drives a cursor that
        // joins the event race below (clone: the borrow would otherwise
        // pin `self` for the whole loop; fault runs are not the hot path)
        let faults_active = self.faults_active;
        let fault_events: Vec<LinkEvent> = if faults_active {
            self.faults
                .as_ref()
                .expect("faults_active without a schedule")
                .link_events
                .clone()
        } else {
            Vec::new()
        };
        let mut fcur = 0usize;
        let mut batch = std::mem::take(&mut self.batch);
        loop {
            // 0) apply fault events due at the current instant: a
            //    degraded link re-seeds the incremental max-min solve
            //    with its new capacity; a failed link drops its
            //    in-flight flows back to the ready set (timed detour
            //    retries) or completes them at the sentinel when no
            //    route survives the budget
            if faults_active {
                let mut applied = false;
                while fcur < fault_events.len()
                    && (fault_events[fcur].at_ns as f64) <= now
                {
                    let ev = fault_events[fcur];
                    fcur += 1;
                    self.bw_factor[ev.link.0] = ev.bw_factor;
                    self.fs.scale_link(ev.link, ev.bw_factor);
                    applied = true;
                }
                if applied {
                    dirty = true;
                    let e_now = (now.round() as SimTime).max(last_admit);
                    let mut i = 0;
                    while i < self.fs.flows.len() {
                        let dead = {
                            let hops = cluster.route_hops(self.fs.flows[i].route);
                            hops.iter().any(|&h| {
                                cluster.link(h).bandwidth * self.bw_factor[h.0] <= 0.0
                            })
                        };
                        if !dead {
                            i += 1;
                            continue;
                        }
                        let f = self.fs.remove(cluster, i);
                        let id = f.op;
                        let meta = cluster.route_meta(f.route);
                        let mut detour = None;
                        let mut t_try = e_now;
                        while self.retry_left[id] > 0 {
                            self.retry_left[id] -= 1;
                            t_try = t_try.saturating_add(self.retry_timeout_ns);
                            if let Some(r2) = self.detour_route(meta.src, meta.dst, t_try)
                            {
                                detour = Some((r2, t_try));
                                break;
                            }
                        }
                        match detour {
                            Some((r2, t_re)) => {
                                self.retry_route[id] = Some(r2);
                                self.retry_remaining[id] = f.remaining.max(0.0);
                                self.retry_pending[id] = true;
                                self.ready.push(t_re, id);
                            }
                            None => {
                                let d = e_now
                                    .saturating_add(f.overhead_ns)
                                    .saturating_add(f.latency_ns)
                                    .saturating_add(UNREACHABLE_NS);
                                if record {
                                    self.done[id] = d;
                                }
                                makespan = makespan.max(d);
                                self.release_dependents(id, d);
                            }
                        }
                    }
                }
            }
            // 1) admit every op due at the current instant, one
            //    same-ready-time batch at a time
            loop {
                match self.ready.peek() {
                    Some((t, _)) if (t as f64) <= now => {}
                    _ => break,
                }
                let t = self
                    .ready
                    .pop_ready_batch(&mut batch)
                    .expect("peeked entry vanished");
                last_admit = last_admit.max(t);
                let mut i = 0;
                while i < batch.len() {
                    let id = batch[i];
                    i += 1;
                    // re-admission of a killed flow on its detour: the op
                    // was already counted at first admission
                    let is_retry = faults_active && self.retry_pending[id];
                    if is_retry {
                        self.retry_pending[id] = false;
                    } else {
                        processed += 1;
                    }
                    let joins = match plan.ends[id] {
                        OpEnd::Route(route) => {
                            let meta = cluster.route_meta(route);
                            if meta.hop_len == 0 {
                                None // local copy: resolves like a Delay below
                            } else {
                                Some((route, meta.latency_ns))
                            }
                        }
                        OpEnd::Dev(_) => None,
                    };
                    match joins {
                        Some((route, latency_ns)) => {
                            // fault overlay: a retried op runs on its
                            // detour with the undrained remainder, a
                            // straggler source scales the overhead, and a
                            // route already dead at admission goes
                            // straight to detour retry or the sentinel
                            let (route, latency_ns, remaining, overhead_ns) =
                                if faults_active {
                                    let (r, lat) = match self.retry_route[id] {
                                        Some(r2) => (r2, cluster.route_meta(r2).latency_ns),
                                        None => (route, latency_ns),
                                    };
                                    let rem = if is_retry {
                                        self.retry_remaining[id]
                                    } else {
                                        plan.bytes[id] as f64
                                    };
                                    let meta_r = cluster.route_meta(r);
                                    let oh = self.scale_dur(plan.overheads[id], meta_r.src.0);
                                    let dead = {
                                        let hops = cluster.route_hops(r);
                                        hops.iter().any(|&h| {
                                            cluster.link(h).bandwidth * self.bw_factor[h.0]
                                                <= 0.0
                                        })
                                    };
                                    if dead {
                                        if record && !is_retry {
                                            self.start[id] = t;
                                        }
                                        let mut detour = None;
                                        let mut t_try = t;
                                        while self.retry_left[id] > 0 {
                                            self.retry_left[id] -= 1;
                                            t_try = t_try.saturating_add(self.retry_timeout_ns);
                                            if let Some(r2) = self.detour_route(
                                                meta_r.src, meta_r.dst, t_try,
                                            ) {
                                                detour = Some((r2, t_try));
                                                break;
                                            }
                                        }
                                        match detour {
                                            Some((r2, t_re)) => {
                                                self.retry_route[id] = Some(r2);
                                                self.retry_remaining[id] = rem;
                                                self.retry_pending[id] = true;
                                                self.ready.push(t_re, id);
                                            }
                                            None => {
                                                let d = t
                                                    .saturating_add(oh)
                                                    .saturating_add(meta_r.latency_ns)
                                                    .saturating_add(UNREACHABLE_NS);
                                                if record {
                                                    self.done[id] = d;
                                                }
                                                makespan = makespan.max(d);
                                                self.release_dependents(id, d);
                                            }
                                        }
                                        continue;
                                    }
                                    (r, lat, rem, oh)
                                } else {
                                    (route, latency_ns, plan.bytes[id] as f64, plan.overheads[id])
                                };
                            if record && !is_retry {
                                self.start[id] = t;
                            }
                            self.fs.add(
                                cluster,
                                Flow {
                                    op: id,
                                    route,
                                    remaining,
                                    rate: 0.0,
                                    cap: plan.bw_caps[id],
                                    fixed: false,
                                    fin: 0.0,
                                    last_rate: -1.0,
                                    overhead_ns,
                                    latency_ns,
                                },
                            );
                            dirty = true;
                        }
                        None => {
                            let (s, d) = self.run_op(plan, id, t);
                            if record {
                                self.start[id] = s;
                                self.done[id] = d;
                            }
                            makespan = makespan.max(d);
                            self.release_dependents_batched(id, d, t, &mut batch, i);
                        }
                    }
                }
            }
            // 2) re-level the allocation if the active set changed
            if dirty {
                self.fs.recompute_rates(cluster);
                dirty = false;
                if let Some(events) = flow_trace.as_deref_mut() {
                    let t_ns = (now.round() as SimTime).max(last_admit);
                    for f in self.fs.flows.iter_mut() {
                        if f.rate != f.last_rate {
                            events.push(FlowEvent {
                                t_ns,
                                op: f.op,
                                rate: f.rate,
                            });
                            f.last_rate = f.rate;
                        }
                    }
                }
            }
            // 3) the next event: earliest pending arrival vs earliest
            //    predicted flow departure under the current rates
            let t_arr = match self.ready.peek() {
                Some((t, _)) => t as f64,
                None => f64::INFINITY,
            };
            let mut t_dep = f64::INFINITY;
            for f in self.fs.flows.iter_mut() {
                f.fin = if f.remaining <= DRAIN_EPS || f.rate.is_infinite() {
                    now
                } else if f.rate > 0.0 {
                    now + f.remaining / f.rate * 1.0e9
                } else {
                    f64::INFINITY // starved: a zero-bandwidth link
                };
                t_dep = t_dep.min(f.fin);
            }
            let t_fault = if faults_active && fcur < fault_events.len() {
                fault_events[fcur].at_ns as f64
            } else {
                f64::INFINITY
            };
            let t_next = t_arr.min(t_dep).min(t_fault);
            if t_next.is_infinite() {
                if self.fs.flows.is_empty() {
                    break; // everything drained
                }
                // every remaining flow is starved and nothing further
                // arrives: complete them at the unreachable sentinel,
                // mirroring `tx_ns` on a dead link (never rewinding the
                // clock — a chain of sentinel completions can already
                // have pushed it past the sentinel itself)
                now = now.max(unreachable);
                for f in self.fs.flows.iter_mut() {
                    f.remaining = 0.0;
                    f.fin = now;
                }
            } else {
                // 4) drain the interval at the current rates. (No clamp:
                // ops scheduled after an unreachable completion live at
                // sentinel-plus timestamps, and the clock must reach
                // them — u64 headroom is what the sentinel's MAX/4
                // margin and the saturating adds are for.)
                let dt_s = ((t_next - now) / 1.0e9).max(0.0);
                if dt_s > 0.0 {
                    for f in self.fs.flows.iter_mut() {
                        if f.rate.is_finite() {
                            f.remaining -= f.rate * dt_s;
                        }
                    }
                }
                now = t_next;
            }
            // 5) retire every flow that drained — or whose predicted
            //    finish *is* this instant: at a huge `now` the interval
            //    to the finish can round below one ulp, so the drain
            //    above could never zero it out
            let mut i = 0;
            while i < self.fs.flows.len() {
                if self.fs.flows[i].remaining <= DRAIN_EPS || self.fs.flows[i].fin <= now {
                    let f = self.fs.remove(cluster, i);
                    let e = (now.round() as SimTime).max(last_admit);
                    let d = e.saturating_add(f.overhead_ns).saturating_add(f.latency_ns);
                    if record {
                        self.done[f.op] = d;
                    }
                    makespan = makespan.max(d);
                    self.release_dependents(f.op, d);
                    dirty = true;
                } else {
                    i += 1;
                }
            }
        }
        self.batch = batch;
        (processed, makespan)
    }

    /// Release `id`'s dependents at completion time `d`: each dependent's
    /// ready time folds in `d`, and dependents whose indegree hits zero
    /// enqueue at their final ready time.
    fn release_dependents(&mut self, id: OpId, d: SimTime) {
        let lo = self.dep_offsets[id] as usize;
        let hi = self.dep_offsets[id + 1] as usize;
        for i in lo..hi {
            let dep = self.dep_targets[i];
            self.ready_time[dep] = self.ready_time[dep].max(d);
            self.indegree[dep] -= 1;
            if self.indegree[dep] == 0 {
                self.ready.push(self.ready_time[dep], dep);
            }
        }
    }

    /// [`Engine::release_dependents`] from inside a same-instant batch: a
    /// dependent whose final ready time *is* the batch instant (released
    /// by a zero-duration parent) splices into the batch's undrained
    /// tail in id order — exactly where a one-at-a-time pop loop would
    /// have dequeued it — instead of round-tripping through the queue.
    /// (A dependent's id always exceeds its parent's, and the tail is
    /// sorted ascending, so the splice preserves `(t, id)` pop order.)
    /// Later ready times go through the queue as usual.
    fn release_dependents_batched(
        &mut self,
        id: OpId,
        d: SimTime,
        batch_t: SimTime,
        batch: &mut Vec<OpId>,
        cursor: usize,
    ) {
        let lo = self.dep_offsets[id] as usize;
        let hi = self.dep_offsets[id + 1] as usize;
        for i in lo..hi {
            let dep = self.dep_targets[i];
            self.ready_time[dep] = self.ready_time[dep].max(d);
            self.indegree[dep] -= 1;
            if self.indegree[dep] == 0 {
                let rt = self.ready_time[dep];
                if rt == batch_t {
                    let at = cursor + batch[cursor..].partition_point(|&e| e < dep);
                    batch.insert(at, dep);
                } else {
                    self.ready.push(rt, dep);
                }
            }
        }
    }

    /// Link `i`'s earliest-free time this run: the stored value when its
    /// stamp matches the current epoch, else 0 (untouched this run).
    #[inline]
    fn lf(&self, i: usize) -> SimTime {
        if self.link_epoch[i] == self.epoch {
            self.link_free[i]
        } else {
            0
        }
    }

    #[inline]
    fn set_lf(&mut self, i: usize, t: SimTime) {
        self.link_epoch[i] = self.epoch;
        self.link_free[i] = t;
    }

    /// Device `i`'s earliest-free time this run (see [`Engine::lf`]).
    #[inline]
    fn df(&self, i: usize) -> SimTime {
        if self.dev_epoch[i] == self.epoch {
            self.dev_free[i]
        } else {
            0
        }
    }

    #[inline]
    fn set_df(&mut self, i: usize, t: SimTime) {
        self.dev_epoch[i] = self.epoch;
        self.dev_free[i] = t;
    }

    /// Run op `id` at its ready time, streaming the plan's columns;
    /// returns (actual start, completion).
    fn run_op(&mut self, plan: &Plan, id: OpId, ready: SimTime) -> (SimTime, SimTime) {
        if self.faults_active {
            return self.run_op_faulty(plan, id, ready);
        }
        match plan.ends[id] {
            OpEnd::Dev(dev) => {
                // a Delay: its duration lives in the overheads column
                let s = ready.max(self.df(dev.0));
                let d = s + plan.overheads[id];
                self.set_df(dev.0, d);
                (s, d)
            }
            OpEnd::Route(route) => {
                let cluster = self.cluster;
                let meta = cluster.route_meta(route);
                let bytes = plan.bytes[id];
                let overhead_ns = plan.overheads[id];
                let issue_ns = plan.issues[id];
                // INFINITY = uncapped; `min` with it is exact identity
                let cap = plan.bw_caps[id];
                if meta.hop_len == 0 {
                    // local (same-device) copy: costs its overhead and
                    // serialises on the device like `Delay` does. (It
                    // used to ignore `issue_ns` and `dev_free` entirely,
                    // letting unlimited local copies on one GPU complete
                    // concurrently for free.) The device stays busy for
                    // the larger of the issue and overhead costs, so
                    // zero-issue copies still occupy it for their
                    // duration.
                    let dev = meta.src;
                    let s = ready.max(self.df(dev.0));
                    let d = s.saturating_add(overhead_ns);
                    self.set_df(dev.0, s.saturating_add(overhead_ns.max(issue_ns)));
                    return (s, d);
                }
                let hops = cluster.route_hops(route);
                // start after every link on the path is free (cut-through:
                // the message occupies the whole path simultaneously)
                let mut s = ready;
                for &h in hops.iter() {
                    s = s.max(self.lf(h.0));
                }
                let eff_bw = meta.bottleneck_bw.min(cap);
                // saturating sums: `tx_ns` reports a dead link as the
                // UNREACHABLE_NS sentinel, which plain `+` would overflow
                let tx = tx_ns(bytes, eff_bw);
                // Each link is busy for the transfer's *issue* cost plus
                // its own transmission time. MPI sends set issue == t_s,
                // which makes back-to-back chunks on one link cost
                // (t_s + C/B) each — the pipelining model of the paper's
                // Eq. (5).
                for &h in hops.iter() {
                    let link_bw = cluster.link(h).bandwidth.min(cap);
                    let busy = s.saturating_add(issue_ns).saturating_add(tx_ns(bytes, link_bw));
                    self.set_lf(h.0, busy);
                }
                let d = s
                    .saturating_add(overhead_ns)
                    .saturating_add(meta.latency_ns)
                    .saturating_add(tx);
                (s, d)
            }
        }
    }

    /// [`Engine::run_op`] under an active fault schedule: durations on a
    /// straggler's device are stretched by its multiplier, transfers see
    /// the per-link bandwidth factors in effect at their start instant,
    /// and a transfer whose route is dead retries over detours within
    /// the budget before completing at the sentinel.
    fn run_op_faulty(&mut self, plan: &Plan, id: OpId, ready: SimTime) -> (SimTime, SimTime) {
        match plan.ends[id] {
            OpEnd::Dev(dev) => {
                let s = ready.max(self.df(dev.0));
                let d = s.saturating_add(self.scale_dur(plan.overheads[id], dev.0));
                self.set_df(dev.0, d);
                (s, d)
            }
            OpEnd::Route(route) => {
                let meta = self.cluster.route_meta(route);
                if meta.hop_len == 0 {
                    let dev = meta.src;
                    let overhead_ns = self.scale_dur(plan.overheads[id], dev.0);
                    let issue_ns = self.scale_dur(plan.issues[id], dev.0);
                    let s = ready.max(self.df(dev.0));
                    let d = s.saturating_add(overhead_ns);
                    self.set_df(dev.0, s.saturating_add(overhead_ns.max(issue_ns)));
                    return (s, d);
                }
                self.fifo_transfer_faulty(plan, id, route, ready)
            }
        }
    }

    /// One FIFO transfer attempt on `route` starting no earlier than
    /// `ready`. The per-hop bandwidth factor is resolved once at the
    /// start instant (cut-through occupancy is atomic in this model —
    /// mid-transfer re-rating belongs to the fair-share loop). A route
    /// dead at its start recurses onto a detour, consuming retry budget
    /// per attempt, and completes at the sentinel when the budget runs
    /// dry with no live route.
    fn fifo_transfer_faulty(
        &mut self,
        plan: &Plan,
        id: OpId,
        route: RouteId,
        ready: SimTime,
    ) -> (SimTime, SimTime) {
        let cluster = self.cluster;
        let meta = cluster.route_meta(route);
        let bytes = plan.bytes[id];
        let overhead_ns = self.scale_dur(plan.overheads[id], meta.src.0);
        let issue_ns = self.scale_dur(plan.issues[id], meta.src.0);
        let cap = plan.bw_caps[id];
        let mut s = ready;
        let mut bottleneck = f64::INFINITY;
        {
            let hops = cluster.route_hops(route);
            for &h in hops.iter() {
                s = s.max(self.lf(h.0));
            }
            for &h in hops.iter() {
                bottleneck =
                    bottleneck.min(cluster.link(h).bandwidth * self.factor_at(h.0, s));
            }
        }
        if bottleneck <= 0.0 {
            let mut t_try = s;
            while self.retry_left[id] > 0 {
                self.retry_left[id] -= 1;
                t_try = t_try.saturating_add(self.retry_timeout_ns);
                if let Some(r2) = self.detour_route(meta.src, meta.dst, t_try) {
                    return self.fifo_transfer_faulty(plan, id, r2, t_try);
                }
            }
            // no surviving route: `tx_ns` on a dead link is the sentinel,
            // matching the healthy engine's dead-link completion shape
            let d = s
                .saturating_add(overhead_ns)
                .saturating_add(meta.latency_ns)
                .saturating_add(tx_ns(bytes, 0.0));
            return (s, d);
        }
        let tx = tx_ns(bytes, bottleneck.min(cap));
        {
            let hops = cluster.route_hops(route);
            for &h in hops.iter() {
                let link_bw = (cluster.link(h).bandwidth * self.factor_at(h.0, s)).min(cap);
                let busy = tx_ns(bytes, link_bw);
                self.set_lf(h.0, s.saturating_add(issue_ns).saturating_add(busy));
            }
        }
        let d = s
            .saturating_add(overhead_ns)
            .saturating_add(meta.latency_ns)
            .saturating_add(tx);
        (s, d)
    }

    /// Bandwidth factor in effect on link index `link` at instant `t`:
    /// the latest scheduled event at or before `t`, else 1.0 (healthy).
    fn factor_at(&self, link: usize, t: SimTime) -> f64 {
        let evs = &self.link_fault_events[link];
        let k = evs.partition_point(|&(at, _)| at <= t);
        if k == 0 {
            1.0
        } else {
            evs[k - 1].1
        }
    }

    /// Straggler stretch: duration `ns` scaled by the device's fault
    /// multiplier. Exactly `ns` for the 1.0 (healthy) factor.
    fn scale_dur(&self, ns: SimTime, dev: usize) -> SimTime {
        let f = self.dev_factor.get(dev).copied().unwrap_or(1.0);
        if f == 1.0 {
            ns
        } else {
            ((ns as f64 * f).round()).min(UNREACHABLE_NS as f64) as SimTime
        }
    }

    /// Deterministic detour selection at instant `t`: the first staging
    /// candidate (Host and IB HCA devices, in device-id order) whose
    /// src→via→dst route is non-trivial and fully live under the fault
    /// schedule. Public so tests can reconstruct which route a retried
    /// transfer actually ran on.
    pub fn detour_route(
        &self,
        src: DeviceId,
        dst: DeviceId,
        t: SimTime,
    ) -> Option<RouteId> {
        for (i, d) in self.cluster.devices().iter().enumerate() {
            if !matches!(d.kind, DeviceKind::Host | DeviceKind::IbHca) {
                continue;
            }
            let via = DeviceId(i);
            if via == src || via == dst {
                continue;
            }
            let Ok(r) = self.cluster.route_via(src, via, dst) else {
                continue;
            };
            if self.cluster.route_meta(r).hop_len == 0 {
                continue;
            }
            let alive = {
                let hops = self.cluster.route_hops(r);
                hops.iter().all(|&h| {
                    self.cluster.link(h).bandwidth * self.factor_at(h.0, t) > 0.0
                })
            };
            if alive {
                return Some(r);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::transfer::{Deps, Plan, SimOp};
    use crate::topology::presets::flat;

    fn transfer_plan(cluster: &Cluster, pairs: &[(usize, usize, u64)]) -> Plan {
        let mut plan = Plan::new();
        for &(src, dst, bytes) in pairs {
            let route = cluster
                .route(cluster.rank_device(src), cluster.rank_device(dst))
                .unwrap();
            plan.push(
                SimOp::Transfer {
                    route,
                    bytes,
                    overhead_ns: 1000,
                    issue_ns: 1000,
                    bw_cap: None,
                },
                Deps::none(),
                Some((dst, 0)),
            );
        }
        plan
    }

    #[test]
    fn single_transfer_cost() {
        let c = flat(2).unwrap();
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000)]);
        let r = e.execute(&plan);
        // 10 MB over 10 GB/s = 1 ms, + 1 µs overhead, 0 latency
        assert_eq!(r.makespan, 1_000_000 + 1000);
    }

    #[test]
    fn independent_transfers_overlap() {
        let c = flat(4).unwrap();
        let mut e = Engine::new(&c);
        // 0->1 and 2->3 share no links
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (2, 3, 10_000_000)]);
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 1_001_000);
    }

    #[test]
    fn shared_source_link_serialises() {
        let c = flat(3).unwrap();
        let mut e = Engine::new(&c);
        // 0->1 and 0->2 share the 0->xbar uplink
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (0, 2, 10_000_000)]);
        let r = e.execute(&plan);
        // second transfer waits for the first's t_s + transmission
        // (1µs + 1ms), then pays its own t_s + 1ms
        assert_eq!(r.makespan, 2 * (1_000_000 + 1000));
    }

    #[test]
    fn deps_respected() {
        let c = flat(3).unwrap();
        let mut e = Engine::new(&c);
        let mut plan = Plan::new();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r12 = c.route(c.rank_device(1), c.rank_device(2)).unwrap();
        let a = plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::none(),
            Some((1, 0)),
        );
        plan.push(
            SimOp::Transfer {
                route: r12,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::one(a),
            Some((2, 0)),
        );
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 2_000_000); // strictly sequential
        assert_eq!(r.start[1], 1_000_000);
    }

    #[test]
    fn bw_cap_applies() {
        let c = flat(2).unwrap();
        let mut e = Engine::new(&c);
        let route = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let mut plan = Plan::new();
        plan.push(
            SimOp::Transfer {
                route,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: Some(2.0e9),
            },
            Deps::none(),
            None,
        );
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 5_000_000); // 10MB at 2GB/s
    }

    #[test]
    fn delay_serialises_on_device() {
        let c = flat(1).unwrap();
        let mut e = Engine::new(&c);
        let mut plan = Plan::new();
        let dev = c.rank_device(0);
        plan.push(SimOp::Delay { dev, dur_ns: 500 }, Deps::none(), None);
        plan.push(SimOp::Delay { dev, dur_ns: 300 }, Deps::none(), None);
        let r = e.execute(&plan);
        assert_eq!(r.makespan, 800);
    }

    #[test]
    fn rank_completion_maps_labels() {
        let c = flat(3).unwrap();
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 1000), (0, 2, 1000)]);
        let r = e.execute(&plan);
        let rc = r.rank_completion(&plan, 3);
        assert_eq!(rc[0], 0);
        assert!(rc[1] > 0 && rc[2] > 0);
    }

    #[test]
    fn merged_schedules_keep_delivery_queries() {
        // regression: Plan::merge used to drop labels, so rank_completion
        // and delivery_time on a merged schedule returned empty/0
        let c = flat(3).unwrap();
        let mut e = Engine::new(&c);
        let a = transfer_plan(&c, &[(0, 1, 1000)]);
        let b = transfer_plan(&c, &[(0, 2, 1000)]);
        let mut merged = Plan::new();
        let ha = merged.merge(&a);
        let hb = merged.merge(&b);
        let r = e.execute(&merged);
        let t1 = r.delivery_time(&merged, 1, crate::netsim::ns_chunk(ha.namespace, 0));
        let t2 = r.delivery_time(&merged, 2, crate::netsim::ns_chunk(hb.namespace, 0));
        assert!(t1.is_some() && t2.is_some());
        let rc = r.rank_completion(&merged, 3);
        assert_eq!(rc[1], t1.unwrap());
        assert_eq!(rc[2], t2.unwrap());
        assert_eq!(rc[0], 0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        // construct a cyclic plan by hand (bypassing push's debug_assert)
        let c = flat(2).unwrap();
        let mut plan = Plan::new();
        plan.push(
            SimOp::Delay {
                dev: c.rank_device(0),
                dur_ns: 1,
            },
            Deps::none(),
            None,
        );
        plan.deps[0] = Deps::one(0);
        let mut e = Engine::new(&c);
        e.execute(&plan);
    }

    #[test]
    fn engine_reuse_resets_state() {
        let c = flat(2).unwrap();
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000)]);
        let first = e.execute(&plan).makespan;
        let second = e.execute(&plan).makespan;
        assert_eq!(first, second);
    }

    fn dead_link_cluster() -> Cluster {
        use crate::topology::{DeviceKind, LinkKind, NodeId, NodeMeta};
        let mut c = Cluster::new("dead-link");
        let a = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "a".into());
        let b = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "b".into());
        let d = c.add_device(DeviceKind::Gpu, NodeId(0), 0, "d".into());
        c.connect_custom(a, b, LinkKind::Ideal, 0.0, 0);
        c.connect_custom(b, d, LinkKind::Ideal, 10.0e9, 0);
        c.push_node_meta(NodeMeta {
            id: NodeId(0),
            gpus: vec![a, b, d],
            hosts: vec![],
            hcas: vec![],
        });
        c
    }

    #[test]
    fn zero_bandwidth_link_saturates_instead_of_overflowing() {
        // regression: tx_ns on a dead link used to report u64::MAX and
        // the completion sum `s + overhead + latency + tx` overflowed
        use crate::netsim::time::UNREACHABLE_NS;
        let c = dead_link_cluster();
        for model in LinkModel::ALL {
            let mut e = Engine::with_model(&c, model);
            let mut plan = transfer_plan(&c, &[(0, 1, 1 << 20)]);
            // a dependent op after the unreachable transfer must not
            // overflow either
            plan.push(
                SimOp::Delay {
                    dev: c.rank_device(1),
                    dur_ns: 500,
                },
                Deps::one(0),
                None,
            );
            let r = e.execute(&plan);
            assert!(
                r.makespan >= UNREACHABLE_NS,
                "{}: dead link must report the unreachable sentinel",
                model.name()
            );
            assert!(
                r.makespan < SimTime::MAX / 2,
                "{}: sentinel arithmetic must stay far from wrapping",
                model.name()
            );
        }
    }

    #[test]
    fn transfers_chained_after_a_dead_link_stay_monotone() {
        // regression for the sentinel-magnitude clock: a *transfer* (not
        // just a Delay) scheduled after an unreachable completion lives
        // at ~2^62 ns, where one f64 ulp is ~1024 ns — its retire
        // instant must never round below its own admitted ready time,
        // or the released dependents would push non-monotonically into
        // the ready queue (debug builds assert on that)
        let c = dead_link_cluster();
        for model in LinkModel::ALL {
            let mut e = Engine::with_model(&c, model);
            let mut plan = Plan::new();
            let dead = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
            // overhead chosen so the first dependent's exact ready time
            // (sentinel + 1500) rounds DOWN through f64 (ulp ≈ 1024 at
            // 2^62): the admitted op's integer time sits above the f64
            // clock, the adversarial case for the retire-instant clamp
            let mut prev = plan.push(
                SimOp::Transfer {
                    route: dead,
                    bytes: 1 << 20,
                    overhead_ns: 1500,
                    issue_ns: 1500,
                    bw_cap: None,
                },
                Deps::none(),
                None,
            );
            let live = c.route(c.rank_device(1), c.rank_device(2)).unwrap();
            for _ in 0..4 {
                prev = plan.push(
                    SimOp::Transfer {
                        route: live,
                        bytes: 100, // 10 ns at 10 GB/s — far below one ulp
                        overhead_ns: 0,
                        issue_ns: 0,
                        bw_cap: None,
                    },
                    Deps::one(prev),
                    None,
                );
            }
            let r = e.execute(&plan);
            assert!(r.makespan >= crate::netsim::time::UNREACHABLE_NS, "{}", model.name());
            // completions stay ordered along the chain
            for w in 1..plan.len() - 1 {
                assert!(
                    r.done[w + 1] >= r.done[w],
                    "{}: chain completion went backwards at op {w}",
                    model.name()
                );
            }
        }
    }

    #[test]
    fn zero_hop_transfers_serialize_on_device() {
        // regression: same-device copies used to ignore issue_ns and
        // dev_free — unlimited local copies completed concurrently for
        // free; they must serialize on the device like `Delay` does
        let c = flat(2).unwrap();
        let dev = c.rank_device(0);
        let route = c.route(dev, dev).unwrap();
        for model in LinkModel::ALL {
            let mut e = Engine::with_model(&c, model);
            let mut plan = Plan::new();
            for _ in 0..3 {
                plan.push(
                    SimOp::Transfer {
                        route,
                        bytes: 4096,
                        overhead_ns: 1000,
                        issue_ns: 1000,
                        bw_cap: None,
                    },
                    Deps::none(),
                    None,
                );
            }
            let r = e.execute(&plan);
            assert_eq!(r.makespan, 3000, "{}", model.name());
            assert_eq!(r.start[1], 1000, "{}", model.name());
            assert_eq!(r.start[2], 2000, "{}", model.name());
            // and they contend with Delay ops for the same device
            let mut mixed = Plan::new();
            mixed.push(SimOp::Delay { dev, dur_ns: 700 }, Deps::none(), None);
            mixed.push(
                SimOp::Transfer {
                    route,
                    bytes: 4096,
                    overhead_ns: 1000,
                    issue_ns: 1000,
                    bw_cap: None,
                },
                Deps::none(),
                None,
            );
            let r = e.execute(&mixed);
            assert_eq!(r.start[1], 700, "{}", model.name());
            assert_eq!(r.makespan, 1700, "{}", model.name());
        }
    }

    #[test]
    fn fairshare_single_flow_matches_fifo() {
        // with no contention the two models agree: a lone flow's rate is
        // the route bottleneck, exactly what FIFO charges
        let c = flat(4).unwrap();
        let mut fifo = Engine::new(&c);
        let mut fair = Engine::with_model(&c, LinkModel::FairShare);
        for bytes in [1u64 << 10, 1 << 20, 10_000_000] {
            let plan = transfer_plan(&c, &[(0, 1, bytes)]);
            assert_eq!(
                fifo.execute(&plan).makespan,
                fair.execute(&plan).makespan,
                "single flow of {bytes}B diverged"
            );
        }
        // a dependent chain is a sequence of lone flows: still identical
        let mut plan = Plan::new();
        let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
        let r12 = c.route(c.rank_device(1), c.rank_device(2)).unwrap();
        let a = plan.push(
            SimOp::Transfer {
                route: r01,
                bytes: 10_000_000,
                overhead_ns: 1000,
                issue_ns: 1000,
                bw_cap: None,
            },
            Deps::none(),
            None,
        );
        plan.push(
            SimOp::Transfer {
                route: r12,
                bytes: 5_000_000,
                overhead_ns: 1000,
                issue_ns: 1000,
                bw_cap: None,
            },
            Deps::one(a),
            None,
        );
        assert_eq!(fifo.execute(&plan).makespan, fair.execute(&plan).makespan);
        // bw_cap binds the lone flow's rate exactly like FIFO's tx cap
        let mut capped = Plan::new();
        capped.push(
            SimOp::Transfer {
                route: r01,
                bytes: 10_000_000,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: Some(2.0e9),
            },
            Deps::none(),
            None,
        );
        assert_eq!(fifo.execute(&capped).makespan, 5_000_000);
        assert_eq!(fair.execute(&capped).makespan, 5_000_000);
    }

    #[test]
    fn fairshare_two_flows_share_the_uplink() {
        // the hand-computed closed form: 10 MB (0->1) and 5 MB (0->2)
        // share the 10 GB/s uplink. Progressive filling: both run at
        // 5 GB/s until the 5 MB flow drains at t = 1 ms; the survivor
        // then fills the link, draining its remaining 5 MB in 0.5 ms.
        let c = flat(3).unwrap();
        let mut fair = Engine::with_model(&c, LinkModel::FairShare);
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (0, 2, 5_000_000)]);
        let r = fair.execute(&plan);
        assert_eq!(r.done[1], 1_000_000 + 1000, "small flow: 1 ms + t_s");
        assert_eq!(r.done[0], 1_500_000 + 1000, "large flow: 1.5 ms + t_s");
        assert_eq!(r.makespan, 1_501_000);
        // FIFO serializes the same pair: 1.001 ms link occupancy, then
        // the second pays its own t_s + 0.5 ms
        let mut fifo = Engine::new(&c);
        assert_eq!(fifo.execute(&plan).makespan, 1_502_000);

        // equal flows: both drain together at 2 ms — one t_s cheaper
        // than FIFO's serialization
        let plan = transfer_plan(&c, &[(0, 1, 10_000_000), (0, 2, 10_000_000)]);
        assert_eq!(fair.execute(&plan).makespan, 2_001_000);
        assert_eq!(fifo.execute(&plan).makespan, 2_002_000);
    }

    #[test]
    fn fairshare_keeps_dag_semantics() {
        // deps, delays, labels and deliveries behave exactly as under
        // FIFO — only bandwidth sharing differs
        let c = flat(3).unwrap();
        let mut fair = Engine::with_model(&c, LinkModel::FairShare);
        // delays serialize on their device identically
        let mut delays = Plan::new();
        let dev = c.rank_device(0);
        delays.push(SimOp::Delay { dev, dur_ns: 500 }, Deps::none(), None);
        delays.push(SimOp::Delay { dev, dur_ns: 300 }, Deps::none(), None);
        assert_eq!(fair.execute(&delays).makespan, 800);
        // a dependent starts exactly at its parent's completion
        let plan = transfer_plan(&c, &[(0, 1, 1000), (0, 2, 1000)]);
        let r = fair.execute(&plan);
        let rc = r.rank_completion(&plan, 3);
        assert_eq!(rc[1], r.delivery_time(&plan, 1, 0).unwrap());
        assert_eq!(rc[2], r.delivery_time(&plan, 2, 0).unwrap());
        assert_eq!(rc[0], 0);
    }

    #[test]
    fn fairshare_engine_reuse_and_makespan_only_match() {
        let c = flat(4).unwrap();
        let mut e = Engine::with_model(&c, LinkModel::FairShare);
        assert_eq!(e.link_model(), LinkModel::FairShare);
        let plan = transfer_plan(
            &c,
            &[(0, 1, 10_000_000), (0, 2, 5_000_000), (2, 3, 1_000_000)],
        );
        let full = e.execute(&plan).makespan;
        let fast = e.makespan_ns(&plan);
        assert_eq!(full, fast);
        assert_eq!(e.execute(&plan).makespan, full);
    }

    #[test]
    fn makespan_only_path_matches_execute() {
        let c = flat(4).unwrap();
        let mut e = Engine::new(&c);
        let plan = transfer_plan(
            &c,
            &[(0, 1, 10_000_000), (0, 2, 5_000_000), (2, 3, 1_000_000)],
        );
        let full = e.execute(&plan).makespan;
        let fast = e.makespan_ns(&plan);
        assert_eq!(full, fast);
        // and interleaving the two paths keeps determinism
        assert_eq!(e.execute(&plan).makespan, full);
    }

    #[test]
    fn fairshare_full_recompute_mode_matches_incremental() {
        // the reference mode must agree on makespans (the incremental
        // solver is bit-identical, not just approximately right), and
        // disjoint per-pair contention must actually take the
        // incremental path
        let c = flat(8).unwrap();
        let pairs: Vec<(usize, usize, u64)> = (0..4)
            .map(|p| (2 * p, 2 * p + 1, 4_000_000 + (p as u64) * 1_000_000))
            .collect();
        // interleave a second wave on the same sources so arrivals and
        // departures ripple within each pair's component
        let mut plan = transfer_plan(&c, &pairs);
        for p in 0..4usize {
            let route = c
                .route(c.rank_device(2 * p), c.rank_device((2 * p + 3) % 8))
                .unwrap();
            plan.push(
                SimOp::Transfer {
                    route,
                    bytes: 2_000_000,
                    overhead_ns: 1000,
                    issue_ns: 1000,
                    bw_cap: None,
                },
                Deps::one(p),
                None,
            );
        }
        let mut inc = Engine::with_model(&c, LinkModel::FairShare);
        inc.set_full_recompute(false);
        let mut full = Engine::with_model(&c, LinkModel::FairShare);
        full.set_full_recompute(true);
        let a = inc.execute(&plan);
        let b = full.execute(&plan);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.done, b.done);
        let (i_inc, _) = inc.fairshare_solve_counts();
        assert!(i_inc > 0, "incremental path never engaged");
        let (f_inc, f_full) = full.fairshare_solve_counts();
        assert_eq!(f_inc, 0, "reference mode must always solve fully");
        assert!(f_full > 0);
    }

    #[test]
    fn flow_trace_is_empty_under_fifo() {
        let c = flat(3).unwrap();
        let mut e = Engine::new(&c);
        let plan = transfer_plan(&c, &[(0, 1, 1000), (0, 2, 1000)]);
        let (r, events) = e.execute_with_flow_trace(&plan);
        assert!(events.is_empty());
        assert_eq!(r.makespan, e.execute(&plan).makespan);
    }

    /// The per-run scratch clear must not scale with topology size: the
    /// epoch-stamp clear writes nothing on healthy runs, and the fault
    /// overlay reset writes one entry per fault-touched link/device —
    /// the same count on a 4-GPU and a 512-GPU fabric.
    #[test]
    fn scratch_clear_cost_independent_of_topology_size() {
        // healthy runs: zero reset writes at any size
        for n in [4usize, 512] {
            let c = flat(n).unwrap();
            let mut e = Engine::new(&c);
            let plan = transfer_plan(&c, &[(0, 1, 1_000_000)]);
            let m = e.execute(&plan).makespan;
            for _ in 0..3 {
                assert_eq!(e.execute(&plan).makespan, m, "engine reuse, n={n}");
            }
            assert_eq!(e.scratch_reset_writes(), 0, "healthy runs wrote scratch, n={n}");
        }
        // faulted runs: both resets (faulted→faulted and faulted→healthy)
        // restore exactly the touched entries, independent of n_links
        let mut writes = Vec::new();
        for n in [4usize, 512] {
            let c = flat(n).unwrap();
            let r01 = c.route(c.rank_device(0), c.rank_device(1)).unwrap();
            let hop = c.route_hops(r01)[0];
            let plan = transfer_plan(&c, &[(0, 1, 1_000_000)]);
            let mut e = Engine::new(&c);
            e.set_faults(Some(
                FaultSchedule::default()
                    .with_link_event(0, hop, 0.5)
                    .with_straggler(1, 2.0),
            ));
            let degraded = e.execute(&plan).makespan;
            assert_eq!(e.execute(&plan).makespan, degraded, "faulted reuse, n={n}");
            e.set_faults(None);
            let healthy = e.execute(&plan).makespan;
            assert!(healthy < degraded, "overlay not restored, n={n}");
            writes.push(e.scratch_reset_writes());
        }
        assert!(writes[0] > 0, "fault overlay resets must be counted");
        assert_eq!(writes[0], writes[1], "reset cost scaled with n_links: {writes:?}");
    }
}
