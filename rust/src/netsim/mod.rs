//! Deterministic discrete-event fabric simulator.
//!
//! The unit of work is a [`SimOp`]: either a cut-through `Transfer` of
//! `bytes` along an interned route (a [`crate::topology::RouteId`]
//! occupying every directed link on the path for the transmission time,
//! so contention falls out naturally), or a `Delay` on a device (used for
//! CUDA kernel launches, staging copies' fixed costs, compute phases).
//!
//! Ops are arranged into a dependency DAG — a [`Plan`] — by the collective
//! algorithms in [`crate::collectives`] and executed by the [`engine`],
//! which resolves link contention under a selectable [`LinkModel`] —
//! exclusive FIFO occupancy (the default) or progressive-filling max-min
//! fair sharing ([`fairshare`]) — and returns per-op start/completion
//! timestamps on a virtual nanosecond clock.
//!
//! The simulator is *deterministic*: same plan, same timings, every run.

pub mod engine;
pub mod fairshare;
pub mod faults;
pub mod queue;
pub mod time;
pub mod trace;
pub mod transfer;

pub use engine::{DegradedOutcome, Engine, ExecResult};
pub use fairshare::{maxmin_rates, LinkModel};
pub use faults::{FaultProfile, FaultSchedule, LinkEvent};
pub use time::{SimTime, UNREACHABLE_NS};
pub use trace::FlowEvent;
pub use transfer::{
    ns_chunk, ByteRole, Deps, MergeHandle, OpByte, OpEnd, OpId, Plan, PlanTemplate, PlannedOp,
    SimOp, LABEL_NS_STRIDE, NO_CLASS,
};
