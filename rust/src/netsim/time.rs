//! Virtual time.

/// Virtual simulation time in nanoseconds.
pub type SimTime = u64;

/// Convert a SimTime (ns) to microseconds as `f64` (the unit the paper's
/// figures report).
#[inline]
pub fn ns_to_us(t: SimTime) -> f64 {
    t as f64 / 1000.0
}

/// Convert microseconds to SimTime (ns).
#[inline]
pub fn us_to_ns(us: f64) -> SimTime {
    (us * 1000.0).round() as SimTime
}

/// Transmission-time sentinel for unreachable links: a non-positive (or
/// NaN) bandwidth can never move a byte, so [`tx_ns`] reports this value
/// instead of the `inf.round() as u64 == u64::MAX` it used to produce —
/// which overflowed the engine's `start + overhead + latency + tx` sum.
/// A quarter of the clock range leaves headroom for overhead/latency
/// additions (done with `saturating_add`) and for chains of ops scheduled
/// after an unreachable completion, without ever wrapping `u64` time.
pub const UNREACHABLE_NS: SimTime = SimTime::MAX / 4;

/// Convert a bytes/bandwidth pair to transmission nanoseconds.
///
/// A non-positive or NaN bandwidth names an unreachable link: the result
/// is the saturating [`UNREACHABLE_NS`] sentinel (finite results are also
/// capped there). An *infinite* bandwidth is the trivial same-device
/// route: free.
#[inline]
pub fn tx_ns(bytes: u64, bandwidth_bytes_per_sec: f64) -> SimTime {
    if bandwidth_bytes_per_sec.is_nan() || bandwidth_bytes_per_sec <= 0.0 {
        return UNREACHABLE_NS;
    }
    if bytes == 0 || bandwidth_bytes_per_sec.is_infinite() {
        return 0;
    }
    let t = (bytes as f64 / bandwidth_bytes_per_sec * 1.0e9).round();
    if t >= UNREACHABLE_NS as f64 {
        UNREACHABLE_NS
    } else {
        t as SimTime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(ns_to_us(1500), 1.5);
        assert_eq!(us_to_ns(2.5), 2500);
        assert_eq!(tx_ns(1_000_000_000, 1.0e9), 1_000_000_000);
        assert_eq!(tx_ns(0, 1.0e9), 0);
        assert_eq!(tx_ns(100, f64::INFINITY), 0);
    }

    #[test]
    fn degenerate_bandwidth_saturates_to_sentinel() {
        // regression: zero bandwidth used to produce u64::MAX, which
        // overflowed the engine's completion-time sums
        assert_eq!(tx_ns(100, 0.0), UNREACHABLE_NS);
        assert_eq!(tx_ns(100, -1.0), UNREACHABLE_NS);
        assert_eq!(tx_ns(100, f64::NAN), UNREACHABLE_NS);
        assert_eq!(tx_ns(0, 0.0), UNREACHABLE_NS);
        // huge-but-finite results cap at the sentinel too
        assert_eq!(tx_ns(u64::MAX, f64::MIN_POSITIVE), UNREACHABLE_NS);
        // and the sentinel leaves room for downstream additions
        assert!(UNREACHABLE_NS.checked_add(UNREACHABLE_NS).is_some());
    }
}
