//! Virtual time.

/// Virtual simulation time in nanoseconds.
pub type SimTime = u64;

/// Convert a SimTime (ns) to microseconds as `f64` (the unit the paper's
/// figures report).
#[inline]
pub fn ns_to_us(t: SimTime) -> f64 {
    t as f64 / 1000.0
}

/// Convert microseconds to SimTime (ns).
#[inline]
pub fn us_to_ns(us: f64) -> SimTime {
    (us * 1000.0).round() as SimTime
}

/// Convert a bytes/bandwidth pair to transmission nanoseconds.
#[inline]
pub fn tx_ns(bytes: u64, bandwidth_bytes_per_sec: f64) -> SimTime {
    if bytes == 0 || !bandwidth_bytes_per_sec.is_finite() {
        return 0;
    }
    (bytes as f64 / bandwidth_bytes_per_sec * 1.0e9).round() as SimTime
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(ns_to_us(1500), 1.5);
        assert_eq!(us_to_ns(2.5), 2500);
        assert_eq!(tx_ns(1_000_000_000, 1.0e9), 1_000_000_000);
        assert_eq!(tx_ns(0, 1.0e9), 0);
        assert_eq!(tx_ns(100, f64::INFINITY), 0);
    }
}
