//! Deterministic fault injection: link failures, bandwidth degradation,
//! straggler ranks, and per-link jitter.
//!
//! A [`FaultSchedule`] is a fully *realized* fault scenario — concrete
//! per-link bandwidth events on the virtual clock plus per-rank duration
//! multipliers — that both engine loops execute
//! ([`super::engine::Engine::set_faults`]):
//!
//! * a **degraded** link (`bw_factor` in `(0, 1)`) rescales that link's
//!   capacity from the event instant on. The FIFO loop resolves each
//!   transfer against the factors in effect at its start (cut-through
//!   occupancy has no in-flight state to re-rate); the fair-share loop
//!   re-seeds the link and triggers the incremental max-min re-solve
//!   with the new capacity, re-rating in-flight flows;
//! * a **failed** link (`bw_factor == 0`) starves everything crossing
//!   it. In-flight fair-share flows are dropped back to the ready set
//!   and retried over a [`crate::topology::Cluster::route_via`] detour
//!   (via hosts/HCAs, in device-id order) under a bounded
//!   retry/timeout budget; when no live detour exists within the
//!   budget, the op completes at the [`super::time::UNREACHABLE_NS`]
//!   sentinel and the run finishes *partially* — per-rank delivery
//!   status is reported by
//!   [`super::engine::ExecResult::degraded_outcome`] instead of
//!   panicking;
//! * a **straggler** rank multiplies every overhead/issue/delay charged
//!   to its device (slow kernels, slow injection).
//!
//! Schedules are usually produced from a [`FaultProfile`] — the parsed
//! `--faults` specification — whose random draws
//! ([`FaultProfile::realize`]) come from the deterministic
//! [`crate::util::rng`] generators: same profile + same seed + same
//! cluster ⇒ the same schedule, on any thread count. An **empty**
//! schedule is the healthy fabric: the engine's fault paths are gated
//! on non-emptiness, so results are bit-identical to an engine without
//! fault support (pinned by the golden-parity suite).
//!
//! See DESIGN.md §Fault model for the schedule format and the
//! retry/timeout and degraded-outcome contracts.

use crate::error::{Error, Result};
use crate::topology::{Cluster, LinkId};
use crate::util::rng::Rng;

use super::time::SimTime;

/// Default retry budget: how many timed detour attempts a transfer
/// crossing a failed link gets before completing at the sentinel.
pub const DEFAULT_RETRY_BUDGET: u32 = 2;

/// Default per-attempt retry timeout (1 ms of virtual time): each detour
/// attempt re-admits the op this much later.
pub const DEFAULT_RETRY_TIMEOUT_NS: SimTime = 1_000_000;

/// One bandwidth event on one directed link: from `at_ns` on, the link
/// runs at `bw_factor` × its nominal bandwidth. `0.0` is a hard failure;
/// a later event on the same link may restore it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkEvent {
    pub at_ns: SimTime,
    pub link: LinkId,
    pub bw_factor: f64,
}

/// A realized fault scenario on the virtual clock. Build one directly,
/// through the `with_*` helpers, or from a parsed [`FaultProfile`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    /// Bandwidth events, sorted by `(at_ns, link)` — [`Self::normalize`]
    /// restores the order after manual pushes.
    pub link_events: Vec<LinkEvent>,
    /// `(rank, multiplier)` stragglers: every overhead/issue/delay on
    /// that rank's device is scaled by the multiplier.
    pub stragglers: Vec<(usize, f64)>,
    /// Detour attempts per op crossing a failed link.
    pub retry_budget: u32,
    /// Virtual time charged per detour attempt.
    pub retry_timeout_ns: SimTime,
}

impl Default for FaultSchedule {
    fn default() -> FaultSchedule {
        FaultSchedule {
            link_events: Vec::new(),
            stragglers: Vec::new(),
            retry_budget: DEFAULT_RETRY_BUDGET,
            retry_timeout_ns: DEFAULT_RETRY_TIMEOUT_NS,
        }
    }
}

impl FaultSchedule {
    /// `true` when the schedule perturbs nothing — the engine treats it
    /// exactly like no schedule at all (bit-identical execution).
    pub fn is_empty(&self) -> bool {
        self.link_events.is_empty() && self.stragglers.is_empty()
    }

    /// Append a bandwidth event (re-sorting lazily via
    /// [`Self::normalize`]).
    pub fn with_link_event(mut self, at_ns: SimTime, link: LinkId, bw_factor: f64) -> Self {
        self.link_events.push(LinkEvent {
            at_ns,
            link,
            bw_factor: bw_factor.max(0.0),
        });
        self.normalize();
        self
    }

    /// Append a straggler rank.
    pub fn with_straggler(mut self, rank: usize, multiplier: f64) -> Self {
        self.stragglers.push((rank, multiplier.max(0.0)));
        self
    }

    /// Override the retry/timeout budget.
    pub fn with_retry(mut self, budget: u32, timeout_ns: SimTime) -> Self {
        self.retry_budget = budget;
        self.retry_timeout_ns = timeout_ns;
        self
    }

    /// Restore the `(at_ns, link)` event order the engine's event cursor
    /// relies on (stable, so same-instant same-link events keep their
    /// insertion order and the last one wins).
    pub fn normalize(&mut self) {
        self.link_events
            .sort_by_key(|e| (e.at_ns, e.link.0));
    }

    /// Re-anchor the schedule for a recovery attempt starting `elapsed`
    /// ns into the original scenario, on a communicator whose rank `i`
    /// was original rank `alive_ranks[i]`:
    ///
    /// * events already fired (`at_ns <= elapsed`) collapse to factor
    ///   events at t = 0 — last event per link wins — so persistent
    ///   damage carries into the retry;
    /// * future events shift left by `elapsed`;
    /// * stragglers are remapped through `alive_ranks`; stragglers on
    ///   dead ranks drop out.
    ///
    /// Retry/timeout budgets are preserved.
    pub fn shifted(&self, elapsed: SimTime, alive_ranks: &[usize]) -> FaultSchedule {
        let mut out = self.shifted_healed(elapsed, alive_ranks);
        // collapse the past: last factor per link, re-issued at t = 0
        let mut past: Vec<(LinkId, f64)> = Vec::new();
        for e in self.link_events.iter().filter(|e| e.at_ns <= elapsed) {
            match past.iter_mut().find(|(l, _)| *l == e.link) {
                Some((_, f)) => *f = e.bw_factor,
                None => past.push((e.link, e.bw_factor)),
            }
        }
        for (link, bw_factor) in past {
            if bw_factor < 1.0 {
                out.link_events.push(LinkEvent {
                    at_ns: 0,
                    link,
                    bw_factor,
                });
            }
        }
        out.normalize();
        out
    }

    /// Like [`Self::shifted`], but past events are *dropped* instead of
    /// collapsed to t = 0 — the checkpoint/restart view, where restored
    /// hardware comes back healthy and only faults still in the future
    /// can strike again.
    pub fn shifted_healed(&self, elapsed: SimTime, alive_ranks: &[usize]) -> FaultSchedule {
        let mut out = FaultSchedule {
            link_events: Vec::new(),
            stragglers: Vec::new(),
            retry_budget: self.retry_budget,
            retry_timeout_ns: self.retry_timeout_ns,
        };
        for e in self.link_events.iter().filter(|e| e.at_ns > elapsed) {
            out.link_events.push(LinkEvent {
                at_ns: e.at_ns - elapsed,
                link: e.link,
                bw_factor: e.bw_factor,
            });
        }
        for &(rank, f) in &self.stragglers {
            if let Some(new_rank) = alive_ranks.iter().position(|&r| r == rank) {
                out.stragglers.push((new_rank, f));
            }
        }
        out.normalize();
        out
    }
}

/// One clause of a `--faults` specification (see [`FaultProfile`]).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultClause {
    /// `kill=N@TIME` — hard-fail N random live links at TIME.
    Kill { n: usize, at_ns: SimTime },
    /// `degrade=N:F@TIME` — scale N random live links to F at TIME.
    Degrade { n: usize, factor: f64, at_ns: SimTime },
    /// `link=I:F@TIME` — explicit event on link index I.
    Link {
        index: usize,
        factor: f64,
        at_ns: SimTime,
    },
    /// `straggle=N:F` — N random ranks run F× slower.
    Straggle { n: usize, factor: f64 },
    /// `rank=R:F` — explicit straggler.
    Rank { rank: usize, factor: f64 },
    /// `jitter=S` — every link's bandwidth drawn uniformly from
    /// `[1−S, 1] ×` nominal at t = 0 (degradation-only jitter).
    Jitter { spread: f64 },
    /// `retry=N` — detour attempts per failed transfer.
    Retry { budget: u32 },
    /// `timeout=T` — virtual time per detour attempt.
    Timeout { ns: SimTime },
}

/// A parsed `--faults` specification: comma-separated clauses, e.g.
///
/// ```text
/// kill=1@500us,degrade=2:0.5@200us,straggle=1:3,jitter=0.05,retry=2,timeout=1ms
/// ```
///
/// A profile is *symbolic* — which links/ranks the random clauses hit is
/// drawn per trial by [`FaultProfile::realize`] from a seeded
/// [`Rng`], in fixed clause order, so a `(profile, cluster, seed)`
/// triple always realizes the same [`FaultSchedule`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultProfile {
    pub clauses: Vec<FaultClause>,
}

impl FaultProfile {
    /// Parse a comma-separated clause list (grammar above). Empty input
    /// parses to an empty profile (healthy fabric).
    pub fn parse(spec: &str) -> Result<FaultProfile> {
        let mut clauses = Vec::new();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (key, val) = raw
                .split_once('=')
                .ok_or_else(|| bad(raw, "expected key=value"))?;
            let clause = match key {
                "kill" => {
                    let (n, at) = split_at(val, raw)?;
                    FaultClause::Kill {
                        n: parse_count(n, raw)?,
                        at_ns: parse_ns(at)?,
                    }
                }
                "degrade" => {
                    let (nf, at) = split_at(val, raw)?;
                    let (n, f) = split_colon(nf, raw)?;
                    FaultClause::Degrade {
                        n: parse_count(n, raw)?,
                        factor: parse_factor(f, raw)?,
                        at_ns: parse_ns(at)?,
                    }
                }
                "link" => {
                    let (nf, at) = split_at(val, raw)?;
                    let (i, f) = split_colon(nf, raw)?;
                    FaultClause::Link {
                        index: parse_count(i, raw)?,
                        factor: parse_factor(f, raw)?,
                        at_ns: parse_ns(at)?,
                    }
                }
                "straggle" => {
                    let (n, f) = split_colon(val, raw)?;
                    FaultClause::Straggle {
                        n: parse_count(n, raw)?,
                        factor: parse_factor_unbounded(f, raw)?,
                    }
                }
                "rank" => {
                    let (r, f) = split_colon(val, raw)?;
                    FaultClause::Rank {
                        rank: parse_count(r, raw)?,
                        factor: parse_factor_unbounded(f, raw)?,
                    }
                }
                "jitter" => FaultClause::Jitter {
                    spread: parse_factor(val, raw)?,
                },
                "retry" => FaultClause::Retry {
                    budget: parse_count(val, raw)? as u32,
                },
                "timeout" => FaultClause::Timeout { ns: parse_ns(val)? },
                other => {
                    return Err(Error::Usage(format!(
                        "unknown fault clause '{other}' in '{raw}' (expected \
                         kill|degrade|link|straggle|rank|jitter|retry|timeout)"
                    )));
                }
            };
            clauses.push(clause);
        }
        Ok(FaultProfile { clauses })
    }

    /// `true` when the profile has no clauses.
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Realize the profile into a concrete schedule for one trial. All
    /// random draws come from `Rng::new(seed)` in fixed clause order,
    /// so the realization is a pure function of
    /// `(profile, cluster, seed)`. Random link clauses draw without
    /// replacement from the cluster's *live* (bandwidth > 0) directed
    /// links; random stragglers draw from the GPU ranks. Explicit
    /// `link=I:...` / `rank=R:...` clauses whose index is out of range
    /// for this cluster are rejected with a usage error (they used to
    /// silently no-op or panic downstream).
    pub fn realize(&self, cluster: &Cluster, seed: u64) -> Result<FaultSchedule> {
        let mut rng = Rng::new(seed);
        let mut schedule = FaultSchedule::default();
        let live_links: Vec<usize> = (0..cluster.n_links())
            .filter(|&l| cluster.links()[l].bandwidth > 0.0)
            .collect();
        for clause in &self.clauses {
            match *clause {
                FaultClause::Jitter { spread } => {
                    for &l in &live_links {
                        let f = 1.0 - spread.clamp(0.0, 1.0) * rng.next_f64();
                        schedule.link_events.push(LinkEvent {
                            at_ns: 0,
                            link: LinkId(l),
                            bw_factor: f,
                        });
                    }
                }
                FaultClause::Kill { n, at_ns } => {
                    for l in draw_links(&mut rng, &live_links, n) {
                        schedule.link_events.push(LinkEvent {
                            at_ns,
                            link: LinkId(l),
                            bw_factor: 0.0,
                        });
                    }
                }
                FaultClause::Degrade { n, factor, at_ns } => {
                    for l in draw_links(&mut rng, &live_links, n) {
                        schedule.link_events.push(LinkEvent {
                            at_ns,
                            link: LinkId(l),
                            bw_factor: factor,
                        });
                    }
                }
                FaultClause::Link {
                    index,
                    factor,
                    at_ns,
                } => {
                    if index >= cluster.n_links() {
                        return Err(Error::Usage(format!(
                            "fault clause 'link={index}:...' out of range: cluster \
                             '{}' has {} directed links (indices 0..={})",
                            cluster.name,
                            cluster.n_links(),
                            cluster.n_links().saturating_sub(1)
                        )));
                    }
                    schedule.link_events.push(LinkEvent {
                        at_ns,
                        link: LinkId(index),
                        bw_factor: factor,
                    });
                }
                FaultClause::Straggle { n, factor } => {
                    let ranks: Vec<usize> = (0..cluster.n_gpus()).collect();
                    for r in draw_links(&mut rng, &ranks, n) {
                        schedule.stragglers.push((r, factor));
                    }
                }
                FaultClause::Rank { rank, factor } => {
                    if rank >= cluster.n_gpus() {
                        return Err(Error::Usage(format!(
                            "fault clause 'rank={rank}:...' out of range: cluster \
                             '{}' has {} GPU ranks (indices 0..={})",
                            cluster.name,
                            cluster.n_gpus(),
                            cluster.n_gpus().saturating_sub(1)
                        )));
                    }
                    schedule.stragglers.push((rank, factor));
                }
                FaultClause::Retry { budget } => schedule.retry_budget = budget,
                FaultClause::Timeout { ns } => schedule.retry_timeout_ns = ns,
            }
        }
        schedule.normalize();
        Ok(schedule)
    }
}

/// Draw `n` distinct elements of `pool` (all of them when `n >= len`),
/// in draw order — deterministic given the generator state.
fn draw_links(rng: &mut Rng, pool: &[usize], n: usize) -> Vec<usize> {
    if n >= pool.len() {
        return pool.to_vec();
    }
    let mut remaining: Vec<usize> = pool.to_vec();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let i = rng.range_usize(0, remaining.len() - 1);
        out.push(remaining.swap_remove(i));
    }
    out
}

/// Parse a duration with an optional `ns`/`us`/`ms`/`s` suffix (bare
/// numbers are nanoseconds): `"500us"`, `"1.5ms"`, `"2s"`, `"1500"`.
pub fn parse_ns(s: &str) -> Result<SimTime> {
    let s = s.trim();
    let (num, mult) = if let Some(v) = s.strip_suffix("ns") {
        (v, 1.0)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1.0e3)
    } else if let Some(v) = s.strip_suffix("ms") {
        (v, 1.0e6)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0e9)
    } else {
        (s, 1.0)
    };
    let x: f64 = num
        .trim()
        .parse()
        .map_err(|_| Error::Usage(format!("cannot parse duration '{s}'")))?;
    if x < 0.0 {
        return Err(Error::Usage(format!("negative duration '{s}'")));
    }
    Ok((x * mult).round() as SimTime)
}

fn bad(clause: &str, why: &str) -> Error {
    Error::Usage(format!("bad fault clause '{clause}': {why}"))
}

fn split_at<'a>(val: &'a str, clause: &str) -> Result<(&'a str, &'a str)> {
    val.split_once('@')
        .ok_or_else(|| bad(clause, "expected ...@TIME"))
}

fn split_colon<'a>(val: &'a str, clause: &str) -> Result<(&'a str, &'a str)> {
    val.split_once(':')
        .ok_or_else(|| bad(clause, "expected A:B"))
}

fn parse_count(s: &str, clause: &str) -> Result<usize> {
    s.trim()
        .parse()
        .map_err(|_| bad(clause, "expected an integer"))
}

fn parse_factor(s: &str, clause: &str) -> Result<f64> {
    let f: f64 = s
        .trim()
        .parse()
        .map_err(|_| bad(clause, "expected a factor"))?;
    if !(0.0..=1.0).contains(&f) {
        return Err(bad(clause, "factor must be in [0, 1]"));
    }
    Ok(f)
}

fn parse_factor_unbounded(s: &str, clause: &str) -> Result<f64> {
    let f: f64 = s
        .trim()
        .parse()
        .map_err(|_| bad(clause, "expected a multiplier"))?;
    if f < 0.0 {
        return Err(bad(clause, "multiplier must be >= 0"));
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets::kesch;

    #[test]
    fn parse_ns_suffixes() {
        assert_eq!(parse_ns("1500").unwrap(), 1500);
        assert_eq!(parse_ns("1500ns").unwrap(), 1500);
        assert_eq!(parse_ns("500us").unwrap(), 500_000);
        assert_eq!(parse_ns("1.5ms").unwrap(), 1_500_000);
        assert_eq!(parse_ns("2s").unwrap(), 2_000_000_000);
        assert!(parse_ns("banana").is_err());
        assert!(parse_ns("-3us").is_err());
    }

    #[test]
    fn profile_grammar_round_trip() {
        let p = FaultProfile::parse(
            "kill=1@500us,degrade=2:0.5@200us,link=7:0.25@1ms,straggle=1:3,\
             rank=0:2.5,jitter=0.05,retry=4,timeout=2ms",
        )
        .unwrap();
        assert_eq!(p.clauses.len(), 8);
        assert_eq!(
            p.clauses[0],
            FaultClause::Kill {
                n: 1,
                at_ns: 500_000
            }
        );
        assert_eq!(
            p.clauses[3],
            FaultClause::Straggle { n: 1, factor: 3.0 }
        );
        assert!(FaultProfile::parse("").unwrap().is_empty());
        assert!(FaultProfile::parse("bogus=1").is_err());
        assert!(FaultProfile::parse("kill=1").is_err(), "missing @TIME");
        assert!(FaultProfile::parse("degrade=1:1.5@0").is_err(), "factor > 1");
    }

    #[test]
    fn realize_is_deterministic_and_seed_sensitive() {
        let cluster = kesch(2, 8).unwrap();
        let p = FaultProfile::parse("kill=2@500us,degrade=3:0.5@200us,straggle=2:3").unwrap();
        let a = p.realize(&cluster, 42).unwrap();
        let b = p.realize(&cluster, 42).unwrap();
        assert_eq!(a, b, "same seed must realize the same schedule");
        let c = p.realize(&cluster, 43).unwrap();
        assert_ne!(a, c, "different seeds should hit different links");
        assert_eq!(a.link_events.len(), 5);
        assert_eq!(a.stragglers.len(), 2);
        // events come out sorted by (time, link)
        for w in a.link_events.windows(2) {
            assert!((w[0].at_ns, w[0].link.0) <= (w[1].at_ns, w[1].link.0));
        }
        // kills draw distinct links
        let kills: Vec<usize> = a
            .link_events
            .iter()
            .filter(|e| e.bw_factor == 0.0)
            .map(|e| e.link.0)
            .collect();
        assert_eq!(kills.len(), 2);
        assert_ne!(kills[0], kills[1]);
    }

    #[test]
    fn empty_schedule_and_profile() {
        let s = FaultSchedule::default();
        assert!(s.is_empty());
        assert_eq!(s.retry_budget, DEFAULT_RETRY_BUDGET);
        assert_eq!(s.retry_timeout_ns, DEFAULT_RETRY_TIMEOUT_NS);
        let cluster = kesch(1, 4).unwrap();
        let realized = FaultProfile::default().realize(&cluster, 7).unwrap();
        assert!(realized.is_empty());
        assert_eq!(realized, s);
    }

    #[test]
    fn realize_rejects_out_of_range_link_and_rank() {
        let cluster = kesch(1, 4).unwrap();
        let n_links = cluster.n_links();
        let p = FaultProfile::parse(&format!("link={n_links}:0.5@0")).unwrap();
        let err = p.realize(&cluster, 1).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("out of range") && msg.contains(&format!("{n_links} directed links")),
            "unexpected message: {msg}"
        );
        let p = FaultProfile::parse("rank=4:2.0").unwrap();
        let err = p.realize(&cluster, 1).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("out of range") && msg.contains("4 GPU ranks"),
            "unexpected message: {msg}"
        );
        // boundary indices still realize
        let p = FaultProfile::parse(&format!("link={}:0.5@0,rank=3:2.0", n_links - 1)).unwrap();
        let s = p.realize(&cluster, 1).unwrap();
        assert_eq!(s.link_events.len(), 1);
        assert_eq!(s.stragglers, vec![(3, 2.0)]);
    }

    #[test]
    fn shifted_collapses_past_and_shifts_future() {
        let s = FaultSchedule::default()
            .with_link_event(100, LinkId(3), 0.5)
            .with_link_event(200, LinkId(3), 0.0)
            .with_link_event(150, LinkId(5), 1.0)
            .with_link_event(900, LinkId(7), 0.25)
            .with_straggler(0, 2.0)
            .with_straggler(2, 3.0)
            .with_retry(5, 777);
        // shift past t = 300 with rank 0 dead (alive: original 1, 2, 3)
        let sh = s.shifted(300, &[1, 2, 3]);
        // link 3: last past event (kill) carries at t = 0; link 5's
        // restore-to-1.0 is the identity and drops out
        assert_eq!(
            sh.link_events,
            vec![
                LinkEvent {
                    at_ns: 0,
                    link: LinkId(3),
                    bw_factor: 0.0
                },
                LinkEvent {
                    at_ns: 600,
                    link: LinkId(7),
                    bw_factor: 0.25
                },
            ]
        );
        // straggler on dead rank 0 dropped; original rank 2 is now rank 1
        assert_eq!(sh.stragglers, vec![(1, 3.0)]);
        assert_eq!(sh.retry_budget, 5);
        assert_eq!(sh.retry_timeout_ns, 777);
        // healed view: past damage gone entirely
        let healed = s.shifted_healed(300, &[1, 2, 3]);
        assert_eq!(
            healed.link_events,
            vec![LinkEvent {
                at_ns: 600,
                link: LinkId(7),
                bw_factor: 0.25
            }]
        );
    }

    #[test]
    fn jitter_degrades_only() {
        let cluster = kesch(1, 8).unwrap();
        let p = FaultProfile::parse("jitter=0.1").unwrap();
        let s = p.realize(&cluster, 9).unwrap();
        assert!(!s.link_events.is_empty());
        for e in &s.link_events {
            assert_eq!(e.at_ns, 0);
            assert!(
                (0.9..=1.0).contains(&e.bw_factor),
                "jitter factor {} out of [0.9, 1]",
                e.bw_factor
            );
        }
    }

    #[test]
    fn builders_keep_events_sorted() {
        let s = FaultSchedule::default()
            .with_link_event(2000, LinkId(3), 0.5)
            .with_link_event(1000, LinkId(7), 0.0)
            .with_straggler(1, 2.0)
            .with_retry(1, 500);
        assert_eq!(s.link_events[0].link, LinkId(7));
        assert_eq!(s.retry_budget, 1);
        assert_eq!(s.retry_timeout_ns, 500);
        assert!(!s.is_empty());
    }
}
